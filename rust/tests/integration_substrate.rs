//! Integration over the Ray-like substrate: placement under load,
//! object-store broadcast, fault injection + checkpoint recovery (C3/C4),
//! and the cooperative function API driven by real schedulers.

use std::sync::Arc;

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, ParamValue, RunOptions, SchedulerKind,
    SearchKind, TrialStatus,
};
use tune::ray::{Cluster, FaultPlan, ObjectStore, Resources};
use tune::trainable::factory;
use tune::trainable::function::{FunctionTrainable, TuneHandle};
use tune::trainable::synthetic::ConstTrainable;

/// C3: trial throughput scales with cluster size (512 short trials).
#[test]
fn throughput_scales_with_nodes() {
    let run = |nodes: usize| {
        let mut spec = ExperimentSpec::named("scaling");
        spec.metric = "iters".into();
        spec.mode = Mode::Max;
        spec.num_samples = 256;
        spec.max_iterations_per_trial = 4;
        let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
        run_experiments(
            spec,
            space,
            SchedulerKind::Fifo,
            SearchKind::Random,
            factory(|c, s| Box::new(ConstTrainable::new(c, s))),
            RunOptions {
                cluster: Cluster::uniform(nodes, Resources::cpu(4.0)),
                ..Default::default()
            },
        )
    };
    let one = run(1);
    let eight = run(8);
    // Virtual duration shrinks near-linearly with node count.
    let speedup = one.duration_s / eight.duration_s;
    assert!(speedup > 6.0, "speedup {speedup}");
    // Two-level placement: with one node everything is local; with 8
    // nodes the head node saturates and work spills.
    assert_eq!(one.placement.spilled, 0);
    assert!(eight.placement.spilled > 0);
}

/// C4: heavy step-failure injection with checkpointing — every trial
/// still completes, recovering from its latest checkpoint.
#[test]
fn failure_storm_recovers_via_checkpoints() {
    let mut spec = ExperimentSpec::named("faults");
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = 24;
    spec.max_iterations_per_trial = 40;
    spec.checkpoint_freq = 4;
    spec.max_failures = 100;
    spec.fault_plan = FaultPlan::flaky_steps(0.05);
    let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(2, Resources::cpu(8.0)),
            ..Default::default()
        },
    );
    assert_eq!(res.count(TrialStatus::Completed), 24, "{:?}", res.stats);
    assert!(res.stats.failures_recovered > 10);
    assert!(res.stats.restores > 0);
}

/// Zero tolerance: max_failures = 0 must error trials out instead.
#[test]
fn max_failures_zero_errors_out() {
    let mut spec = ExperimentSpec::named("fragile");
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = 16;
    spec.max_iterations_per_trial = 50;
    spec.max_failures = 0;
    spec.fault_plan = FaultPlan::flaky_steps(0.05);
    let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
        RunOptions::default(),
    );
    assert!(res.stats.errored > 0);
    assert_eq!(res.stats.failures_recovered, 0);
}

/// §4.3.2: weight broadcast through the object store — one transfer per
/// remote node, local hits afterwards.
#[test]
fn object_store_broadcast_pattern() {
    let mut store = ObjectStore::new();
    let weights = vec![0u8; 1 << 20]; // 1 MiB of "weights"
    let id = store.put(0, weights);
    // 16 trials spread over 4 nodes fetch at init.
    for trial in 0..16u32 {
        let node = trial % 4;
        let got = store.get(node, id).unwrap();
        assert_eq!(got.len(), 1 << 20);
    }
    assert_eq!(store.transfers, 3); // nodes 1..3; node 0 was local
    assert_eq!(store.transfer_bytes, 3 << 20);
    assert_eq!(store.local_hits, 13);
}

/// The cooperative function API (Figure 2(a)) composed with ASHA over
/// the threaded executor: reports flow, bad trials stop early.
#[test]
fn function_api_under_asha_threads() {
    let train = Arc::new(|tune: TuneHandle| {
        // Converges to `quality`, fast; reports every iteration.
        let quality = tune.param_f64("quality", 0.5);
        let mut acc = 0.0;
        for i in (tune.start_iteration() + 1)..=100 {
            acc += (quality - acc) * 0.3;
            if tune.should_checkpoint() {
                tune.record_checkpoint(acc.to_le_bytes().to_vec());
            }
            if !tune.report(i, &[("accuracy", acc)]) {
                return;
            }
        }
    });
    let mut spec = ExperimentSpec::named("fn-asha");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = 12;
    spec.max_iterations_per_trial = 30;
    spec.max_concurrent = 4;
    let space = SpaceBuilder::new().uniform("quality", 0.1, 0.9).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Asha { grace_period: 2, reduction_factor: 2.0, max_t: 30 },
        SearchKind::Random,
        factory(move |c, s| {
            Box::new(FunctionTrainable::spawn(c.clone(), s, train.clone()))
        }),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(4.0)),
            exec: ExecMode::Threads,
            ..Default::default()
        },
    );
    assert_eq!(res.trials.len(), 12);
    assert!(res.count(TrialStatus::Stopped) > 0, "ASHA stopped nothing");
    assert!(res.best_metric().unwrap() > 0.6);
    for t in res.trials.values() {
        assert!(t.status.is_terminal());
    }
}

/// Resource accounting stays exact across a whole noisy experiment.
#[test]
fn cluster_invariants_hold_under_churn() {
    // Churn: failures + node failures + pauses (hyperband).
    let mut spec = ExperimentSpec::named("churn");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = 32;
    spec.max_iterations_per_trial = 27;
    spec.checkpoint_freq = 3;
    spec.max_failures = 50;
    spec.fault_plan = FaultPlan { step_failure_prob: 0.01, node_failure_prob: 0.002, ..Default::default() };
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::HyperBand { max_t: 27, eta: 3.0 },
        SearchKind::Random,
        factory(|c, s| Box::new(tune::trainable::synthetic::CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(4.0)),
            ..Default::default()
        },
    );
    // All trials terminal, none stuck; accounting verified inside the
    // cluster (check_invariants is exercised by the runner's release
    // paths — a leak would deadlock admission and fail the run).
    for t in res.trials.values() {
        assert!(t.status.is_terminal(), "trial {} stuck in {:?}", t.id, t.status);
    }
    assert!(res.stats.results > 0);
}
