//! Fault locality at scale: killing one node of a 10k-trial experiment
//! must touch only that node's trials, with work proportional to the
//! victim's lease count — not to the trial table. The runner's per-node
//! lease index is what makes this O(victim); this harness pins it with
//! the trial-table touch counter (the ops analogue of the counting
//! allocator in `alloc_count`).

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    build_runner, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind, TrialStatus,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

const SAMPLES: usize = 10_000;
const ITERS: u64 = 5;

#[test]
fn node_kill_at_10k_trials_touches_only_the_victims() {
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
    let mut spec = ExperimentSpec::named("scale-kill");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = ITERS;
    spec.seed = 7;
    spec.checkpoint_freq = 2; // bounds post-kill replay
    let mut runner = build_runner(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(24, Resources::cpu(16.0)),
            ..Default::default()
        },
    );

    // Reach a saturated steady state: hundreds of concurrent leases
    // spread over every node, thousands of trials in the table.
    while runner.debug_step() {
        if runner.debug_stats().results >= 2_000 {
            break;
        }
    }
    let (victim, victims) = runner.debug_busiest_node().expect("no leases at steady state");
    assert!(victims >= 8, "busiest node holds only {victims} leases");

    let before: std::collections::BTreeMap<u64, TrialStatus> =
        runner.trials().iter().map(|(id, t)| (*id, t.status)).collect();
    let touches_before = runner.debug_table_touches();
    let kill_touched_before = runner.debug_stats().kill_touched;

    runner.debug_kill_node(victim);

    // Work bound: the kill walked the victim's lease set, not the
    // 10k-entry table. Each failed trial costs a small constant of keyed
    // accesses (rollback, counter moves, requeue); 64x leaves generous
    // headroom while a full-table walk (10k touches minimum) still
    // fails by two orders of magnitude.
    let touch_delta = runner.debug_table_touches() - touches_before;
    assert!(
        touch_delta <= 64 * victims as u64 + 16,
        "kill of {victims} leases touched the table {touch_delta} times"
    );
    assert_eq!(
        runner.debug_stats().kill_touched - kill_touched_before,
        victims as u64,
        "kill_touched must count exactly the victim's trials"
    );

    // Blast radius: exactly the victim's trials changed, every one of
    // them Running -> Pending (first failure, so none errored out).
    let mut changed = 0usize;
    for (id, t) in runner.trials() {
        let old = before[id];
        if t.status != old {
            changed += 1;
            assert_eq!(old, TrialStatus::Running, "trial {id} was not running before the kill");
            assert_eq!(
                t.status,
                TrialStatus::Pending,
                "trial {id} should be requeued, not {:?}",
                t.status
            );
        }
    }
    assert_eq!(changed, victims, "blast radius was not confined to the victim node");
    runner.debug_check_indices().expect("indices diverged after the kill");

    // The dead node stays dead; the remaining 23 nodes absorb the
    // requeued trials and the run completes.
    while runner.debug_step() {}
    let res = runner.finalize();
    assert_eq!(res.trials.len(), SAMPLES);
    assert!(res.trials.values().all(|t| t.status.is_terminal()));
    assert_eq!(res.stats.kill_touched, victims as u64);
    assert_eq!(res.stats.failures_recovered, victims as u64);
    assert_eq!(res.count(TrialStatus::Completed), SAMPLES);
}
