//! Edge cases of the coordinator: endgame with orphaned paused trials,
//! scheduler/search compositions, zero-result metrics, degenerate specs.

use tune::coordinator::schedulers::{Decision, SchedulerCtx, TrialScheduler};
use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::trial::{ResultRow, Trial};
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind, TrialRunner,
    TrialStatus,
};
use tune::coordinator::executor::SimExecutor;
use tune::coordinator::search::RandomSearch;
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::{ConstTrainable, CurveTrainable};

/// A pathological scheduler that pauses everything and never resumes:
/// the runner's endgame must still terminate, stopping orphaned trials.
struct PauseForever;
impl TrialScheduler for PauseForever {
    fn name(&self) -> &'static str {
        "pause_forever"
    }
    fn on_result(&mut self, _: &SchedulerCtx, _: &Trial, _: &ResultRow) -> Decision {
        Decision::Pause
    }
    fn choose_trial_to_run(&mut self, ctx: &SchedulerCtx) -> Option<tune::coordinator::TrialId> {
        ctx.first_pending() // never offers paused trials back
    }
}

#[test]
fn orphaned_paused_trials_do_not_hang_the_runner() {
    let mut spec = ExperimentSpec::named("orphans");
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = 6;
    spec.max_iterations_per_trial = 50;
    let space = SpaceBuilder::new().uniform("x", 0.0, 1.0).build();
    let search = Box::new(RandomSearch::new(space, 6));
    let executor = Box::new(SimExecutor::new(factory(|c, s| {
        Box::new(ConstTrainable::new(c, s))
    })));
    let mut runner = TrialRunner::new(
        spec,
        Box::new(PauseForever),
        search,
        executor,
        Cluster::uniform(1, Resources::cpu(8.0)),
    );
    let res = runner.run(); // must return, not loop forever
    assert_eq!(res.trials.len(), 6);
    for t in res.trials.values() {
        assert_eq!(t.status, TrialStatus::Stopped);
        assert_eq!(t.iteration, 1); // paused after the first result
    }
    assert!(res.stats.checkpoints >= 6); // pause implies snapshot
}

/// HyperBand under a tight max_concurrent: rung barriers must still
/// complete even though cohort members run in small waves.
#[test]
fn hyperband_with_limited_concurrency_terminates() {
    let mut spec = ExperimentSpec::named("hb-tight");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = 20;
    spec.max_iterations_per_trial = 27;
    spec.max_concurrent = 2;
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::HyperBand { max_t: 27, eta: 3.0 },
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(16.0)),
            ..Default::default()
        },
    );
    assert_eq!(res.trials.len(), 20);
    for t in res.trials.values() {
        assert!(t.status.is_terminal());
    }
    assert!(res.stats.stopped_early > 0);
}

/// TPE composes with ASHA over a mixed continuous/categorical space
/// through the full runner.
#[test]
fn tpe_with_asha_on_mixed_space() {
    let mut spec = ExperimentSpec::named("tpe-asha");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = 40;
    spec.max_iterations_per_trial = 27;
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .choice_str("opt", &["sgd", "adam"])
        .randint("layers", 1, 4)
        .build();
    let res = run_experiments(
        spec,
        space.clone(),
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 27 },
        SearchKind::Tpe,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions::default(),
    );
    assert_eq!(res.trials.len(), 40);
    // Every config TPE emitted stays in the declared support.
    for t in res.trials.values() {
        for (k, d) in &space {
            assert!(d.contains(&t.config[k]), "{k}: {:?}", t.config[k]);
        }
    }
    assert!(res.best_metric().unwrap() > 0.8);
}

/// Trainables that report a metric the experiment doesn't track: the
/// scheduler sees no value and must keep the trial running to its
/// stopping criterion (never crash, never stop on missing data).
#[test]
fn missing_metric_defaults_to_continue() {
    let mut spec = ExperimentSpec::named("missing-metric");
    spec.metric = "no_such_metric".into();
    spec.mode = Mode::Max;
    spec.num_samples = 4;
    spec.max_iterations_per_trial = 10;
    let space = SpaceBuilder::new().uniform("x", 0.0, 1.0).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 2.0, max_t: 10 },
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
        RunOptions::default(),
    );
    assert_eq!(res.count(TrialStatus::Completed), 4);
    assert!(res.best.is_none()); // no metric ever observed
}

/// num_samples = 0 and empty spaces degrade gracefully.
#[test]
fn degenerate_specs_run_cleanly() {
    let mut spec = ExperimentSpec::named("empty");
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = 0;
    spec.max_iterations_per_trial = 5;
    let res = run_experiments(
        spec,
        SpaceBuilder::new().build(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
        RunOptions::default(),
    );
    assert_eq!(res.trials.len(), 0);
    assert_eq!(res.stats.results, 0);
}

/// A metric target in Min mode stops trials the moment they cross it.
#[test]
fn metric_target_min_mode() {
    let mut spec = ExperimentSpec::named("target");
    spec.metric = "loss".into();
    spec.mode = Mode::Min;
    spec.num_samples = 8;
    spec.max_iterations_per_trial = 10_000;
    spec.metric_target = Some(0.3);
    let space = SpaceBuilder::new().loguniform("lr", 0.01, 0.05).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions::default(),
    );
    // Good lr region: every trial reaches loss <= 0.3 well before 10k.
    assert_eq!(res.count(TrialStatus::Completed), 8);
    assert!(res.total_iterations() < 8 * 10_000);
    for t in res.trials.values() {
        let last = t.last_result.as_ref().unwrap().metric(&res.schema, "loss").unwrap();
        assert!(last <= 0.31, "trial {} stopped at loss {last}", t.id);
    }
}
