//! Resource-aware trials end to end: fractional CPU/GPU demands flowing
//! from the spec through placement, heterogeneous clusters, elastic
//! autoscaling with checkpoint-then-requeue preemption, executor-side
//! capacity vectors, fail-fast infeasibility — and sim-vs-pool
//! determinism of all of it (the ISSUE 5 acceptance scenarios).

use std::path::PathBuf;

use tune::coordinator::spec::{SearchSpace, SpaceBuilder};
use tune::coordinator::trial::Config;
use tune::coordinator::{
    build_runner, run_experiments, ExecMode, ExperimentResult, ExperimentSpec, Mode, RunOptions,
    SchedulerKind, SearchKind, TrialStatus,
};
use tune::ray::{AutoscalePolicy, Cluster, Resources};
use tune::trainable::synthetic::CurveTrainable;
use tune::trainable::{factory, StepOutput, Trainable, TrainableFactory};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tune_resources_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn curve_space() -> SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build()
}

fn spec(name: &str, samples: usize, iters: u64, seed: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = seed;
    spec
}

/// Two 4-GPU trainer nodes plus two CPU-only nodes — the heterogeneous
/// cluster of the acceptance scenario.
fn het_cluster() -> Cluster {
    Cluster::heterogeneous(vec![
        Resources::cpu_gpu(8.0, 4.0),
        Resources::cpu_gpu(8.0, 4.0),
        Resources::cpu(8.0),
        Resources::cpu(8.0),
    ])
}

/// [`CurveTrainable`] with a constant 1.0s step cost. With uniform step
/// costs the sim executor's virtual-time ordering degenerates to FIFO —
/// exactly the order a single-worker pool executes in — so sim and pool
/// produce identical event streams and therefore identical scheduler
/// decisions, autoscale ticks and preemptions. (The per-trial random
/// cost of the raw curve trainable is what usually makes virtual
/// ordering diverge from wall ordering.)
struct UniformCostCurve(CurveTrainable);

impl Trainable for UniformCostCurve {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.0.step()
    }
    fn save(&mut self) -> Vec<u8> {
        self.0.save()
    }
    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        self.0.restore(blob)
    }
    fn update_config(&mut self, config: &Config) {
        self.0.update_config(config)
    }
    fn step_cost(&self) -> f64 {
        1.0
    }
}

fn uniform_curve_factory() -> TrainableFactory {
    factory(|c, s| Box::new(UniformCostCurve(CurveTrainable::new(c, s))))
}

/// Clock-free fingerprint (id, status, iteration, config, metric bits):
/// byte-identical across executors means identical semantics.
fn fingerprint(res: &ExperimentResult) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    for t in res.trials.values() {
        writeln!(
            out,
            "{}|{}|{}|{}|{}",
            t.id,
            t.status.as_str(),
            t.iteration,
            tune::coordinator::trial::config_str(&t.config),
            t.best_metric.map(f64::to_bits).unwrap_or(0),
        )
        .unwrap();
    }
    out
}

// ---------------------------------------------------------------------------
// Fail fast on unsatisfiable demands
// ---------------------------------------------------------------------------

/// A gpu=9 demand on a cluster whose largest node has 4 GPUs must error
/// out before launching (or even creating) any trial — on the sim AND
/// the pool executor.
#[test]
fn unsatisfiable_gpu_demand_errors_before_any_launch() {
    for exec in [ExecMode::Sim, ExecMode::Pool { workers: 2 }] {
        let mut sp = spec("infeasible", 8, 10, 1);
        sp.resources_per_trial = Resources::cpu_gpu(1.0, 9.0);
        let res = run_experiments(
            sp,
            curve_space(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            uniform_curve_factory(),
            RunOptions { cluster: het_cluster(), exec, ..Default::default() },
        );
        let msg = res.infeasible.as_deref().expect("must report infeasibility");
        assert!(msg.contains("unsatisfiable"), "{msg}");
        assert_eq!(res.stats.launches, 0, "launched a trial despite infeasibility");
        assert!(res.trials.is_empty(), "created trials despite infeasibility");
        assert_eq!(res.stats.results, 0);
    }
}

/// NaN / negative demands are rejected the same way (never reach the
/// accounting), and a feasible demand reports no error.
#[test]
fn garbage_demands_fail_fast_and_clean_demands_do_not() {
    let mut bad = spec("nan-demand", 4, 5, 2);
    bad.resources_per_trial = Resources::cpu(f64::NAN);
    let res = run_experiments(
        bad,
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions { cluster: het_cluster(), ..Default::default() },
    );
    assert!(res.infeasible.is_some());
    assert!(res.trials.is_empty());

    let mut ok = spec("ok-demand", 4, 5, 2);
    ok.resources_per_trial = Resources::cpu_gpu(1.0, 0.5);
    let res = run_experiments(
        ok,
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions { cluster: het_cluster(), ..Default::default() },
    );
    assert!(res.infeasible.is_none());
    assert_eq!(res.count(TrialStatus::Completed), 4);
}

// ---------------------------------------------------------------------------
// Placement honors demands; scarce capacity parks trials as Pending
// ---------------------------------------------------------------------------

/// Fractional-GPU trials only ever land on GPU-bearing nodes, and
/// capacity bounds concurrency: 8 GPUs at 0.5/trial = 16 concurrent.
#[test]
fn gpu_demands_place_only_on_gpu_nodes_and_bound_parallelism() {
    let mut sp = spec("placement", 24, 8, 3);
    sp.resources_per_trial = Resources::cpu_gpu(1.0, 0.5);
    let res = run_experiments(
        sp,
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions { cluster: het_cluster(), ..Default::default() },
    );
    assert_eq!(res.count(TrialStatus::Completed), 24);
    for t in res.trials.values() {
        let node = t.node.expect("every trial ran somewhere");
        assert!(node < 2, "gpu trial {} placed on CPU-only node {node}", t.id);
    }
    // 24 trials over 16 GPU slots: someone had to wait (placement
    // failures are the Pending-parking signal, not errors)...
    assert!(res.placement.failed > 0);
    assert_eq!(res.stats.errored, 0);
    // ...and the virtual duration reflects ≤16-way parallelism.
    assert!(res.duration_s >= res.budget_used_s / 16.0 - 1e-6);
}

/// A demand that fits the cluster but exceeds every *executor worker*
/// capacity vector errors trials with a clear message instead of
/// hanging (the executor-side Infeasible path).
#[test]
fn executor_worker_capacity_infeasible_errors_trials() {
    let mut sp = spec("worker-infeasible", 3, 5, 4);
    sp.resources_per_trial = Resources::cpu(2.0);
    let res = run_experiments(
        sp,
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(8.0)),
            exec: ExecMode::Pool { workers: 2 },
            // Each worker holds 1 CPU: a 2-CPU trainable fits nowhere.
            worker_caps: Some(vec![Resources::cpu(1.0), Resources::cpu(1.0)]),
            ..Default::default()
        },
    );
    assert_eq!(res.count(TrialStatus::Errored), res.trials.len());
    assert!(!res.trials.is_empty());
}

/// Executor capacity vectors bound live trainables: 2 one-CPU workers
/// serve 6 one-CPU trials by parking the overflow as Pending until
/// capacity frees — everything still completes.
#[test]
fn executor_worker_capacity_exhaustion_parks_and_completes() {
    let mut sp = spec("worker-exhausted", 6, 5, 5);
    sp.resources_per_trial = Resources::cpu(1.0);
    let res = run_experiments(
        sp,
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(64.0)),
            exec: ExecMode::Pool { workers: 2 },
            worker_caps: Some(vec![Resources::cpu(1.0), Resources::cpu(1.0)]),
            ..Default::default()
        },
    );
    assert_eq!(res.count(TrialStatus::Completed), 6);
    assert_eq!(res.stats.errored, 0);
}

// ---------------------------------------------------------------------------
// Elastic autoscaling: shrink never loses a trial
// ---------------------------------------------------------------------------

/// Aggressive consolidation: every node (even one hosting trials) falls
/// under the 80% scale-down threshold, so draining repeatedly preempts
/// running trials — checkpoint-then-requeue must carry every trial to
/// completion with zero lost iterations, across repeated shrink/grow
/// churn.
#[test]
fn drain_preempts_checkpoint_then_requeue_loses_nothing() {
    let mut sp = spec("drain", 3, 12, 6);
    sp.resources_per_trial = Resources::cpu(1.0);
    let res = run_experiments(
        sp,
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions {
            cluster: Cluster::uniform(2, Resources::cpu(4.0)),
            autoscale: Some(AutoscalePolicy {
                node_template: Resources::cpu(4.0),
                templates: Vec::new(),
                min_nodes: 0,
                max_nodes: 2,
                scale_up_after: 2,
                scale_down_after: 10,
                scale_down_util: 0.8,
            }),
            ..Default::default()
        },
    );
    assert_eq!(res.count(TrialStatus::Completed), 3, "{:?}", res.stats);
    assert_eq!(res.stats.errored, 0);
    // Every completed trial reached full term: preemption lost nothing.
    assert_eq!(res.total_iterations(), 3 * 12);
    assert!(res.stats.preemptions >= 3, "no preemption happened: {:?}", res.stats);
    assert!(res.stats.scale_downs >= 1, "{:?}", res.stats);
    assert!(res.stats.scale_ups >= 1, "{:?}", res.stats);
    // Preempted trials relaunched from their preemption checkpoints.
    assert!(res.stats.restores >= res.stats.preemptions);
}

/// The acceptance scenario: a 64-trial ASHA run with 0.5-GPU demands on
/// the heterogeneous cluster, under an elastic autoscaler that grows on
/// queue pressure and shrinks as ASHA culls the population. It must
/// complete with no lost trials across the shrink, and the sim and
/// (single-worker) pool executors must produce byte-identical
/// fingerprints — identical best trial included — because uniform step
/// costs make both event streams FIFO.
#[test]
fn asha_64_halfgpu_autoscaled_identical_on_sim_and_pool() {
    let run = |exec: ExecMode| {
        let mut sp = spec("asha-het", 64, 27, 7);
        sp.resources_per_trial = Resources::cpu_gpu(1.0, 0.5);
        sp.checkpoint_freq = 5;
        run_experiments(
            sp,
            curve_space(),
            SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 27 },
            SearchKind::Random,
            uniform_curve_factory(),
            RunOptions {
                cluster: het_cluster(),
                exec,
                autoscale: Some(AutoscalePolicy {
                    node_template: Resources::cpu_gpu(8.0, 4.0),
                    templates: Vec::new(),
                    min_nodes: 2,
                    max_nodes: 6,
                    scale_up_after: 3,
                    scale_down_after: 60,
                    scale_down_util: 0.3,
                }),
                ..Default::default()
            },
        )
    };
    let sim = run(ExecMode::Sim);
    // All 64 trials accounted for, none lost, none errored.
    assert_eq!(sim.trials.len(), 64);
    for t in sim.trials.values() {
        assert!(t.status.is_terminal(), "trial {} stuck in {:?}", t.id, t.status);
    }
    assert_eq!(sim.stats.errored, 0);
    assert_eq!(
        sim.count(TrialStatus::Completed) + sim.count(TrialStatus::Stopped),
        64
    );
    // The elastic story actually happened: pressure grew the cluster,
    // the post-cull idle capacity shrank it.
    assert!(sim.stats.scale_ups >= 1, "never scaled up: {:?}", sim.stats);
    assert!(sim.stats.scale_downs >= 1, "never scaled down: {:?}", sim.stats);
    assert!(sim.stats.stopped_early > 0, "ASHA culled nothing");

    let pool = run(ExecMode::Pool { workers: 1 });
    assert_eq!(fingerprint(&pool), fingerprint(&sim), "sim/pool fingerprints diverge");
    assert_eq!(pool.best, sim.best, "best trial differs");
    assert_eq!(
        pool.best_metric().map(f64::to_bits),
        sim.best_metric().map(f64::to_bits),
        "best metric bits differ"
    );
    // The autoscale/preemption trajectory is part of the determinism
    // contract too.
    assert_eq!(pool.stats.preemptions, sim.stats.preemptions);
    assert_eq!(pool.stats.scale_ups, sim.stats.scale_ups);
    assert_eq!(pool.stats.scale_downs, sim.stats.scale_downs);
}

/// The scaled cluster survives the durable snapshot: resuming an
/// autoscaled run restores the node set the run actually ended on
/// (grown/retired shape included), not the initial RunOptions cluster.
#[test]
fn autoscaled_cluster_shape_survives_resume() {
    let dir = tmpdir("autoscale");
    let policy = AutoscalePolicy {
        node_template: Resources::cpu(4.0),
        templates: Vec::new(),
        min_nodes: 0,
        max_nodes: 2,
        scale_up_after: 2,
        scale_down_after: 10,
        scale_down_util: 0.8,
    };
    let mk_spec = || {
        let mut sp = spec("autoscale-durable", 3, 12, 6);
        sp.resources_per_trial = Resources::cpu(1.0);
        sp
    };
    let opts = |resume: bool| RunOptions {
        cluster: Cluster::uniform(2, Resources::cpu(4.0)),
        autoscale: Some(policy.clone()),
        experiment_dir: Some(dir.clone()),
        snapshot_every: 5,
        resume,
        ..Default::default()
    };
    let res = run_experiments(
        mk_spec(),
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        opts(false),
    );
    assert_eq!(res.count(TrialStatus::Completed), 3);
    assert!(res.stats.scale_ups >= 1 && res.stats.scale_downs >= 1, "{:?}", res.stats);
    let runner = build_runner(
        mk_spec(),
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        opts(true),
    );
    // The restored cluster matches the run's final shape, not the
    // 2-node constructor cluster the drains retired from.
    assert_eq!(
        runner.utilization().nodes_alive,
        res.final_utilization.nodes_alive,
        "resume reset the autoscaled cluster"
    );
    assert_eq!(runner.trials().len(), 3);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Demands survive snapshot / resume
// ---------------------------------------------------------------------------

/// Fractional + custom resource demands round-trip through the durable
/// snapshot: a resumed runner's trial table carries the exact vectors.
#[test]
fn resource_demands_survive_snapshot_and_resume() {
    let dir = tmpdir("demands");
    let demand = Resources::cpu_gpu(0.5, 0.25).with_custom("tpu", 1.0);
    let mk_spec = || {
        let mut sp = spec("demand-durable", 4, 6, 8);
        sp.resources_per_trial = demand.clone();
        sp
    };
    let cluster = || {
        Cluster::uniform(1, Resources::cpu_gpu(4.0, 2.0).with_custom("tpu", 8.0))
    };
    let res = run_experiments(
        mk_spec(),
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions {
            cluster: cluster(),
            experiment_dir: Some(dir.clone()),
            snapshot_every: 5,
            ..Default::default()
        },
    );
    assert_eq!(res.count(TrialStatus::Completed), 4);
    for t in res.trials.values() {
        assert_eq!(t.resources, demand);
    }
    // Resume the finished experiment: the restored table must carry the
    // same demand vectors (EPS-aware equality).
    let runner = build_runner(
        mk_spec(),
        curve_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        uniform_curve_factory(),
        RunOptions {
            cluster: cluster(),
            experiment_dir: Some(dir.clone()),
            resume: true,
            ..Default::default()
        },
    );
    assert_eq!(runner.trials().len(), 4);
    for t in runner.trials().values() {
        assert_eq!(t.resources, demand, "restored demand drifted for trial {}", t.id);
    }
    std::fs::remove_dir_all(&dir).ok();
}
