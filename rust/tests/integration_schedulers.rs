//! End-to-end scheduler behaviour over the full runner + sim executor +
//! ray substrate — the C1/C2 claims of DESIGN.md as assertions.

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
    TrialStatus,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::{CurveTrainable, NonStationaryTrainable};

fn curve_spec(name: &str, samples: usize, iters: u64, seed: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = seed;
    spec
}

fn curve_space() -> tune::coordinator::spec::SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build()
}

fn run_sched(kind: SchedulerKind, samples: usize, iters: u64, seed: u64) -> tune::coordinator::ExperimentResult {
    run_experiments(
        curve_spec(kind.label(), samples, iters, seed),
        curve_space(),
        kind,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(8.0)),
            ..Default::default()
        },
    )
}

/// C1: at matched trial count, early-stopping schedulers must reach
/// within 5% of FIFO's best accuracy using far less training budget
/// (HyperBand trades a little terminal quality for the largest budget
/// saving, as in the original paper).
#[test]
fn early_stoppers_save_budget_without_losing_quality() {
    let fifo = run_sched(SchedulerKind::Fifo, 64, 81, 7);
    for kind in [
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 81 },
        SchedulerKind::HyperBand { max_t: 81, eta: 3.0 },
        SchedulerKind::MedianStopping { grace_period: 8, min_samples: 3 },
    ] {
        let label = kind.label();
        let res = run_sched(kind, 64, 81, 7);
        let quality_gap = fifo.best_metric().unwrap() - res.best_metric().unwrap();
        assert!(quality_gap < 0.05, "{label}: gap {quality_gap}");
        assert!(
            res.budget_used_s < fifo.budget_used_s * 0.65,
            "{label}: budget {} vs fifo {}",
            res.budget_used_s,
            fifo.budget_used_s
        );
        assert!(res.stats.stopped_early > 0, "{label} never stopped a trial");
    }
}

/// ASHA should stop the majority of bad trials at low rungs.
#[test]
fn asha_kills_bad_trials_early() {
    let res = run_sched(
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 81 },
        96,
        81,
        3,
    );
    let stopped = res.count(TrialStatus::Stopped);
    assert!(stopped > 48, "only {stopped} stopped");
    // Stopped trials should on average have consumed far less than max_t.
    let mean_iter: f64 = res
        .trials
        .values()
        .filter(|t| t.status == TrialStatus::Stopped)
        .map(|t| t.iteration as f64)
        .sum::<f64>()
        / stopped as f64;
    assert!(mean_iter < 20.0, "mean stopped iteration {mean_iter}");
}

/// HyperBand's pause/resume machinery: paused trials must resume (the
/// checkpoint+restore path) and the experiment must terminate cleanly.
#[test]
fn hyperband_pauses_and_resumes_via_checkpoints() {
    let res = run_sched(SchedulerKind::HyperBand { max_t: 27, eta: 3.0 }, 40, 27, 1);
    assert!(res.stats.checkpoints > 0);
    assert!(res.stats.restores > 0, "no paused trial ever resumed");
    // No trial left non-terminal.
    for t in res.trials.values() {
        assert!(t.status.is_terminal(), "trial {} in {:?}", t.id, t.status);
    }
    // Some trials must have trained past the first rung.
    assert!(res.trials.values().any(|t| t.iteration >= 9));
}

/// C2: on the non-stationary objective PBT must beat random search at
/// the same budget, and must actually exploit/mutate.
#[test]
fn pbt_beats_static_configs_on_nonstationary_objective() {
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 0.5).build();
    let mut spec = ExperimentSpec::named("pbt");
    spec.metric = "score".into();
    spec.mode = Mode::Max;
    spec.num_samples = 16;
    spec.max_iterations_per_trial = 120;
    spec.seed = 5;
    let run = |kind: SchedulerKind| {
        run_experiments(
            spec.clone(),
            space.clone(),
            kind,
            SearchKind::Random,
            factory(|c, s| Box::new(NonStationaryTrainable::new(c, s))),
            RunOptions {
                cluster: Cluster::uniform(2, Resources::cpu(8.0)),
                ..Default::default()
            },
        )
    };
    let pbt = run(SchedulerKind::Pbt { perturbation_interval: 10, space: space.clone() });
    let random = run(SchedulerKind::Fifo);
    assert!(pbt.stats.exploits > 0, "PBT never exploited");
    let pbt_best = pbt.best_metric().unwrap();
    let rnd_best = random.best_metric().unwrap();
    assert!(
        pbt_best > rnd_best * 1.15,
        "pbt {pbt_best} vs random {rnd_best}"
    );
    // Mutation lineage is recorded.
    assert!(pbt.trials.values().any(|t| t.mutations > 0));
}

/// TPE should find a better config than random search on a smooth
/// objective at equal trial count.
#[test]
fn tpe_beats_random_on_smooth_objective() {
    let mk = |search: SearchKind, seed: u64| {
        run_experiments(
            curve_spec("tpe-vs-random", 60, 30, seed),
            curve_space(),
            SchedulerKind::Fifo,
            search,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            RunOptions {
                cluster: Cluster::uniform(1, Resources::cpu(4.0)),
                ..Default::default()
            },
        )
    };
    // Compare mean final asymptote quality proxy: mean best over trials.
    let mean_best = |r: &tune::coordinator::ExperimentResult| {
        let v: Vec<f64> = r.trials.values().filter_map(|t| t.best_metric).collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    let mut tpe_wins = 0;
    for seed in [1, 2, 3] {
        let tpe = mk(SearchKind::Tpe, seed);
        let rnd = mk(SearchKind::Random, seed);
        if mean_best(&tpe) > mean_best(&rnd) {
            tpe_wins += 1;
        }
    }
    assert!(tpe_wins >= 2, "TPE won only {tpe_wins}/3 seeds");
}

/// The bounded pool executor: a 64-trial ASHA experiment on 4 workers.
/// Every trial is live concurrently (the cluster has capacity for all of
/// them) but only 4 OS threads ever run trainables — M >> N. The run
/// must terminate cleanly with ASHA culling bad trials, checkpoints
/// flowing through the pool's synchronous save path.
#[test]
fn asha_on_pool_executor_64_trials_4_workers() {
    let mut spec = curve_spec("asha-pool", 64, 27, 9);
    spec.checkpoint_freq = 5;
    let res = run_experiments(
        spec,
        curve_space(),
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 27 },
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            // 128 cpus: all 64 trials admitted at once; the pool's 4
            // workers are the only execution threads.
            cluster: Cluster::uniform(8, Resources::cpu(16.0)),
            exec: ExecMode::Pool { workers: 4 },
            ..Default::default()
        },
    );
    assert_eq!(res.trials.len(), 64);
    for t in res.trials.values() {
        assert!(t.status.is_terminal(), "trial {} stuck in {:?}", t.id, t.status);
    }
    assert!(res.stats.stopped_early > 0, "ASHA stopped nothing on the pool");
    assert!(res.stats.checkpoints > 0, "no checkpoint flowed through the pool");
    assert!(res.best_metric().unwrap() > 0.5, "best {:?}", res.best_metric());
    // Wall-clock executor: duration is real seconds, not virtual budget.
    assert!(res.duration_s > 0.0);
}

/// Pool and thread executors agree on experiment outcomes (same trials,
/// same per-trial iteration counts) for a deterministic FIFO workload.
#[test]
fn pool_matches_threads_on_fifo_outcomes() {
    let run = |exec: ExecMode| {
        let spec = curve_spec("pool-parity", 12, 10, 4);
        run_experiments(
            spec,
            curve_space(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            RunOptions {
                cluster: Cluster::uniform(2, Resources::cpu(8.0)),
                exec,
                ..Default::default()
            },
        )
    };
    let pool = run(ExecMode::Pool { workers: 3 });
    let threads = run(ExecMode::Threads);
    assert_eq!(pool.trials.len(), threads.trials.len());
    assert_eq!(pool.count(TrialStatus::Completed), threads.count(TrialStatus::Completed));
    for (a, b) in pool.trials.values().zip(threads.trials.values()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.iteration, b.iteration);
        assert_eq!(a.config, b.config);
    }
}

/// Determinism: the same seed must produce the identical experiment.
#[test]
fn experiments_replay_bit_identically() {
    let a = run_sched(SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 27 }, 24, 27, 11);
    let b = run_sched(SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 27 }, 24, 27, 11);
    assert_eq!(a.trials.len(), b.trials.len());
    assert_eq!(a.best, b.best);
    assert_eq!(a.best_metric(), b.best_metric());
    assert_eq!(a.stats.results, b.stats.results);
    assert!((a.duration_s - b.duration_s).abs() < 1e-9);
    for (x, y) in a.trials.values().zip(b.trials.values()) {
        assert_eq!(x.iteration, y.iteration);
        assert_eq!(x.status, y.status);
    }
}

// ---------------------------------------------------------------------------
// Conformance matrix: every scheduler × search algorithm × executor
// ---------------------------------------------------------------------------

/// A stable, clock-free fingerprint of an experiment outcome: one line
/// per trial (id, status, iterations, mutations, config, best-metric
/// bits). Times are deliberately excluded — sim reports virtual
/// seconds, pool/threads report wall seconds — so byte-identical
/// fingerprints mean the *semantics* matched across substrates.
fn fingerprint(res: &tune::coordinator::ExperimentResult) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    for t in res.trials.values() {
        writeln!(
            out,
            "{}|{}|{}|{}|{}|{}",
            t.id,
            t.status.as_str(),
            t.iteration,
            t.mutations,
            tune::coordinator::trial::config_str(&t.config),
            t.best_metric.map(f64::to_bits).unwrap_or(0),
        )
        .unwrap();
    }
    writeln!(
        out,
        "best={:?} best_bits={}",
        res.best,
        res.best_metric().map(f64::to_bits).unwrap_or(0)
    )
    .unwrap();
    out
}

/// One conformance cell: a small-budget experiment under the given
/// scheduler/search/executor. `max_concurrent = 1` serializes execution,
/// which makes the event stream — and therefore every scheduler and
/// search decision — identical on all three substrates, turning the
/// fingerprint comparison into a strict executor-transparency check.
fn conformance_run(
    sched: SchedulerKind,
    search: SearchKind,
    exec: ExecMode,
) -> tune::coordinator::ExperimentResult {
    let mut spec = curve_spec("conformance", 4, 8, 13);
    spec.max_concurrent = 1;
    spec.checkpoint_freq = 3; // exercise save/restore on every substrate
    let space = SpaceBuilder::new()
        .grid_f64("lr", &[0.02, 0.001])
        .uniform("momentum", 0.8, 0.99)
        .build();
    run_experiments(
        spec,
        space,
        sched,
        search,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(2, Resources::cpu(4.0)),
            exec,
            ..Default::default()
        },
    )
}

/// The scheduler × search × executor conformance matrix (5 × 4 × 3):
/// every combination must terminate with every trial in a terminal
/// state, produce identical trial counts on every executor, and produce
/// byte-identical fingerprints on sim, threads and pool — the narrow
/// waist's promise that scheduling research results transfer to real
/// execution. Writes the fingerprint table to `$CONFORMANCE_FP_OUT`
/// when set (CI uploads it as an artifact).
#[test]
fn conformance_matrix_scheduler_x_search_x_executor() {
    let space_for_pbt = SpaceBuilder::new()
        .grid_f64("lr", &[0.02, 0.001])
        .uniform("momentum", 0.8, 0.99)
        .build();
    let schedulers: Vec<(&str, SchedulerKind)> = vec![
        ("fifo", SchedulerKind::Fifo),
        (
            "asha",
            SchedulerKind::Asha { grace_period: 1, reduction_factor: 2.0, max_t: 8 },
        ),
        ("hyperband", SchedulerKind::HyperBand { max_t: 8, eta: 2.0 }),
        (
            "median",
            SchedulerKind::MedianStopping { grace_period: 2, min_samples: 2 },
        ),
        (
            "pbt",
            SchedulerKind::Pbt { perturbation_interval: 3, space: space_for_pbt },
        ),
    ];
    let searches: Vec<(&str, SearchKind)> = vec![
        ("grid", SearchKind::Grid),
        ("random", SearchKind::Random),
        ("tpe", SearchKind::Tpe),
        ("evolution", SearchKind::Evolution),
    ];
    let execs: Vec<(&str, ExecMode)> = vec![
        ("sim", ExecMode::Sim),
        ("threads", ExecMode::Threads),
        ("pool", ExecMode::Pool { workers: 2 }),
    ];

    let mut report = String::new();
    for (s_name, sched) in &schedulers {
        for (q_name, search) in &searches {
            let mut prints: Vec<(&str, usize, String)> = Vec::new();
            for (e_name, exec) in &execs {
                let res = conformance_run(sched.clone(), search.clone(), *exec);
                assert!(
                    !res.trials.is_empty(),
                    "{s_name}×{q_name}×{e_name}: no trials ran"
                );
                for t in res.trials.values() {
                    assert!(
                        t.status.is_terminal(),
                        "{s_name}×{q_name}×{e_name}: trial {} stuck in {:?}",
                        t.id,
                        t.status
                    );
                }
                assert_eq!(
                    res.count(TrialStatus::Errored),
                    0,
                    "{s_name}×{q_name}×{e_name}: errored trials"
                );
                prints.push((*e_name, res.trials.len(), fingerprint(&res)));
            }
            // Invariant trial counts across executors...
            let counts: Vec<usize> = prints.iter().map(|(_, n, _)| *n).collect();
            assert!(
                counts.windows(2).all(|w| w[0] == w[1]),
                "{s_name}×{q_name}: trial counts differ across executors: {counts:?}"
            );
            // ...and byte-identical fingerprints (sim vs pool vs threads).
            for (e_name, _, fp) in &prints[1..] {
                assert_eq!(
                    fp, &prints[0].2,
                    "{s_name}×{q_name}: {} fingerprint diverges from {}",
                    e_name, prints[0].0
                );
            }
            report.push_str(&format!(
                "=== {s_name} x {q_name} ({} trials) ===\n{}",
                counts[0], prints[0].2
            ));
        }
    }
    if let Ok(path) = std::env::var("CONFORMANCE_FP_OUT") {
        std::fs::write(&path, &report).expect("write conformance fingerprint artifact");
    }
}

/// Grid search + §4.3's quickstart space: exactly 6 trials, all complete.
#[test]
fn quickstart_grid_runs_six_trials() {
    let mut spec = curve_spec("quickstart", 1, 20, 0);
    spec.checkpoint_at_end = true;
    let space = SpaceBuilder::new()
        .grid_f64("lr", &[0.01, 0.001, 0.0001])
        .grid_str("activation", &["relu", "tanh"])
        .build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Grid,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions::default(),
    );
    assert_eq!(res.trials.len(), 6);
    assert_eq!(res.count(TrialStatus::Completed), 6);
    assert!(res.stats.checkpoints >= 6); // final checkpoints
}
