//! ExperimentHub integration suite: concurrent multi-experiment serving
//! over one shared pool, with the isolation proof (hub results are
//! byte-identical to solo runs), fault-recovery-under-quota regression,
//! panic containment at the experiment level, and a `serve`/`submit`/
//! `status` CLI smoke test.

use tune::coordinator::hub::{ExperimentHub, Submission};
use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::trial::config_str;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentResult, ExperimentSpec, Mode, RunOptions, SchedulerKind,
    SearchKind, TrialStatus,
};
use tune::ray::FaultPlan;
use tune::trainable::synthetic::CurveTrainable;
use tune::trainable::{factory, StepOutput, Trainable, TrainableFactory};

fn curve_factory() -> TrainableFactory {
    factory(|c, s| Box::new(CurveTrainable::new(c, s)))
}

fn curve_spec(name: &str, seed: u64, samples: usize, iters: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = seed;
    spec
}

fn lr_space() -> tune::coordinator::spec::SearchSpace {
    SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build()
}

/// Canonical, timing-free serialization of an experiment's outcome:
/// per trial its config, iteration count, terminal status and the exact
/// bits of its best metric. Two runs with identical trial streams
/// produce identical strings, byte for byte.
fn fingerprint(res: &ExperimentResult) -> String {
    let mut out = String::new();
    for t in res.trials.values() {
        out.push_str(&format!(
            "{}|{}|{}|{}|{}\n",
            t.id,
            config_str(&t.config),
            t.iteration,
            t.status.as_str(),
            t.best_metric.map(|v| format!("{:016x}", v.to_bits())).unwrap_or_else(|| "-".into()),
        ));
    }
    out.push_str(&format!(
        "best={:?} completed={}\n",
        res.best,
        res.count(TrialStatus::Completed)
    ));
    out
}

#[test]
fn three_concurrent_experiments_match_solo_runs_byte_for_byte() {
    // The isolation proof: 3 experiments multiplexed over one 4-worker
    // pool must produce results byte-identical to running each
    // experiment alone (same seeds) on its own pool. Per-experiment RNG
    // streams, trial tables and clusters may share nothing.
    let seeds = [11u64, 22, 33];
    let solo: Vec<String> = seeds
        .iter()
        .map(|&seed| {
            let res = run_experiments(
                curve_spec(&format!("iso-{seed}"), seed, 6, 12),
                lr_space(),
                SchedulerKind::Fifo,
                SearchKind::Random,
                curve_factory(),
                RunOptions {
                    exec: ExecMode::Pool { workers: 4 },
                    ..Default::default()
                },
            );
            fingerprint(&res)
        })
        .collect();

    let mut hub = ExperimentHub::new(4, 0);
    for &seed in &seeds {
        hub.submit(Submission::new(
            curve_spec(&format!("iso-{seed}"), seed, 6, 12),
            lr_space(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            curve_factory(),
        ))
        .unwrap();
    }
    let results = hub.run_all();
    assert_eq!(results.len(), 3);
    for (i, (name, res)) in results.iter().enumerate() {
        assert_eq!(name, &format!("iso-{}", seeds[i]));
        assert_eq!(
            fingerprint(res),
            solo[i],
            "experiment {name} diverged from its solo run"
        );
    }
}

#[test]
fn fault_recovery_cannot_deadlock_exhausted_quotas() {
    // Regression (hub admission vs `handle_failure` relaunch): 3
    // experiments on a 2-worker pool with a global budget of 3 slots —
    // every experiment's fair share is exactly 1, so each fault-recovery
    // relaunch competes with fresh admissions for the experiment's only
    // slot. Flaky steps + checkpoints must still drive every trial to
    // completion; a deadlock would hang the run (and the harness).
    let mut hub = ExperimentHub::new(2, 3);
    for seed in 0..3u64 {
        let mut spec = curve_spec(&format!("flaky-{seed}"), seed, 3, 15);
        spec.fault_plan = FaultPlan::flaky_steps(0.05);
        spec.checkpoint_freq = 3;
        spec.max_failures = 100;
        hub.submit(Submission::new(
            spec,
            lr_space(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            curve_factory(),
        ))
        .unwrap();
    }
    let results = hub.run_all();
    assert_eq!(results.len(), 3);
    let mut recovered = 0;
    for (name, res) in &results {
        assert_eq!(
            res.count(TrialStatus::Completed),
            3,
            "{name}: {:?}",
            res.stats
        );
        recovered += res.stats.failures_recovered;
    }
    // 135 injected-fault coin flips at 5%: recovery definitely fired.
    assert!(recovered > 0);
}

/// Panics deterministically every time it steps *to* iteration
/// `panic_at` (so a checkpoint-restored incarnation panics again) —
/// drives the permanent-failure path through `max_failures`.
struct PanicAt {
    t: u64,
    panic_at: u64,
}

impl Trainable for PanicAt {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.t += 1;
        if self.panic_at > 0 && self.t == self.panic_at {
            panic!("deterministic panic at iteration {}", self.t);
        }
        Ok(StepOutput::of(&[("accuracy", self.t as f64 / 100.0)]))
    }
    fn save(&mut self) -> Vec<u8> {
        self.t.to_le_bytes().to_vec()
    }
    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        self.t = u64::from_le_bytes(blob.try_into().map_err(|_| "bad blob")?);
        Ok(())
    }
}

#[test]
fn panicking_trainable_errors_out_without_killing_the_experiment() {
    // 2 healthy + 2 permanently-panicking trials on the pool: the
    // panicking ones exhaust max_failures and error out; the healthy
    // ones (and the coordinator, and the pool mutex) survive.
    let fac: TrainableFactory = factory(|c, _s| {
        let panic_at = c.get("panic_at").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        Box::new(PanicAt { t: 0, panic_at })
    });
    let mut spec = curve_spec("panic-mix", 5, 2, 8);
    spec.max_failures = 2;
    spec.checkpoint_freq = 3;
    let space = SpaceBuilder::new().grid_f64("panic_at", &[0.0, 4.0]).build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Grid,
        fac,
        RunOptions {
            exec: ExecMode::Pool { workers: 2 },
            ..Default::default()
        },
    );
    assert_eq!(res.trials.len(), 4); // 2 passes x 2 grid values
    assert_eq!(res.count(TrialStatus::Errored), 2, "{:?}", res.stats);
    assert_eq!(res.count(TrialStatus::Completed), 2);
    assert!(res.best_metric().is_some());
}

#[test]
fn hub_experiments_keep_isolated_durable_dirs() {
    let root = std::env::temp_dir().join(format!("tune_hub_dirs_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let mut hub = ExperimentHub::new(2, 4);
    for seed in 0..2u64 {
        let name = format!("durable-{seed}");
        let mut sub = Submission::new(
            curve_spec(&name, seed, 3, 6),
            lr_space(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            curve_factory(),
        );
        sub.experiment_dir = Some(root.join(&name));
        sub.snapshot_every = 5;
        hub.submit(sub).unwrap();
    }
    let results = hub.run_all();
    assert_eq!(results.len(), 2);
    for seed in 0..2u64 {
        let dir = root.join(format!("durable-{seed}"));
        assert!(dir.join("experiment.meta.json").exists(), "{dir:?}");
        assert!(dir.join("snapshot.json").exists(), "{dir:?}");
        assert!(dir.join("experiment.json").exists(), "{dir:?}");
        // Each experiment logged exactly its own 3 trials.
        let logs = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| {
                let n = e.file_name();
                let n = n.to_string_lossy().into_owned();
                n.starts_with("trial_") && n.ends_with(".jsonl")
            })
            .count();
        assert_eq!(logs, 3);
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn serve_submit_status_cli_smoke() {
    use std::process::Command;
    let tune = env!("CARGO_BIN_EXE_tune");
    let root = std::env::temp_dir().join(format!("tune_serve_smoke_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let spec_path = root.join("smoke-a.json");
    std::fs::write(
        &spec_path,
        r#"{
            "name": "smoke-a", "metric": "accuracy", "mode": "max",
            "num_samples": 4, "max_iterations_per_trial": 5, "seed": 3,
            "workload": "curve", "scheduler": "fifo", "search": "random",
            "weight": 2,
            "space": {"lr": {"loguniform": [1e-4, 1.0]}},
            "cluster": {"nodes": 1, "cpus_per_node": 8}
        }"#,
    )
    .unwrap();
    let exp_dir = root.join("server");

    // submit: validates the spec and queues it.
    let out = Command::new(tune)
        .args(["submit", "--exp-dir"])
        .arg(&exp_dir)
        .arg("--spec")
        .arg(&spec_path)
        .output()
        .expect("run tune submit");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(exp_dir.join("queue/smoke-a.json").exists());

    // serve --drain: ingests the queue, runs it over the shared pool,
    // publishes status, exits when drained.
    let out = Command::new(tune)
        .args(["serve", "--workers", "2", "--drain", "--exp-dir"])
        .arg(&exp_dir)
        .output()
        .expect("run tune serve");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(!exp_dir.join("queue/smoke-a.json").exists(), "queue not drained");
    let exp_out = exp_dir.join("experiments/smoke-a");
    assert!(exp_out.join("experiment.json").exists(), "no results at {exp_out:?}");
    assert!(exp_out.join("snapshot.json").exists());

    // status: prints the published table.
    let out = Command::new(tune)
        .args(["status", "--exp-dir"])
        .arg(&exp_dir)
        .output()
        .expect("run tune status");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("smoke-a"), "{stdout}");
    assert!(stdout.contains("finished"), "{stdout}");

    // stop: drops the stop marker for a (hypothetical) live server.
    let out = Command::new(tune)
        .args(["stop", "--exp-dir"])
        .arg(&exp_dir)
        .output()
        .expect("run tune stop");
    assert!(out.status.success());
    assert!(exp_dir.join("serve.stop").exists());

    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn weighted_shares_let_heavy_experiments_hold_more_slots() {
    // Not a strict scheduling assertion (wall-clock pool), but the
    // fair-share math is deterministic: run a heavy (weight 3) and a
    // light (weight 1) experiment over a 4-slot budget and check both
    // finish with full trial tables — the heavy one must not starve the
    // light one despite owning 3 of 4 slots.
    let mut hub = ExperimentHub::new(2, 4);
    let mut heavy = Submission::new(
        curve_spec("heavy", 1, 6, 8),
        lr_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        curve_factory(),
    );
    heavy.weight = 3;
    hub.submit(heavy).unwrap();
    hub.submit(Submission::new(
        curve_spec("light", 2, 6, 8),
        lr_space(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        curve_factory(),
    ))
    .unwrap();
    let results = hub.run_all();
    assert_eq!(results.len(), 2);
    for (name, res) in &results {
        assert_eq!(res.trials.len(), 6, "{name}");
        assert_eq!(res.count(TrialStatus::Completed), 6, "{name}");
    }
    assert!(hub.mean_occupancy() > 0.0);
}
