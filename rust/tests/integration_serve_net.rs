//! Network control-plane integration suite: frame abuse against a live
//! server, slow-watcher shedding under the backpressure cap, concurrent
//! same-name admission, graceful drain, the sharded-vs-solo isolation
//! proof (extending the PR-3 byte-identity check to `ShardedHub`), and
//! a CLI end-to-end run over a real Unix socket.

use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tune::coordinator::hub::Submission;
use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::trial::config_str;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentResult, ExperimentSpec, Mode, RunOptions, SchedulerKind,
    SearchKind, TrialStatus,
};
use tune::net::protocol::{frame_bytes, read_frame, NetStream, MAX_FRAME_BYTES};
use tune::net::{
    serve, shard_of, Client, ListenAddr, ServeOptions, ShardedHub, ShardedHubOptions,
    WorkloadResolver,
};
use tune::trainable::synthetic::CurveTrainable;
use tune::trainable::{factory, TrainableFactory};
use tune::util::json::Json;

fn curve_factory() -> TrainableFactory {
    factory(|c, s| Box::new(CurveTrainable::new(c, s)))
}

/// The workload table a test server resolves against: `curve` only.
fn curve_resolver() -> WorkloadResolver {
    Arc::new(|w: &str| {
        if w == "curve" {
            Ok(factory(|c, s| Box::new(CurveTrainable::new(c, s))))
        } else {
            Err(format!("unknown workload {w:?}"))
        }
    })
}

fn curve_spec(name: &str, seed: u64, samples: usize, iters: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = seed;
    spec
}

fn lr_space() -> tune::coordinator::spec::SearchSpace {
    SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build()
}

/// Spec-file text as a client would send it over the `submit` verb.
fn spec_text(name: &str, seed: u64, samples: usize, iters: u64) -> String {
    format!(
        r#"{{
            "name": "{name}", "metric": "accuracy", "mode": "max",
            "num_samples": {samples}, "max_iterations_per_trial": {iters}, "seed": {seed},
            "workload": "curve", "scheduler": "fifo", "search": "random",
            "space": {{"lr": {{"loguniform": [1e-4, 1.0]}}}},
            "cluster": {{"nodes": 1, "cpus_per_node": 8}}
        }}"#
    )
}

/// Canonical, timing-free serialization of an experiment's outcome
/// (same shape as the PR-3 hub isolation proof): per trial its config,
/// iteration count, terminal status and the exact bits of its best
/// metric.
fn fingerprint(res: &ExperimentResult) -> String {
    let mut out = String::new();
    for t in res.trials.values() {
        out.push_str(&format!(
            "{}|{}|{}|{}|{}\n",
            t.id,
            config_str(&t.config),
            t.iteration,
            t.status.as_str(),
            t.best_metric.map(|v| format!("{:016x}", v.to_bits())).unwrap_or_else(|| "-".into()),
        ));
    }
    out.push_str(&format!(
        "best={:?} completed={}\n",
        res.best,
        res.count(TrialStatus::Completed)
    ));
    out
}

/// Boot an in-process server on an ephemeral TCP port.
fn serve_curve(opts: ShardedHubOptions, serve_opts: ServeOptions) -> tune::net::ServerHandle {
    let hub = ShardedHub::new(opts);
    let addr = ListenAddr::parse("127.0.0.1:0").unwrap();
    serve(&addr, hub, curve_resolver(), serve_opts).unwrap()
}

#[test]
fn frame_abuse_gets_error_replies_without_killing_the_server() {
    let handle = serve_curve(
        ShardedHubOptions { shards: 1, workers: 2, ..Default::default() },
        ServeOptions::default(),
    );
    let addr = handle.addr().clone();

    // Garbage body inside an intact frame: error reply, connection kept.
    let mut s = NetStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let body = b"not json at all";
    s.write_all(&(body.len() as u32).to_be_bytes()).unwrap();
    s.write_all(body).unwrap();
    let reply = read_frame(&mut s, MAX_FRAME_BYTES).unwrap().unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
    // The same connection still serves well-formed requests.
    s.write_all(&frame_bytes(&Json::obj(vec![("verb", Json::Str("ping".into()))]))).unwrap();
    let reply = read_frame(&mut s, MAX_FRAME_BYTES).unwrap().unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));

    // Oversized length header: error reply, then the server closes the
    // connection (the unread body makes the stream unresynchronizable).
    let mut s = NetStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&(MAX_FRAME_BYTES as u32 + 1).to_be_bytes()).unwrap();
    let reply = read_frame(&mut s, MAX_FRAME_BYTES).unwrap().unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
    match read_frame(&mut s, MAX_FRAME_BYTES) {
        Ok(None) | Err(_) => {} // closed, as promised
        Ok(Some(f)) => panic!("expected close after oversized frame, got {f}"),
    }

    // Torn frame: half a length header, then hang up. Dropped silently.
    let mut s = NetStream::connect(&addr).unwrap();
    s.write_all(&[0u8, 0]).unwrap();
    drop(s);

    assert_eq!(handle.stats().protocol_errors.load(Ordering::Relaxed), 2);
    // A fresh client still gets service after all of the above.
    let mut c = Client::connect(&addr).unwrap();
    c.ping().unwrap();
    handle.shutdown(false);
    handle.join();
}

#[test]
fn slow_watcher_is_shed_while_request_service_survives() {
    let handle = serve_curve(
        ShardedHubOptions { shards: 1, workers: 2, ..Default::default() },
        // Tiny cap: the very first status delta exceeds it, so a watcher
        // that neither reads nor acks is shed almost immediately.
        ServeOptions { watch_cap_bytes: 64, ..Default::default() },
    );
    let addr = handle.addr().clone();

    // A watcher that never reads its stream and never acks.
    let mut lazy = NetStream::connect(&addr).unwrap();
    lazy.write_all(&frame_bytes(&Json::obj(vec![("verb", Json::Str("watch".into()))]))).unwrap();

    // Churn keeps the per-shard status (and thus the deltas) moving.
    let mut c = Client::connect(&addr).unwrap();
    c.submit_spec_text(&spec_text("churn", 1, 4, 30)).unwrap();

    let deadline = Instant::now() + Duration::from_secs(10);
    while handle.stats().watch_shed.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "watcher never shed");
        std::thread::sleep(Duration::from_millis(20));
    }
    // Shedding the watch stream must not degrade request/reply service.
    c.ping().unwrap();
    c.status().unwrap();
    drop(lazy);
    c.stop(true).unwrap();
    handle.join();
}

#[test]
fn concurrent_same_name_submissions_admit_exactly_one() {
    let handle = serve_curve(
        ShardedHubOptions { shards: 4, workers: 2, ..Default::default() },
        ServeOptions::default(),
    );
    let addr = handle.addr().clone();
    let text = spec_text("dup", 9, 3, 6);
    let joins: Vec<_> = (0..8)
        .map(|_| {
            let addr = addr.clone();
            let text = text.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.submit_spec_text(&text)
            })
        })
        .collect();
    let verdicts: Vec<Result<String, String>> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    let admitted = verdicts.iter().filter(|v| v.is_ok()).count();
    assert_eq!(admitted, 1, "verdicts: {verdicts:?}");
    assert_eq!(handle.stats().submits_ok.load(Ordering::Relaxed), 1);
    assert_eq!(handle.stats().submits_rejected.load(Ordering::Relaxed), 7);
    handle.shutdown(true);
    let results = handle.join();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].0, "dup");
}

#[test]
fn drain_completes_in_flight_experiments_and_watchers_get_bye() {
    let root = std::env::temp_dir().join(format!("tune_net_drain_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let handle = serve_curve(
        ShardedHubOptions {
            shards: 2,
            workers: 2,
            root: Some(root.clone()),
            snapshot_every: 5,
            ..Default::default()
        },
        ServeOptions::default(),
    );
    let addr = handle.addr().clone();

    // A well-behaved (acking) watcher, attached before any submission.
    let events = Arc::new(AtomicUsize::new(0));
    let ev = Arc::clone(&events);
    let watch_conn = Client::connect(&addr).unwrap();
    let watcher = std::thread::spawn(move || {
        watch_conn.watch(|_| {
            ev.fetch_add(1, Ordering::SeqCst);
            true
        })
    });

    let mut c = Client::connect(&addr).unwrap();
    let name = c.submit_spec_text(&spec_text("drain-a", 7, 4, 10)).unwrap();
    assert_eq!(name, "drain-a");
    // Stop with drain while the experiment is in flight: it must still
    // run to completion before the server retires.
    c.stop(true).unwrap();
    let results = handle.join();
    assert_eq!(results.len(), 1);
    let (rname, res) = &results[0];
    assert_eq!(rname, "drain-a");
    assert_eq!(res.count(TrialStatus::Completed), 4, "{:?}", res.stats);

    // Durable output landed in the owning shard's directory.
    let k = shard_of("drain-a", 2);
    let dir = root.join("shards").join(k.to_string()).join("experiments").join("drain-a");
    assert!(dir.join("experiment.json").exists(), "no results at {dir:?}");
    assert!(dir.join("snapshot.json").exists(), "{dir:?}");

    // The watcher saw status flow and then a clean bye (Ok return).
    watcher.join().unwrap().unwrap();
    assert!(events.load(Ordering::SeqCst) > 0, "watcher saw no status deltas");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn sharded_experiments_match_solo_runs_byte_for_byte() {
    // The PR-3 isolation proof, extended across shards: 3 experiments
    // hashed over 2 hub shards sharing ONE 4-worker fleet must produce
    // results byte-identical to running each alone on its own pool.
    let seeds = [11u64, 22, 33];
    let solo: Vec<String> = seeds
        .iter()
        .map(|&seed| {
            let res = run_experiments(
                curve_spec(&format!("iso-{seed}"), seed, 6, 12),
                lr_space(),
                SchedulerKind::Fifo,
                SearchKind::Random,
                curve_factory(),
                RunOptions { exec: ExecMode::Pool { workers: 4 }, ..Default::default() },
            );
            fingerprint(&res)
        })
        .collect();

    let hub = ShardedHub::new(ShardedHubOptions { shards: 2, workers: 4, ..Default::default() });
    for &seed in &seeds {
        hub.submit(Submission::new(
            curve_spec(&format!("iso-{seed}"), seed, 6, 12),
            lr_space(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            curve_factory(),
        ))
        .unwrap();
    }
    hub.stop(true);
    let results = hub.wait();
    assert_eq!(results.len(), 3);
    for (i, &seed) in seeds.iter().enumerate() {
        let name = format!("iso-{seed}");
        let res = results
            .iter()
            .find(|(n, _)| n == &name)
            .map(|(_, r)| r)
            .unwrap_or_else(|| panic!("missing experiment {name}"));
        assert_eq!(fingerprint(res), solo[i], "{name} diverged from its solo run");
    }
}

#[test]
fn serve_net_cli_end_to_end_over_unix_socket() {
    use std::process::Command;
    let tune = env!("CARGO_BIN_EXE_tune");
    let root = std::env::temp_dir().join(format!("tune_net_cli_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).unwrap();
    let addr = format!("unix:{}", root.join("ctl.sock").display());
    let spec_path = root.join("net-a.json");
    std::fs::write(&spec_path, spec_text("net-a", 3, 4, 5)).unwrap();
    let exp_dir = root.join("server");

    let mut server = Command::new(tune)
        .args(["serve", "--listen", &addr, "--shards", "2", "--workers", "2", "--exp-dir"])
        .arg(&exp_dir)
        .spawn()
        .expect("spawn tune serve --listen");

    // submit: retries its dial for ~2 s internally; loop a few times in
    // case the server binds slowly on a loaded CI machine.
    let mut admitted = false;
    for _ in 0..10 {
        let out = Command::new(tune)
            .args(["submit", "--addr", &addr, "--spec"])
            .arg(&spec_path)
            .output()
            .expect("run tune submit");
        if out.status.success() {
            admitted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    assert!(admitted, "submit never reached the server at {addr}");

    // status: the admitted experiment shows up in the sharded table.
    let mut seen = false;
    for _ in 0..25 {
        let out = Command::new(tune)
            .args(["status", "--addr", &addr])
            .output()
            .expect("run tune status");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        if String::from_utf8_lossy(&out.stdout).contains("net-a") {
            seen = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(seen, "status table never showed net-a");

    // stop (drain): the server finishes the experiment and exits 0.
    let out = Command::new(tune)
        .args(["stop", "--addr", &addr])
        .output()
        .expect("run tune stop");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let status = server.wait().expect("server exit");
    assert!(status.success());

    let k = shard_of("net-a", 2);
    let dir = exp_dir.join("shards").join(k.to_string()).join("experiments").join("net-a");
    assert!(dir.join("experiment.json").exists(), "no results at {dir:?}");
    std::fs::remove_dir_all(&root).ok();
}
