//! Fault injection against the content-addressed checkpoint store,
//! end to end through the runner: crash a PBT experiment mid-flight
//! with the chunk spill tier active, then resume — restored blobs must
//! be byte-identical to their pre-crash contents and the dedup ratio
//! must survive the round trip; a torn chunk file must degrade the
//! affected trials to replay-from-scratch instead of poisoning the
//! store or the run.

use std::collections::BTreeMap;
use std::path::PathBuf;

use tune::checkpoint::CheckpointStore;
use tune::coordinator::spec::{SearchSpace, SpaceBuilder};
use tune::coordinator::{
    build_runner, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
    TrialRunner,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

const SAMPLES: usize = 8;
const ITERS: u64 = 18;
const SEED: u64 = 11;

fn spec() -> ExperimentSpec {
    let mut spec = ExperimentSpec::named("ckpt-store-pbt");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = ITERS;
    spec.seed = SEED;
    spec.max_concurrent = 4;
    spec.checkpoint_freq = 2;
    spec
}

fn space() -> SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build()
}

fn scheduler() -> SchedulerKind {
    // PBT is the exploit-heavy workload: bottom-quantile trials clone
    // top-quantile checkpoints every perturbation interval.
    SchedulerKind::Pbt { perturbation_interval: 3, space: space() }
}

fn opts(dir: PathBuf, resume: bool) -> RunOptions {
    RunOptions {
        cluster: Cluster::uniform(2, Resources::cpu(4.0)),
        exec: ExecMode::Sim,
        experiment_dir: Some(dir),
        snapshot_every: 3,
        resume,
        // Tiny cap: forces assembled caches and chunk payloads out to
        // the spill tier, so resume actually reads chunk files back.
        checkpoint_mem_budget: Some(256),
        ..Default::default()
    }
}

fn runner(dir: &PathBuf, resume: bool) -> TrialRunner {
    build_runner(
        spec(),
        space(),
        scheduler(),
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        opts(dir.clone(), resume),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tune_ckptstore_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Every checkpoint blob the crashed run persisted: id -> payload.
fn capture_blobs(store: &mut CheckpointStore) -> BTreeMap<u64, Vec<u8>> {
    let ids: Vec<u64> = store.ids().collect();
    ids.iter()
        .map(|id| (*id, store.get(*id).expect("live id readable").to_vec()))
        .collect()
}

/// Crash mid-PBT with spill enabled, resume: restored checkpoints are
/// byte-equal to their pre-crash blobs, and the store's physical
/// (deduped) footprint after restore equals what re-ingesting the same
/// blobs from scratch would produce — the dedup ratio survives the
/// snapshot/restore round trip instead of silently re-duplicating.
#[test]
fn crash_resume_restores_byte_identical_blobs_and_dedup() {
    let dir = tmpdir("resume");
    let pre_crash = {
        let mut r = runner(&dir, false);
        assert!(r.run_to_crash(2), "experiment finished before the crash point");
        let store = r.debug_ckpt_store();
        store.debug_check_store();
        let blobs = capture_blobs(store);
        assert!(!blobs.is_empty(), "crash point produced no checkpoints");
        blobs
    }; // runner dropped mid-flight — the "crash"
    assert!(dir.join("checkpoints").join("chunks").is_dir(), "spill tier missing");

    let mut r = runner(&dir, true);
    let store = r.debug_ckpt_store();
    store.debug_check_store();
    let restored_ids: Vec<u64> = store.ids().collect();
    assert!(!restored_ids.is_empty(), "restore lost every checkpoint");
    let mut survivors: Vec<(u64, Vec<u8>)> = Vec::new();
    for id in &restored_ids {
        let got = store.get(*id).expect("restored id readable");
        let expect = pre_crash
            .get(id)
            .unwrap_or_else(|| panic!("restored id {id} did not exist pre-crash"));
        assert_eq!(&got[..], &expect[..], "blob {id} changed across crash-resume");
        survivors.push((*id, got.to_vec()));
    }

    // Dedup-survival oracle: a fresh store fed the same blobs (in id
    // order, no GC) must land on the same physical byte count — the
    // restore path re-established chunk sharing, it didn't re-copy.
    let restored_physical = store.stats().physical_bytes;
    let mut oracle = CheckpointStore::new();
    oracle.keep_per_trial = 0; // unbounded: ingest everything
    for (i, (_, blob)) in survivors.iter().enumerate() {
        oracle.save(0, i as u64, blob.clone());
    }
    assert_eq!(
        oracle.stats().physical_bytes,
        restored_physical,
        "dedup ratio did not survive restore"
    );

    // And the resumed experiment runs to completion on top of it.
    let res = r.run();
    assert_eq!(res.trials.len(), SAMPLES);
    assert!(res.trials.values().all(|t| t.status.is_terminal()));
    assert!(res.best.is_some());
    assert!(res.ckpt.saved > 0, "no checkpoints written after resume");
    if res.stats.exploits > 0 {
        assert!(
            res.ckpt.blob_dedup_hits > 0,
            "PBT exploit clones should dedup at the blob level"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Torn spill tier: corrupt every chunk file between crash and resume.
/// Restore must drop the unreadable blobs (verified by rehash, so even
/// same-length corruption is caught), degrade the affected trials to
/// replay-from-scratch, and still finish the experiment — one bad file
/// never poisons the store or wedges the run.
#[test]
fn torn_chunk_files_degrade_to_replay_not_poison() {
    let dir = tmpdir("torn");
    {
        let mut r = runner(&dir, false);
        assert!(r.run_to_crash(2), "experiment finished before the crash point");
        assert!(!capture_blobs(r.debug_ckpt_store()).is_empty());
    }
    let chunks_dir = dir.join("checkpoints").join("chunks");
    let mut torn = 0;
    for entry in std::fs::read_dir(&chunks_dir).expect("spill tier exists") {
        let path = entry.unwrap().path();
        if path.is_file() {
            // Same length as nothing we store; rehash catches the rest.
            std::fs::write(&path, b"torn").unwrap();
            torn += 1;
        }
    }
    assert!(torn > 0, "no chunk files to corrupt");

    let mut r = runner(&dir, true);
    {
        let store = r.debug_ckpt_store();
        assert_eq!(store.len(), 0, "blobs with torn chunks must be dropped at restore");
        store.debug_check_store();
    }
    // Trials that pointed at the lost checkpoints replay from scratch;
    // the run still completes with a full, sane result.
    let res = r.run();
    assert_eq!(res.trials.len(), SAMPLES);
    assert!(res.trials.values().all(|t| t.status.is_terminal()));
    assert!(res.best.is_some());
    let sum_iters: u64 = res.trials.values().map(|t| t.iteration).sum();
    assert_eq!(res.stats.total_iterations, sum_iters, "iteration accounting drifted");
    // The store works again for the rest of the run: new checkpoints
    // chunk, spill, and read back normally.
    assert!(res.ckpt.checkpoints > 0, "no fresh checkpoints after degradation");
    std::fs::remove_dir_all(&dir).ok();
}
