//! Property-based tests over coordinator invariants (routing, batching,
//! state), using the library's deterministic property harness.

use std::collections::BTreeSet;

use tune::coordinator::schedulers::{
    AshaScheduler, Decision, MedianStoppingRule, PbtScheduler, SchedulerCtx, TrialScheduler,
};
use tune::coordinator::spec::{expand_grid, grid_size, sample_config, ParamDist, SpaceBuilder};
use tune::coordinator::trial::{Config, Mode, ParamValue, ResultRow, Trial, TrialId, TrialStatus};
use tune::coordinator::{
    build_runner, ExperimentSpec, RunOptions, SchedulerKind, SearchKind, TrialRunner,
};
use tune::ray::{
    AutoscalePolicy, Cluster, FaultPlan, Resources, ThroughputProfiler, TwoLevelScheduler,
    Utilization,
};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;
use tune::util::intern::MetricId;
use tune::util::prop::check;
use tune::util::rng::Rng;

/// Slow-path reference for the runner's incrementally maintained
/// Pending queue: recompute it from trial statuses.
fn pending_of(trials: &std::collections::BTreeMap<TrialId, Trial>) -> BTreeSet<TrialId> {
    trials.values().filter(|t| t.status == TrialStatus::Pending).map(|t| t.id).collect()
}

fn random_space(rng: &mut Rng) -> tune::coordinator::spec::SearchSpace {
    let mut b = SpaceBuilder::new();
    let n = rng.range(1, 5);
    for i in 0..n {
        b = match rng.index(4) {
            0 => b.uniform(&format!("u{i}"), 0.0, rng.uniform(0.5, 10.0)),
            1 => b.loguniform(&format!("l{i}"), 1e-5, 1.0),
            2 => b.randint(&format!("r{i}"), 0, rng.range(1, 20)),
            _ => b.grid_f64(&format!("g{i}"), &[0.1, 0.2, 0.3][..rng.index(3) + 1]),
        };
    }
    b.build()
}

#[test]
fn prop_samples_always_in_support() {
    check("samples_in_support", 0xA11CE, 200, |rng, _| {
        let space = random_space(rng);
        let cfg = sample_config(&space, rng);
        for (k, d) in &space {
            assert!(d.contains(&cfg[k]), "{k}: {:?} not in {:?}", cfg[k], d);
        }
    });
}

#[test]
fn prop_grid_expansion_size_is_product() {
    check("grid_size", 0xB0B, 200, |rng, _| {
        let space = random_space(rng);
        let configs = expand_grid(&space, rng);
        assert_eq!(configs.len(), grid_size(&space));
        // All configs complete and distinct on grid dims.
        for c in &configs {
            assert_eq!(c.len(), space.len());
        }
    });
}

/// A random fractional resource vector (sometimes with custom keys).
fn rand_resources(rng: &mut Rng) -> Resources {
    let mut r = Resources::cpu_gpu(
        rng.uniform(0.0, 8.0),
        if rng.bool(0.5) { rng.uniform(0.0, 4.0) } else { 0.0 },
    );
    for i in 0..rng.index(3) {
        r.custom.insert(format!("c{i}"), rng.uniform(0.0, 16.0));
    }
    r
}

/// `Resources` arithmetic closure: for any capacity and any demand that
/// fits it, acquire keeps the vector valid (non-negative), release
/// restores the original exactly (EPS-aware equality — the satellite
/// fix: a raw-f64 `==` fails this after float round trips), and `fits`
/// is monotone under growing capacity.
#[test]
fn prop_resources_acquire_release_closure() {
    check("resources_closure", 0x5E50, 300, |rng, _| {
        let cap = rand_resources(rng);
        // A demand scaled inside the capacity always fits...
        let demand = cap.scaled(rng.uniform(0.0, 1.0));
        assert!(cap.fits(&demand), "{cap} should fit {demand}");
        // ...and a grown capacity still fits it (monotonicity).
        let mut grown = cap.clone();
        grown.release(&rand_resources(rng));
        assert!(grown.fits(&demand));
        // acquire/release closure.
        let mut work = cap.clone();
        work.acquire(&demand);
        assert!(work.is_valid(), "negative after acquire: {work}");
        work.release(&demand);
        assert_eq!(work, cap, "release did not restore the original");
        // Chains of fitting sub-demands stay valid and restore too.
        let parts: Vec<Resources> =
            (0..rng.index(4) + 1).map(|_| work.scaled(rng.uniform(0.0, 0.2))).collect();
        let mut acc = work.clone();
        for p in &parts {
            assert!(acc.fits(p));
            acc.acquire(p);
            assert!(acc.is_valid());
        }
        for p in &parts {
            acc.release(p);
        }
        assert_eq!(acc, cap);
    });
}

/// EPS boundary behaviour of `fits`: exact equality fits, overshoot
/// within EPS/2 still fits, overshoot beyond 2*EPS does not.
#[test]
fn prop_resources_fits_eps_boundary() {
    check("resources_eps", 0xE95, 300, |rng, _| {
        let cap = rand_resources(rng);
        assert!(cap.fits(&cap), "exact equality must fit");
        let mut barely = cap.clone();
        barely.cpu += 5e-10;
        barely.gpu += 5e-10;
        assert!(cap.fits(&barely), "within-EPS overshoot must fit");
        let dim = rng.index(2);
        let mut over = cap.clone();
        if dim == 0 {
            over.cpu += 2e-9 + rng.uniform(0.0, 1.0);
        } else {
            over.gpu += 2e-9 + rng.uniform(0.0, 1.0);
        }
        assert!(!cap.fits(&over), "{cap} must not fit {over}");
        // A custom key the capacity lacks never fits (beyond EPS).
        let mut alien = cap.clone();
        alien.custom.insert("alien".into(), rng.uniform(0.1, 4.0));
        assert!(!cap.fits(&alien));
    });
}

/// NaN / negative / infinite demands are rejected by validation, and a
/// NaN demand never silently "fits" validation-guarded paths.
#[test]
fn prop_resources_validate_rejects_garbage() {
    check("resources_validate", 0xBAD, 200, |rng, case| {
        let mut r = rand_resources(rng);
        assert!(r.validate_demand().is_ok(), "clean vector rejected: {r}");
        let poison = [f64::NAN, -1.0 - rng.uniform(0.0, 5.0), f64::INFINITY][case % 3];
        match rng.index(3) {
            0 => r.cpu = poison,
            1 => r.gpu = poison,
            _ => {
                r.custom.insert("bad".into(), poison);
            }
        }
        assert!(r.validate_demand().is_err(), "poisoned vector accepted: {r}");
    });
}

/// Placement never over-commits a node and accounting stays exact under
/// random lease/release/kill churn.
#[test]
fn prop_cluster_accounting_under_churn() {
    check("cluster_accounting", 0xC1u64, 120, |rng, _| {
        let n_nodes = rng.index(6) + 1;
        let mut cluster = Cluster::uniform(n_nodes, Resources::cpu_gpu(8.0, 2.0));
        let mut placer = TwoLevelScheduler::new();
        let mut live: Vec<(u32, u64)> = Vec::new();
        for _ in 0..200 {
            match rng.index(10) {
                0..=5 => {
                    let demand = Resources::cpu_gpu(
                        rng.uniform(0.5, 3.0),
                        if rng.bool(0.3) { rng.uniform(0.0, 1.0) } else { 0.0 },
                    );
                    let origin = rng.index(n_nodes) as u32;
                    if let Some(p) = placer.place(&mut cluster, origin, &demand) {
                        live.push((p.node, p.lease));
                    }
                }
                6..=8 => {
                    if !live.is_empty() {
                        let (node, lease) = live.swap_remove(rng.index(live.len()));
                        cluster.release(node, lease);
                    }
                }
                _ => {
                    let victim = rng.index(n_nodes) as u32;
                    let dead = cluster.kill_node(victim);
                    live.retain(|(n, l)| *n != victim || !dead.contains(l));
                    cluster.restart_node(victim);
                }
            }
            assert!(cluster.check_invariants(), "accounting broke");
        }
    });
}

/// ASHA decisions use only the rung contents at arrival time. Two
/// order-sensitive invariants: (a) strictly descending arrivals promote
/// exactly the first trial; (b) random arrival order promotes at most
/// n/eta + O(log n) trials (the harmonic excess of running-top-1/eta).
#[test]
fn prop_asha_promotion_rate_bounded() {
    check("asha_promotions", 0xA5A, 60, |rng, case| {
        let eta = [2.0, 3.0, 4.0][rng.index(3)];
        let mut s = AshaScheduler::new(1, eta, 1000);
        let mut trials = std::collections::BTreeMap::new();
        let n = rng.index(40) + 5;
        let descending = case % 2 == 0;
        let mut values: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        if descending {
            values.sort_by(|a, b| b.partial_cmp(a).unwrap());
            values.dedup();
        }
        const METRIC: MetricId = 0;
        let mut promoted = 0;
        let m = values.len();
        for (i, v) in values.into_iter().enumerate() {
            let id = i as u64;
            let mut t = Trial::new(id, Config::new(), Resources::cpu(1.0), id);
            let row = ResultRow::new(1, 1.0).with(METRIC, v);
            t.status = TrialStatus::Running;
            t.record(row.clone(), METRIC, Mode::Max);
            trials.insert(id, t.clone());
            let pending = pending_of(&trials);
            let ctx = SchedulerCtx {
                trials: &trials,
                pending: &pending,
                metric_id: METRIC,
                mode: Mode::Max,
                utilization: Utilization::default(),
            };
            match s.on_result(&ctx, &t, &row) {
                Decision::Stop => {}
                _ => promoted += 1,
            }
        }
        if descending {
            assert_eq!(promoted, 1, "descending arrivals must promote only the first");
        } else {
            let bound = m as f64 / eta + 3.0 * (m as f64).ln() + 3.0;
            assert!(
                (promoted as f64) <= bound,
                "promoted {promoted} of {m} at eta {eta} (bound {bound:.1})"
            );
        }
    });
}

/// Median stopping never stops the best trial.
#[test]
fn prop_median_never_stops_best() {
    check("median_best_survives", 0x3E0, 60, |rng, _| {
        let mut s = MedianStoppingRule::new(1, 2);
        let n = rng.index(8) + 3;
        let qualities: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let best = qualities
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u64;
        let mut trials = std::collections::BTreeMap::new();
        for id in 0..n as u64 {
            let t = Trial::new(id, Config::new(), Resources::cpu(1.0), id);
            trials.insert(id, t);
        }
        const METRIC: MetricId = 0;
        for iter in 1..=10u64 {
            for id in 0..n as u64 {
                let v = qualities[id as usize] + rng.normal_scaled(0.0, 0.001);
                let row = ResultRow::new(iter, iter as f64).with(METRIC, v);
                {
                    let t = trials.get_mut(&id).unwrap();
                    if t.status != TrialStatus::Running {
                        continue;
                    }
                    t.record(row.clone(), METRIC, Mode::Max);
                    t.status = TrialStatus::Running;
                }
                let t = trials[&id].clone();
                let pending = pending_of(&trials);
                let ctx = SchedulerCtx {
                    trials: &trials,
                    pending: &pending,
                    metric_id: METRIC,
                    mode: Mode::Max,
                    utilization: Utilization::default(),
                };
                let d = s.on_result(&ctx, &t, &row);
                if let Decision::Stop = d {
                    assert_ne!(id, best, "stopped the best trial (quality {})", qualities[id as usize]);
                    trials.get_mut(&id).unwrap().status = TrialStatus::Stopped;
                }
            }
        }
    });
}

/// PBT exploit sources are always top-quantile members and mutated
/// configs stay inside the search space.
#[test]
fn prop_pbt_exploit_sources_are_top() {
    check("pbt_sources", 0x9B7, 40, |rng, case| {
        let space = SpaceBuilder::new().loguniform("lr", 1e-5, 1.0).build();
        let mut s = PbtScheduler::new(1, space.clone(), case as u64);
        let n = rng.index(12) + 6;
        let mut trials = std::collections::BTreeMap::new();
        let scores: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        for id in 0..n as u64 {
            let mut c = Config::new();
            c.insert("lr".into(), ParamValue::F64(rng.log_uniform(1e-5, 1.0)));
            let mut t = Trial::new(id, c, Resources::cpu(1.0), id);
            t.status = TrialStatus::Running;
            trials.insert(id, t);
        }
        // One full round of reports at iteration 1.
        const METRIC: MetricId = 0;
        for id in 0..n as u64 {
            let row = ResultRow::new(1, 1.0).with(METRIC, scores[id as usize]);
            trials.get_mut(&id).unwrap().record(row.clone(), METRIC, Mode::Max);
            let t = trials[&id].clone();
            let pending = pending_of(&trials);
            let ctx = SchedulerCtx {
                trials: &trials,
                pending: &pending,
                metric_id: METRIC,
                mode: Mode::Max,
                utilization: Utilization::default(),
            };
            if let Decision::Exploit { source, config } = s.on_result(&ctx, &t, &row) {
                // Source strictly better than self.
                assert!(
                    scores[source as usize] > scores[id as usize],
                    "exploited a worse trial"
                );
                let lr = config["lr"].as_f64().unwrap();
                assert!((1e-5..=1.0).contains(&lr));
            }
        }
    });
}

// ---------------------------------------------------------------------
// Runner index equivalence (the million-trial tentpole's oracle)
// ---------------------------------------------------------------------

/// Step `runner` to completion, re-deriving every incrementally
/// maintained index (per-status counters, Pending queue, per-node lease
/// index, running-demand sum, iteration/budget totals, cluster caches)
/// from a full scan after each event; fail on the first divergence.
fn drive_checked(runner: &mut TrialRunner, label: &str) {
    runner
        .debug_check_indices()
        .unwrap_or_else(|e| panic!("{label}: diverged before the first event: {e}"));
    while runner.debug_step() {
        runner.debug_check_indices().unwrap_or_else(|e| panic!("{label}: {e}"));
    }
}

fn lr_space() -> tune::coordinator::spec::SearchSpace {
    SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build()
}

/// Final-state consistency shared by the oracle tests below.
fn assert_result_consistent(res: &tune::coordinator::ExperimentResult, n: usize) {
    assert_eq!(res.trials.len(), n);
    assert!(res.trials.values().all(|t| t.status.is_terminal()));
    assert_eq!(res.stats.total_iterations, res.total_iterations());
    let budget: f64 = res.trials.values().map(|t| t.time_total_s).sum();
    assert!(
        (res.budget_used_s - budget).abs() <= 1e-6 * budget.abs().max(1.0),
        "incremental budget {} != recomputed {budget}",
        res.budget_used_s
    );
}

/// The tentpole's oracle: across randomized runs mixing schedulers
/// (FIFO/ASHA/HyperBand/median), search algorithms, step and node
/// faults, HyperBand pauses and autoscaler drains, the runner's
/// incremental indices stay equal to a freshly computed full-scan
/// reference after EVERY event.
#[test]
fn prop_runner_indices_match_full_scan_reference() {
    check("runner_indices", 0x1D5, 10, |rng, case| {
        let mut spec = ExperimentSpec::named(&format!("prop-idx-{case}"));
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.num_samples = rng.index(120) + 40;
        spec.max_iterations_per_trial = rng.range(3, 9) as u64;
        spec.seed = 0xD0 + case as u64;
        spec.checkpoint_freq = 2;
        spec.max_failures = 20;
        if rng.bool(0.5) {
            spec.fault_plan = FaultPlan {
                step_failure_prob: 0.01,
                node_failure_prob: 0.01,
                nodes_restart: true,
                node_restart_delay: 10,
            };
        }
        let scheduler = match rng.index(4) {
            0 => SchedulerKind::Fifo,
            1 => SchedulerKind::Asha {
                grace_period: 1,
                reduction_factor: 3.0,
                max_t: spec.max_iterations_per_trial,
            },
            2 => SchedulerKind::MedianStopping { grace_period: 2, min_samples: 3 },
            _ => SchedulerKind::HyperBand { max_t: spec.max_iterations_per_trial, eta: 3.0 },
        };
        let search = if rng.bool(0.5) { SearchKind::Random } else { SearchKind::Tpe };
        let mut opts = RunOptions {
            cluster: Cluster::uniform(rng.index(3) + 2, Resources::cpu(4.0)),
            ..Default::default()
        };
        if rng.bool(0.4) {
            opts.autoscale = Some(AutoscalePolicy {
                node_template: Resources::cpu(4.0),
                templates: Vec::new(),
                min_nodes: 1,
                max_nodes: 6,
                scale_up_after: 3,
                scale_down_after: 10,
                scale_down_util: 0.15,
            });
        }
        let n = spec.num_samples;
        let mut runner = build_runner(
            spec,
            lr_space(),
            scheduler,
            search,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts,
        );
        drive_checked(&mut runner, &format!("case {case}"));
        assert_result_consistent(&runner.finalize(), n);
    });
}

/// The same oracle across snapshot→restore at 2k trials: a faulty ASHA
/// run is driven with per-event index checks until two periodic
/// snapshots are durable, abandoned mid-flight, resumed from disk (the
/// indices are rebuilt from the trial table — they are never
/// persisted), and driven to completion with per-event checks.
#[test]
fn runner_indices_survive_snapshot_restore_at_2k_trials() {
    let dir = std::env::temp_dir().join(format!("tune_prop_idx_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let spec = || {
        let mut s = ExperimentSpec::named("prop-idx-2k");
        s.metric = "accuracy".into();
        s.mode = Mode::Max;
        s.num_samples = 2000;
        s.max_iterations_per_trial = 3;
        s.seed = 0x2B5;
        s.checkpoint_freq = 2;
        s.max_failures = 30;
        s.fault_plan = FaultPlan {
            step_failure_prob: 0.002,
            node_failure_prob: 0.002,
            nodes_restart: true,
            node_restart_delay: 20,
        };
        s
    };
    let sched = || SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 3 };
    let opts = |resume| RunOptions {
        cluster: Cluster::uniform(4, Resources::cpu(8.0)),
        experiment_dir: Some(dir.clone()),
        snapshot_every: 400,
        resume,
        ..Default::default()
    };
    let mk = |resume| {
        build_runner(
            spec(),
            lr_space(),
            sched(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(resume),
        )
    };
    // Phase 1: per-event oracle checks until two snapshots exist, then
    // abandon the runner mid-flight (the in-process crash).
    {
        let mut r = mk(false);
        r.debug_check_indices().expect("pre-crash divergence before first event");
        while r.debug_step() {
            r.debug_check_indices().expect("pre-crash divergence");
            if r.debug_stats().snapshots >= 2 {
                break;
            }
        }
        assert!(r.debug_stats().snapshots >= 2, "finished before the crash point");
    }
    // Phase 2: resume. The restore path must rebuild every index from
    // the trial table before the first post-resume event fires.
    let mut r = mk(true);
    r.debug_check_indices().expect("restored indices diverged");
    while r.debug_step() {
        r.debug_check_indices().expect("post-resume divergence");
    }
    let res = r.finalize();
    assert_result_consistent(&res, 2000);
    assert!(res.stats.replayed > 0, "the crash should have forced a replay");
    std::fs::remove_dir_all(&dir).ok();
}

/// Checkpoint store GC keeps the newest blobs and latest_for is stable.
#[test]
fn prop_checkpoint_gc_keeps_latest() {
    check("ckpt_gc", 0xCC, 100, |rng, _| {
        let mut store = tune::checkpoint::CheckpointStore::new();
        let trials = rng.index(4) + 1;
        let mut latest = std::collections::BTreeMap::new();
        for i in 0..rng.index(30) + 5 {
            let trial = rng.index(trials) as u64;
            let id = store.save(trial, i as u64, vec![i as u8]);
            latest.insert(trial, (id, i as u8));
        }
        for (trial, (id, byte)) in latest {
            assert_eq!(store.latest_for(trial), Some(id));
            assert_eq!(&store.get(id).unwrap()[..], &[byte]);
        }
    });
}

/// Random mutation of a checkpoint payload: in-place flips, appends,
/// truncations, and shifting inserts — the mix that exercises both the
/// whole-blob dedup fast path (no-op mutations are rare but legal) and
/// the content-defined chunker's shift resistance.
fn mutate_blob(rng: &mut Rng, buf: &mut Vec<u8>) {
    match rng.index(4) {
        0 => {
            // XOR a small window in place (same-length edit).
            if !buf.is_empty() {
                let at = rng.index(buf.len());
                let n = (rng.range(1, 2000) as usize).min(buf.len() - at);
                for b in &mut buf[at..at + n] {
                    *b ^= 0x5A;
                }
            }
        }
        1 => {
            // Grow at the tail.
            for i in 0..rng.range(1, 8000) {
                buf.push((i * 13) as u8);
            }
        }
        2 => {
            // Shrink.
            let keep = rng.index(buf.len() + 1);
            buf.truncate(keep);
        }
        _ => {
            // Insert bytes mid-stream, shifting everything after them.
            if buf.is_empty() {
                buf.push(7);
            } else {
                let at = rng.index(buf.len());
                let ins: Vec<u8> = (0..rng.range(1, 300)).map(|i| (i * 7) as u8).collect();
                buf.splice(at..at, ins);
            }
        }
    }
}

/// Mirror of `CheckpointStore::gc` over the shadow oracle: keep only
/// the newest `keep` ids per trial.
fn mirror_gc(
    keep: usize,
    live: &mut std::collections::BTreeMap<u64, Vec<u64>>,
    shadow: &mut std::collections::BTreeMap<u64, Vec<u8>>,
    trial: u64,
) {
    let ids = live.entry(trial).or_default();
    while ids.len() > keep {
        let old = ids.remove(0);
        shadow.remove(&old);
    }
}

/// Content-addressed checkpoint store: after every randomized op
/// (save with mutation, PBT exploit-clone, read-back, memory-budget
/// churn) the incrementally maintained refcounts/indices/counters must
/// match a full-scan recomputation (`debug_check_store`), every live
/// id must read back byte-identically to an independent shadow map,
/// and a snapshot + delta-journal fold must reproduce the live store
/// bit for bit — including its physical (deduped) footprint.
#[test]
fn prop_ckpt_store_invariants_hold_under_random_op_sequences() {
    check("ckpt_store_ops", 0xC4A2_57_0E, 12, |rng, case| {
        let dir = std::env::temp_dir()
            .join(format!("tune_prop_ckpt_{}_{case}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();

        let keep = rng.range(1, 4) as usize;
        let mut store = tune::checkpoint::CheckpointStore::new().with_disk(dir.clone());
        store.keep_per_trial = keep;
        let trials = rng.range(2, 6) as usize;
        // Per-trial evolving state; sizes straddle the chunker's min
        // and average chunk sizes so manifests have 0..n chunks.
        let mut state: Vec<Vec<u8>> = (0..trials)
            .map(|t| {
                let len = rng.index(60_000);
                (0..len).map(|i| (i as u64 * 31 + t as u64 * 7) as u8).collect()
            })
            .collect();
        let mut iter = vec![0u64; trials];
        // Shadow oracle: live checkpoint id -> expected payload bytes.
        let mut shadow: std::collections::BTreeMap<u64, Vec<u8>> = Default::default();
        let mut live: std::collections::BTreeMap<u64, Vec<u64>> = Default::default();

        let mut save_current = |rng: &mut Rng,
                                store: &mut tune::checkpoint::CheckpointStore,
                                state: &mut [Vec<u8>],
                                iter: &mut [u64],
                                shadow: &mut std::collections::BTreeMap<u64, Vec<u8>>,
                                live: &mut std::collections::BTreeMap<u64, Vec<u64>>| {
            let t = rng.index(state.len());
            mutate_blob(rng, &mut state[t]);
            iter[t] += 1;
            let id = store.save_timed(t as u64, iter[t], iter[t] as f64, state[t].clone());
            shadow.insert(id, state[t].clone());
            live.entry(t as u64).or_default().push(id);
            mirror_gc(keep, live, shadow, t as u64);
        };

        for _ in 0..rng.range(20, 50) {
            match rng.index(10) {
                0..=4 => {
                    save_current(rng, &mut store, &mut state, &mut iter, &mut shadow, &mut live)
                }
                5 | 6 => {
                    // PBT exploit: clone the donor's latest checkpoint
                    // into the target trial — must be a pure refcount
                    // bump on the existing blob.
                    let donor = rng.index(trials) as u64;
                    if let Some(cid) = store.latest_for(donor) {
                        let hits_before = store.stats().blob_dedup_hits;
                        let blob = store.get(cid).expect("latest id must read back");
                        let target = rng.index(trials);
                        state[target] = blob.to_vec();
                        iter[target] += 1;
                        let id = store.save_timed(
                            target as u64,
                            iter[target],
                            iter[target] as f64,
                            blob,
                        );
                        assert_eq!(
                            store.stats().blob_dedup_hits,
                            hits_before + 1,
                            "exploit clone did not dedup at the blob level"
                        );
                        shadow.insert(id, state[target].clone());
                        live.entry(target as u64).or_default().push(id);
                        mirror_gc(keep, &mut live, &mut shadow, target as u64);
                    }
                }
                7 => {
                    // Random live read must match the shadow bytes.
                    if !shadow.is_empty() {
                        let keys: Vec<u64> = shadow.keys().copied().collect();
                        let id = *rng.choose(&keys);
                        let got = store.get(id).expect("live id readable");
                        assert_eq!(&got[..], &shadow[&id][..], "payload drift for id {id}");
                    }
                }
                8 => {
                    // Budget churn: evict resident chunk payloads to
                    // disk, or lift the cap again.
                    let budget =
                        if rng.bool(0.3) { None } else { Some(rng.index(150_000)) };
                    store.set_mem_budget(budget);
                }
                _ => {
                    // GC'd / unknown ids must be gone, not half-alive.
                    let id = rng.range(1, 1000) as u64;
                    if !shadow.contains_key(&id) {
                        assert!(store.get(id).is_none(), "dead id {id} still readable");
                    }
                }
            }
            store.debug_check_store();
            assert_eq!(store.len(), shadow.len(), "live count drifted from oracle");
        }

        // Durability fold: base snapshot + a delta window of further
        // ops must rebuild the identical store from disk.
        let base = store.snapshot();
        store.reset_delta_cursor();
        for _ in 0..rng.range(1, 8) {
            save_current(rng, &mut store, &mut state, &mut iter, &mut shadow, &mut live);
            store.debug_check_store();
        }
        let delta = store.snapshot_delta();
        let mut folded =
            tune::checkpoint::CheckpointStore::restore_from(&base, &dir).expect("restore");
        folded.apply_delta(&delta, &dir).expect("delta fold");
        // Only after the fold is it safe to sweep: base-orphaned chunk
        // files may belong to delta-added blobs. Folded == live, so the
        // sweep must find nothing to delete.
        assert_eq!(folded.sweep_orphan_chunks(), 0, "fold left orphan chunk files");
        folded.debug_check_store();
        assert_eq!(folded.len(), shadow.len());
        for (id, bytes) in &shadow {
            let got = folded.get(*id).expect("folded store lost a live id");
            assert_eq!(&got[..], &bytes[..], "folded payload drift for id {id}");
        }
        assert_eq!(
            folded.stats().physical_bytes,
            store.stats().physical_bytes,
            "dedup ratio did not survive the fold"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}

/// Throughput profiles recover a planted fast/slow hardware ordering
/// under noisy step times and hostile (NaN/negative) observations, and
/// the learned state survives a snapshot/restore cycle bit-for-bit —
/// the property the hardware-aware placement ranking stands on.
#[test]
fn prop_profiler_learns_planted_ordering() {
    check("profiler_planted_ordering", 0x5AD0, 200, |rng, _| {
        let mut p = ThroughputProfiler::new();
        // Plant a >=4x throughput gap; per-step jitter of 0.8-1.25x
        // keeps every fast observation strictly above every slow one.
        let fast_sps = rng.uniform(2.0, 50.0);
        let slow_sps = fast_sps / rng.uniform(4.0, 20.0);
        for _ in 0..rng.range(5, 40) {
            p.observe("w", "fast", 1.0 / (fast_sps * rng.uniform(0.8, 1.25)));
            p.observe("w", "slow", 1.0 / (slow_sps * rng.uniform(0.8, 1.25)));
            // Garbage must be dropped, not folded in.
            p.observe("w", "fast", f64::NAN);
            p.observe("w", "slow", -rng.uniform(0.1, 5.0));
            p.observe("w", "fast", 0.0);
        }
        assert!(p.is_warm("w"), "two shapes with >=5 samples each must be warm");
        let f = p.predict("w", "fast").expect("fast profile warm");
        let s = p.predict("w", "slow").expect("slow profile warm");
        assert!(f.is_finite() && s.is_finite(), "garbage poisoned a profile");
        assert!(f > s, "planted ordering lost: fast {f} <= slow {s}");
        // Snapshot/restore reproduces the learned state exactly.
        let mut q = ThroughputProfiler::new();
        q.restore(&p.snapshot()).expect("snapshot roundtrip");
        assert_eq!(
            q.predict("w", "fast").map(f64::to_bits),
            p.predict("w", "fast").map(f64::to_bits)
        );
        assert_eq!(
            q.predict("w", "slow").map(f64::to_bits),
            p.predict("w", "slow").map(f64::to_bits)
        );
        assert_eq!(q.fleet_score("fast").to_bits(), p.fleet_score("fast").to_bits());
    });
}
