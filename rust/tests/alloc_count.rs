//! Counting-allocator regression test for the result hot path: at
//! steady state, processing one intermediate result must cost at most a
//! pinned small constant of heap allocations.
//!
//! The whole binary installs a counting `#[global_allocator]` (a thin
//! wrapper over `System`); the single test below runs sim-executor
//! experiments — strictly single-threaded, so the counter observes only
//! the coordinator — and asserts the amortized allocations per result
//! stay under the pin. Regressions that reintroduce per-result
//! `BTreeMap`/`String`/row-clone churn blow well past it (the
//! pre-interning path cost ~4-6x the pin).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    build_runner, run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Bytes requested, not just events — the checkpoint-handoff case pins
/// "zero blob-sized copies", which an event count can't distinguish
/// from small bookkeeping allocations.
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

// SAFETY: defers entirely to `System`; the counters are relaxed atomics.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Amortized allocations per processed result for one experiment run.
/// Per-trial fixed costs (trainable construction, launch bookkeeping,
/// log-free loggers) amortize across `iters` results per trial.
fn allocs_per_result(kind: SchedulerKind, samples: usize, iters: u64) -> (f64, u64) {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("alloc-count");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    let before = ALLOCS.load(Ordering::Relaxed);
    let res = run_experiments(
        spec,
        space,
        kind,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(2, Resources::cpu(8.0)),
            ..Default::default()
        },
    );
    let total = ALLOCS.load(Ordering::Relaxed) - before;
    assert!(res.stats.results > 0);
    (total as f64 / res.stats.results as f64, res.stats.results)
}

/// Amortized (allocations, keyed trial-table accesses) per processed
/// result — the doubling check's probe. Uses `build_runner` so the
/// table's touch counter is readable after the run.
fn cost_per_result(samples: usize, iters: u64) -> (f64, f64) {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("alloc-doubling");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    let before = ALLOCS.load(Ordering::Relaxed);
    let mut runner = build_runner(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(16.0)),
            ..Default::default()
        },
    );
    // Step the loop to exhaustion, read the touch counter, THEN
    // finalize: finalize consumes the table (taking its counter with
    // it) and legitimately scans it, so the measurement window is
    // exactly the per-event path.
    while runner.debug_step() {}
    let touches = runner.debug_table_touches();
    let res = runner.finalize();
    let total = ALLOCS.load(Ordering::Relaxed) - before;
    let n = res.stats.results;
    assert!(n >= samples as u64 * iters, "short run: {n} results");
    (total as f64 / n as f64, touches as f64 / n as f64)
}

/// THE pinned constant. Current steady state is dominated by the
/// trainable's own `StepOutput` (a `BTreeMap` with two `String` keys,
/// ~4-6 allocations per step — upstream of the coordinator); the
/// coordinator itself adds amortized ~0 (reused row buffer, interned
/// ids, incremental scheduler stats, heap growth amortized). The pin
/// leaves ~3x headroom for allocator/platform variance while still
/// catching any per-result map/string/clone regression, which costs
/// 15+ allocations per result the moment one sneaks back in.
const MAX_ALLOCS_PER_RESULT: f64 = 30.0;

/// One test (not several) so no parallel test thread pollutes the
/// process-wide counter; the sim executor runs everything on this
/// thread.
#[test]
fn steady_state_result_path_allocations_stay_pinned() {
    // Warm-up run: one-time lazy init (stdio locks, TLS, allocator
    // internals) must not count against the measured runs.
    let _ = allocs_per_result(SchedulerKind::Fifo, 4, 50);

    // FIFO: the pure runner + logger-free hot path.
    let (fifo, n) = allocs_per_result(SchedulerKind::Fifo, 16, 400);
    assert!(n >= 6_000, "expected a long steady-state window, got {n} results");
    assert!(
        fifo <= MAX_ALLOCS_PER_RESULT,
        "fifo hot path allocates {fifo:.1}/result (pin {MAX_ALLOCS_PER_RESULT})"
    );

    // ASHA: adds the incremental rung order-statistics to the path.
    let (asha, _) = allocs_per_result(
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 400 },
        16,
        400,
    );
    assert!(
        asha <= MAX_ALLOCS_PER_RESULT,
        "asha hot path allocates {asha:.1}/result (pin {MAX_ALLOCS_PER_RESULT})"
    );

    // Median stopping: adds the per-iteration dual-heap medians.
    let (median, _) = allocs_per_result(
        SchedulerKind::MedianStopping { grace_period: 5, min_samples: 3 },
        16,
        400,
    );
    assert!(
        median <= MAX_ALLOCS_PER_RESULT,
        "median hot path allocates {median:.1}/result (pin {MAX_ALLOCS_PER_RESULT})"
    );

    // Doubling check for the indexed per-event hot loops: 4x the trial
    // table, same amortized per-result cost — in heap allocations AND
    // in keyed trial-table accesses. Any O(live-trials) walk left on
    // the dispatch/unblock/fault path makes either ratio grow with the
    // table instead of staying flat.
    let (allocs_1k, touches_1k) = cost_per_result(1024, 12);
    let (allocs_4k, touches_4k) = cost_per_result(4096, 12);
    assert!(
        allocs_4k <= allocs_1k * 1.15 + 0.5,
        "allocs/result grew with trial count: {allocs_1k:.2} @1k -> {allocs_4k:.2} @4k"
    );
    assert!(
        touches_4k <= touches_1k * 1.15 + 0.5,
        "table touches/result grew with trial count: {touches_1k:.2} @1k -> {touches_4k:.2} @4k"
    );

    // PBT exploit-clone handoff: `CheckpointStore` and `ObjectStore`
    // share `Arc<[u8]>` as their blob currency, so cloning a donor
    // checkpoint into another trial and broadcasting it to a worker is
    // refcount bumps end to end — a 1 MiB blob must move with zero
    // blob-sized allocations (64 KiB slack covers map nodes and the
    // manifest vec; a single byte copy would cost 1 MiB+).
    {
        use std::sync::Arc;
        use tune::checkpoint::CheckpointStore;
        use tune::ray::ObjectStore;

        let mut store = CheckpointStore::new();
        let mut objs = ObjectStore::new();
        let blob: Arc<[u8]> = vec![0xAB; 1 << 20].into();
        let donor = store.save(1, 1, Arc::clone(&blob)); // chunking copies happen HERE
        let bytes_before = ALLOC_BYTES.load(Ordering::Relaxed);
        let handle = store.get(donor).expect("donor blob readable");
        assert!(Arc::ptr_eq(&handle, &blob), "get must return the stored allocation");
        let clone_id = store.save(2, 1, Arc::clone(&handle)); // the exploit clone
        let oid = objs.put(0, handle); // broadcast to a worker
        let moved = ALLOC_BYTES.load(Ordering::Relaxed) - bytes_before;
        assert!(
            moved < 64 * 1024,
            "exploit-clone handoff allocated {moved} bytes for a 1 MiB blob"
        );
        assert_eq!(store.stats().blob_dedup_hits, 1, "clone must dedup at the blob level");
        assert!(Arc::ptr_eq(&store.get(clone_id).unwrap(), &blob));
        assert!(Arc::ptr_eq(&objs.get(1, oid).unwrap(), &blob));
    }
}
