//! NaN regression suite: a trainable whose metrics diverge to `NaN`
//! mid-run must never panic a scheduler, a searcher or the runner, and
//! the experiment must still complete with a *finite* best trial.
//!
//! Before the `util::order` total-order fix, every ranking site in the
//! coordinator compared metrics with `partial_cmp(..).unwrap()`: the
//! first NaN that reached an ASHA rung, a PBT ranking, a HyperBand
//! barrier, the median rule, TPE's good/bad split, evolution's parent
//! pool or the final best-trial pick panicked the whole coordinator.

use tune::coordinator::spec::{SearchSpace, SpaceBuilder};
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, ParamValue, RunOptions, SchedulerKind,
    SearchKind, TrialStatus,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::synthetic::DivergentTrainable;
use tune::trainable::{factory, TrainableFactory};

/// EVERY trial diverges somewhere in iterations 4..=10, so each one
/// records a few finite early results and then streams NaN for the rest
/// of the run — the hardest version of the regression (no scheduler
/// callback is safe from NaN), while the early finite results guarantee
/// a finite best trial exists.
fn all_diverge_space() -> SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .randint("nan_after", 3, 11)
        .build()
}

/// Exactly half the population healthy, half diverging at iteration 4
/// (deterministic under grid expansion).
fn half_diverge_space() -> SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .grid_f64("nan_after", &[1e18, 3.0])
        .build()
}

fn divergent_factory() -> TrainableFactory {
    factory(|c, s| Box::new(DivergentTrainable::new(c, s)))
}

fn spec(name: &str, samples: usize, iters: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = 42;
    spec
}

/// One assertion shared by all cases: the experiment completes (every
/// trial terminal) and the best metric is finite — NaN streams exist in
/// every trial, but a NaN can never win.
fn assert_nan_proof(scheduler: SchedulerKind, search: SearchKind, exec: ExecMode) {
    let res = run_experiments(
        spec("nan-proof", 8, 18),
        all_diverge_space(),
        scheduler,
        search,
        divergent_factory(),
        RunOptions {
            cluster: Cluster::uniform(2, Resources::cpu(8.0)),
            exec,
            ..Default::default()
        },
    );
    assert_eq!(res.trials.len(), 8);
    let terminal = res.trials.values().filter(|t| t.status.is_terminal()).count();
    assert_eq!(terminal, res.trials.len());
    let best = res.best_metric().expect("early finite results exist in every trial");
    assert!(best.is_finite(), "best metric is {best}");
    assert!(best > 0.0);
    // Per-trial bests are NaN-free too (the Trial::record guard).
    for t in res.trials.values() {
        if let Some(b) = t.best_metric {
            assert!(b.is_finite(), "trial {} best is {b}", t.id);
        }
    }
}

fn nan_scheduler(kind: &str) -> SchedulerKind {
    match kind {
        "fifo" => SchedulerKind::Fifo,
        "asha" => SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 18 },
        "hyperband" => SchedulerKind::HyperBand { max_t: 18, eta: 3.0 },
        "median" => SchedulerKind::MedianStopping { grace_period: 2, min_samples: 2 },
        "pbt" => SchedulerKind::Pbt { perturbation_interval: 4, space: all_diverge_space() },
        other => unreachable!("{other}"),
    }
}

#[test]
fn nan_mid_run_does_not_panic_any_scheduler() {
    for kind in ["fifo", "asha", "hyperband", "median", "pbt"] {
        assert_nan_proof(nan_scheduler(kind), SearchKind::Random, ExecMode::Sim);
    }
}

#[test]
fn nan_mid_run_does_not_panic_any_searcher() {
    for search in [SearchKind::Random, SearchKind::Grid, SearchKind::Tpe, SearchKind::Evolution]
    {
        assert_nan_proof(nan_scheduler("asha"), search, ExecMode::Sim);
    }
}

#[test]
fn nan_mid_run_survives_the_pool_executor() {
    assert_nan_proof(nan_scheduler("asha"), SearchKind::Random, ExecMode::Pool { workers: 4 });
}

#[test]
fn diverged_trials_never_beat_healthy_ones() {
    // Grid-deterministic mix: 8 healthy trials, 8 diverging at
    // iteration 4. A diverged trial's best is frozen at its third
    // (early, low) curve point, so the winner must be healthy.
    let res = run_experiments(
        spec("nan-mixed", 8, 18),
        half_diverge_space(),
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 18 },
        SearchKind::Grid,
        divergent_factory(),
        RunOptions::default(),
    );
    assert_eq!(res.trials.len(), 16); // 8 passes x 2 grid values
    let best = res.best.expect("finite best exists");
    let nan_after = res.trials[&best].config["nan_after"].as_f64().unwrap();
    assert!(nan_after > 1e17, "a diverged trial won: {:?}", res.trials[&best].config);
    assert!(res.best_metric().unwrap().is_finite());
}

#[test]
fn all_nan_experiment_completes_with_no_best() {
    // Pathological endgame: every result of every trial is NaN, so no
    // finite metric is ever recorded — the experiment must still finish
    // (no panic) and report no best rather than a NaN best.
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .constant("nan_after", ParamValue::F64(0.0))
        .build();
    let res = run_experiments(
        spec("nan-all", 6, 10),
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        divergent_factory(),
        RunOptions::default(),
    );
    assert_eq!(res.trials.len(), 6);
    assert_eq!(res.count(TrialStatus::Completed), 6);
    assert!(res.best.is_none());
    assert!(res.best_metric().is_none());
    assert!(res.best_curve.is_empty());
}

#[test]
fn nan_experiment_snapshots_and_resumes() {
    // Scheduler state containing NaN (ASHA rung values, trial
    // last_result metrics) must survive a snapshot/restore roundtrip:
    // the non-finite encoding in `persist` turns them into tagged
    // strings instead of unreadable bare `NaN` tokens.
    let dir = std::env::temp_dir().join(format!("tune_nan_resume_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let run = |resume: bool| {
        run_experiments(
            spec("nan-durable", 6, 18),
            all_diverge_space(),
            SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 18 },
            SearchKind::Random,
            divergent_factory(),
            RunOptions {
                experiment_dir: Some(dir.clone()),
                snapshot_every: 10,
                resume,
                ..Default::default()
            },
        )
    };
    let first = run(false);
    assert!(first.best_metric().unwrap().is_finite());
    // Finished experiment: resume is a no-op and reproduces the result.
    let resumed = run(true);
    assert_eq!(resumed.best, first.best);
    assert_eq!(resumed.best_metric(), first.best_metric());
    std::fs::remove_dir_all(&dir).ok();
}
