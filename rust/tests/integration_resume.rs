//! Durability end to end: run an ASHA experiment, hard-stop the runner
//! mid-flight at a snapshot boundary, resume from the experiment
//! directory, and finish with the identical outcome the same seed
//! produces uninterrupted — under both the `sim` and `pool` executors.
//!
//! Determinism scope: with one trial in flight (`max_concurrent = 1`)
//! the event order is fully sequential on every executor, so resume is
//! bit-exact. (With concurrent trials the post-resume interleaving may
//! differ, like any async system; ARCHITECTURE.md documents this.)

use std::path::PathBuf;

use tune::coordinator::spec::{SearchSpace, SpaceBuilder};
use tune::coordinator::{
    build_runner, run_experiments, ExecMode, ExperimentResult, ExperimentSpec, Mode, RunOptions,
    SchedulerKind, SearchKind, TrialStatus,
};
use tune::logger::ExperimentAnalysis;
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

const SAMPLES: usize = 12;
const ITERS: u64 = 27;
const SEED: u64 = 21;
/// Deliberately offset from `checkpoint_freq` (5) so the crash lands
/// between checkpoints and the replay path is exercised.
const SNAPSHOT_EVERY: u64 = 7;

fn spec() -> ExperimentSpec {
    let mut spec = ExperimentSpec::named("resume-asha");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = ITERS;
    spec.seed = SEED;
    spec.max_concurrent = 1; // sequential events: bit-exact resume
    spec.checkpoint_freq = 5;
    spec
}

fn space() -> SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build()
}

fn scheduler() -> SchedulerKind {
    SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: ITERS }
}

fn opts(exec: ExecMode, exp_dir: Option<PathBuf>, resume: bool) -> RunOptions {
    RunOptions {
        cluster: Cluster::uniform(2, Resources::cpu(4.0)),
        exec,
        experiment_dir: exp_dir,
        snapshot_every: SNAPSHOT_EVERY,
        resume,
        ..Default::default()
    }
}

fn run(exec: ExecMode, exp_dir: Option<PathBuf>, resume: bool) -> ExperimentResult {
    run_experiments(
        spec(),
        space(),
        scheduler(),
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        opts(exec, exp_dir, resume),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tune_resume_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Crash after two periodic snapshots, then resume; the final state must
/// be identical to an uninterrupted run of the same seed.
fn crash_resume_matches_uninterrupted(exec: ExecMode, tag: &str) {
    let plain = run(exec, None, false);
    assert_eq!(plain.trials.len(), SAMPLES);

    let dir = tmpdir(tag);
    // Phase 1: run until the second snapshot has been written, then
    // abandon the runner mid-flight (the in-process analogue of a
    // process kill at a snapshot boundary).
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(exec, Some(dir.clone()), false),
        );
        let crashed = runner.run_to_crash(2);
        assert!(crashed, "experiment finished before the crash point");
        // Mid-flight state: at least one trial is non-terminal.
        assert!(runner.trials().values().any(|t| !t.status.is_terminal()));
    } // runner dropped here with live trials — the "crash"
    assert!(dir.join("snapshot.json").exists());
    assert!(dir.join("experiment.meta.json").exists());

    // Phase 2: resume from the directory and run to completion.
    let resumed = run(exec, Some(dir.clone()), true);

    assert_eq!(resumed.trials.len(), plain.trials.len());
    assert_eq!(resumed.best, plain.best, "best trial id diverged");
    assert_eq!(resumed.best_metric(), plain.best_metric(), "best metric diverged");
    assert_eq!(resumed.best_config(), plain.best_config(), "best config diverged");
    for (a, b) in resumed.trials.values().zip(plain.trials.values()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.config, b.config, "trial {} config diverged", a.id);
        assert_eq!(a.status, b.status, "trial {} status diverged", a.id);
        assert_eq!(a.iteration, b.iteration, "trial {} iterations diverged", a.id);
        assert_eq!(a.best_metric, b.best_metric, "trial {} metric diverged", a.id);
    }
    // Suppressed replays keep the result count exact across the crash.
    assert_eq!(resumed.stats.results, plain.stats.results);
    assert!(resumed.stats.replayed > 0, "the crash should have forced a replay");
    // Checkpoint metadata carries time, so rollback/replay reconstructs
    // per-trial time accounting exactly (virtual clock only — wall-clock
    // executors measure real time).
    if exec == ExecMode::Sim {
        assert!(
            (resumed.budget_used_s - plain.budget_used_s).abs() < 1e-9,
            "budget diverged: {} vs {}",
            resumed.budget_used_s,
            plain.budget_used_s
        );
    }

    // The on-disk logs are complete and duplicate-free: offline analysis
    // sees exactly the rows an uninterrupted run would have produced,
    // and agrees on the winner.
    let analysis = ExperimentAnalysis::load(&dir).unwrap();
    assert_eq!(analysis.num_results(), plain.stats.results as usize);
    let (best_id, best_v) = analysis.best_trial("accuracy", Mode::Max).unwrap();
    assert_eq!(Some(best_id), plain.best);
    let plain_best = plain.best_metric().unwrap();
    assert!((best_v - plain_best).abs() < 1e-12, "{best_v} vs {plain_best}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn asha_crash_resume_is_deterministic_on_sim() {
    crash_resume_matches_uninterrupted(ExecMode::Sim, "sim");
}

#[test]
fn asha_crash_resume_is_deterministic_on_pool() {
    crash_resume_matches_uninterrupted(ExecMode::Pool { workers: 2 }, "pool");
}

/// `--resume` on a directory that has no snapshot yet (crashed before
/// the first snapshot, or never ran) starts fresh instead of failing.
#[test]
fn resume_without_snapshot_starts_fresh() {
    let dir = tmpdir("fresh");
    let res = run(ExecMode::Sim, Some(dir.clone()), true);
    assert_eq!(res.trials.len(), SAMPLES);
    assert!(res.trials.values().all(|t| t.status.is_terminal()));
    std::fs::remove_dir_all(&dir).ok();
}

/// A completed experiment's final snapshot is marked finished: resuming
/// it is a no-op that reports the same result instead of re-running.
#[test]
fn resume_of_finished_experiment_is_a_noop() {
    let dir = tmpdir("finished");
    let first = run(ExecMode::Sim, Some(dir.clone()), false);
    let again = run(ExecMode::Sim, Some(dir.clone()), true);
    assert_eq!(again.trials.len(), first.trials.len());
    assert_eq!(again.best, first.best);
    assert_eq!(again.best_metric(), first.best_metric());
    assert_eq!(again.stats.results, first.stats.results);
    assert_eq!(again.stats.replayed, 0);
    assert_eq!(again.count(TrialStatus::Completed), first.count(TrialStatus::Completed));
    std::fs::remove_dir_all(&dir).ok();
}

/// A fresh (non-resume) run into a directory holding a crashed run's
/// state must clear it: a later `--resume` continues the fresh run, not
/// the abandoned one, and the logs contain no stale rows.
#[test]
fn fresh_run_clears_stale_state_from_reused_dir() {
    let dir = tmpdir("reuse");
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(ExecMode::Sim, Some(dir.clone()), false),
        );
        assert!(runner.run_to_crash(1));
    } // crashed run A: snapshot + partial logs + checkpoints on disk
    assert!(dir.join("snapshot.json").exists());

    let fresh = run(ExecMode::Sim, Some(dir.clone()), false); // run B
    let again = run(ExecMode::Sim, Some(dir.clone()), true); // resume = no-op of B
    assert_eq!(again.best, fresh.best);
    assert_eq!(again.stats.results, fresh.stats.results);
    assert_eq!(again.stats.replayed, 0);
    // The logs hold exactly run B's rows — nothing stale survived.
    let analysis = ExperimentAnalysis::load(&dir).unwrap();
    assert_eq!(analysis.num_results(), fresh.stats.results as usize);
    std::fs::remove_dir_all(&dir).ok();
}

/// The incrementally maintained `stats.total_iterations` and
/// `stats.budget_used_s` (which `finalize` now reads instead of
/// rescanning the trial table) must equal the recomputed per-trial sums
/// at the end of the hardest path we have: a run with step and node
/// faults, crashed at a snapshot boundary and resumed — i.e. across
/// failure rollbacks, replays, and the restore-time index rebuild.
#[test]
fn incremental_stats_match_recomputed_sums_after_faulty_resume() {
    let faulty_spec = || {
        let mut s = spec();
        s.fault_plan = tune::ray::FaultPlan {
            step_failure_prob: 0.02,
            node_failure_prob: 0.02,
            nodes_restart: true,
            node_restart_delay: 10,
        };
        s.max_failures = 50;
        s
    };
    let dir = tmpdir("incstats");
    {
        let mut runner = build_runner(
            faulty_spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(ExecMode::Sim, Some(dir.clone()), false),
        );
        assert!(runner.run_to_crash(2), "experiment finished before the crash point");
    }
    let mut runner = build_runner(
        faulty_spec(),
        space(),
        scheduler(),
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        opts(ExecMode::Sim, Some(dir.clone()), true),
    );
    let res = runner.run();
    assert_eq!(res.trials.len(), SAMPLES);
    let sum_iters: u64 = res.trials.values().map(|t| t.iteration).sum();
    let sum_budget: f64 = res.trials.values().map(|t| t.time_total_s).sum();
    assert_eq!(res.stats.total_iterations, sum_iters, "incremental iteration count drifted");
    assert_eq!(res.total_iterations(), sum_iters);
    assert!(
        (res.stats.budget_used_s - sum_budget).abs() <= 1e-6 * sum_budget.max(1.0),
        "incremental budget {} != recomputed {sum_budget}",
        res.stats.budget_used_s
    );
    // `ExperimentResult::budget_used_s` is the same counter by
    // construction now; keep the API contract pinned anyway.
    assert_eq!(res.budget_used_s, res.stats.budget_used_s);
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-resume also survives on the thread-per-trial executor (the
/// third executor `--resume` must honor); outcome equality is checked
/// structurally since trial threads interleave.
#[test]
fn crash_resume_completes_on_threads() {
    let dir = tmpdir("threads");
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(ExecMode::Threads, Some(dir.clone()), false),
        );
        assert!(runner.run_to_crash(2));
    }
    let resumed = run(ExecMode::Threads, Some(dir.clone()), true);
    assert_eq!(resumed.trials.len(), SAMPLES);
    assert!(resumed.trials.values().all(|t| t.status.is_terminal()));
    assert!(resumed.best.is_some());
    std::fs::remove_dir_all(&dir).ok();
}
