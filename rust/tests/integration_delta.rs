//! Delta-snapshot durability end to end: periodic persistence writes a
//! base snapshot plus compact fsync'd delta records, a crash at any
//! snapshot boundary resumes to the identical outcome an uninterrupted
//! run produces, compaction rolls deltas into fresh bases, and a
//! pre-delta directory (full `snapshot.json` only) still restores.

use std::path::PathBuf;

use tune::coordinator::persist::ExperimentDir;
use tune::coordinator::spec::{SearchSpace, SpaceBuilder};
use tune::coordinator::{
    build_runner, run_experiments, ExecMode, ExperimentResult, ExperimentSpec, Mode, RunOptions,
    SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

const SAMPLES: usize = 12;
const ITERS: u64 = 27;
const SEED: u64 = 33;

fn spec() -> ExperimentSpec {
    let mut spec = ExperimentSpec::named("delta-asha");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = ITERS;
    spec.seed = SEED;
    spec.max_concurrent = 1; // sequential events: bit-exact resume
    spec.checkpoint_freq = 5;
    spec
}

fn space() -> SearchSpace {
    SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build()
}

fn scheduler() -> SchedulerKind {
    SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: ITERS }
}

fn opts(exp_dir: Option<PathBuf>, snapshot_every: u64, resume: bool) -> RunOptions {
    RunOptions {
        cluster: Cluster::uniform(2, Resources::cpu(4.0)),
        exec: ExecMode::Sim,
        experiment_dir: exp_dir,
        snapshot_every,
        resume,
        ..Default::default()
    }
}

fn run(exp_dir: Option<PathBuf>, snapshot_every: u64, resume: bool) -> ExperimentResult {
    run_experiments(
        spec(),
        space(),
        scheduler(),
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        opts(exp_dir, snapshot_every, resume),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tune_delta_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn assert_same_outcome(resumed: &ExperimentResult, plain: &ExperimentResult) {
    assert_eq!(resumed.trials.len(), plain.trials.len());
    assert_eq!(resumed.best, plain.best, "best trial id diverged");
    assert_eq!(resumed.best_metric(), plain.best_metric(), "best metric diverged");
    assert_eq!(resumed.best_config(), plain.best_config(), "best config diverged");
    for (a, b) in resumed.trials.values().zip(plain.trials.values()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.config, b.config, "trial {} config diverged", a.id);
        assert_eq!(a.status, b.status, "trial {} status diverged", a.id);
        assert_eq!(a.iteration, b.iteration, "trial {} iterations diverged", a.id);
        assert_eq!(a.best_metric, b.best_metric, "trial {} metric diverged", a.id);
    }
    assert_eq!(resumed.stats.results, plain.stats.results);
}

/// Crash while the durable state is base + several deltas; the resumed
/// run must fold them and finish identically to an uninterrupted run.
#[test]
fn crash_with_pending_deltas_resumes_identically() {
    let plain = run(None, 7, false);
    let dir = tmpdir("fold");
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(Some(dir.clone()), 7, false),
        );
        // 5 periodic snapshots: 1 base + 4 delta records.
        assert!(runner.run_to_crash(5), "experiment finished before the crash point");
    }
    assert!(dir.join("snapshot.json").exists());
    let exp = ExperimentDir::open(dir.clone());
    let deltas = exp.read_deltas();
    assert_eq!(deltas.len(), 4, "expected 4 delta records after 5 snapshots");
    // Deltas are compact. The first delta's window is deterministic:
    // under max_concurrent=1 trial 0 (alone, always top-1 at its rungs)
    // is the only trial advancing through results 8..=14, so exactly
    // one trial is dirty. Later windows may churn through several
    // one-result ASHA casualties, but never the whole table.
    let first = deltas[0].get("trials").unwrap().as_arr().unwrap();
    assert_eq!(first.len(), 1, "first delta window should only touch trial 0");
    for d in &deltas {
        let trials = d.get("trials").unwrap().as_arr().unwrap();
        assert!(
            trials.len() < SAMPLES,
            "delta carries all {SAMPLES} trials — not incremental"
        );
    }

    let resumed = run(Some(dir.clone()), 7, true);
    assert!(resumed.stats.replayed > 0, "the crash should have forced a replay");
    assert_same_outcome(&resumed, &plain);
    std::fs::remove_dir_all(&dir).ok();
}

/// More snapshots than DELTAS_PER_BASE: a new base must be written
/// (compaction), the delta file restarted, and resume still exact.
#[test]
fn compaction_rolls_deltas_into_a_new_base() {
    let plain = run(None, 1, false);
    let dir = tmpdir("compact");
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(Some(dir.clone()), 1, false),
        );
        // 36 snapshots at every result: base, 32 deltas, base (the
        // compaction at snapshot 34), 2 deltas. 36 stays safely below
        // the worst-case result count of this seeded ASHA run.
        assert!(runner.run_to_crash(36), "experiment finished before the crash point");
    }
    let exp = ExperimentDir::open(dir.clone());
    let base = exp.read_snapshot().unwrap();
    assert_eq!(
        base.get("delta_epoch").and_then(|v| v.as_u64()),
        Some(2),
        "expected a second (compacted) base"
    );
    let deltas = exp.read_deltas();
    assert_eq!(deltas.len(), 2);
    assert!(deltas.iter().all(|d| d.get("epoch").and_then(|v| v.as_u64()) == Some(2)));

    let resumed = run(Some(dir.clone()), 1, true);
    assert_same_outcome(&resumed, &plain);
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-window safety: a new base already written but the old delta
/// file not yet cleared. Stale-epoch records must be skipped, not
/// folded onto the new base.
#[test]
fn stale_epoch_deltas_are_ignored() {
    let plain = run(None, 7, false);
    let dir = tmpdir("stale");
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(Some(dir.clone()), 7, false),
        );
        assert!(runner.run_to_crash(3)); // base + 2 deltas, epoch 1
    }
    let exp = ExperimentDir::open(dir.clone());
    // Forge the crash window: bump the base's epoch as if a newer base
    // had landed right before the crash, stranding epoch-1 deltas.
    // (Folding them anyway would double-apply scheduler/trial state.)
    let mut base = exp.read_snapshot().unwrap();
    if let tune::util::json::Json::Obj(m) = &mut base {
        m.insert("delta_epoch".into(), tune::util::json::Json::Num(2.0));
    }
    exp.write_snapshot(&base).unwrap();
    let resumed = run(Some(dir.clone()), 7, true);
    // Resume continues from the base's state, skipping the stranded
    // epoch-1 deltas — exactly what a crash right after the first base
    // would have resumed from, so the deterministic outcome still
    // matches the uninterrupted run (folding the stale deltas would
    // have double-applied scheduler and trial state instead).
    assert_same_outcome(&resumed, &plain);
    std::fs::remove_dir_all(&dir).ok();
}

/// Backward compatibility: a directory holding only a pre-delta FULL
/// snapshot (no `delta_epoch`, no delta file) restores exactly as the
/// old format did.
#[test]
fn old_full_snapshot_format_still_restores() {
    let plain = run(None, 7, false);
    let dir = tmpdir("oldfmt");
    {
        let mut runner = build_runner(
            spec(),
            space(),
            scheduler(),
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            opts(Some(dir.clone()), 7, false),
        );
        assert!(runner.run_to_crash(1)); // exactly one snapshot: the base
    }
    let exp = ExperimentDir::open(dir.clone());
    assert!(exp.read_deltas().is_empty());
    // Rewrite the base as the PRE-DELTA format: strip the epoch stamp.
    let mut base = exp.read_snapshot().unwrap();
    if let tune::util::json::Json::Obj(m) = &mut base {
        assert!(m.remove("delta_epoch").is_some());
    }
    exp.write_snapshot(&base).unwrap();

    let resumed = run(Some(dir.clone()), 7, true);
    assert_same_outcome(&resumed, &plain);
    std::fs::remove_dir_all(&dir).ok();
}

/// A finished experiment ends on a clean base: no delta file remains,
/// and `--resume` is a no-op reproducing the result.
#[test]
fn finished_experiment_leaves_no_deltas() {
    let dir = tmpdir("finish");
    let first = run(Some(dir.clone()), 7, false);
    let exp = ExperimentDir::open(dir.clone());
    assert!(exp.read_deltas().is_empty(), "final base must clear the delta file");
    assert_eq!(
        exp.read_snapshot().unwrap().get("finished").and_then(|v| v.as_bool()),
        Some(true)
    );
    let again = run(Some(dir.clone()), 7, true);
    assert_eq!(again.best, first.best);
    assert_eq!(again.best_metric(), first.best_metric());
    assert_eq!(again.stats.replayed, 0);
    std::fs::remove_dir_all(&dir).ok();
}
