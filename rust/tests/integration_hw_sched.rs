//! Hardware-aware scheduling end to end (ISSUE 10): cost-aware
//! autoscaling buys cheaper hardware than the cost-blind policy, a zero
//! dollar budget fails fast before any trial launches, and learned
//! throughput profiles route GPU-favored workloads onto GPU shapes —
//! all on the sim executor's virtual clock, so every run is a
//! deterministic offline proof.

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::trial::ParamValue;
use tune::coordinator::{
    build_runner, run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind,
    SearchKind,
};
use tune::ray::{AutoscalePolicy, Cluster, NodeTemplate, Resources, ShapeFactors};
use tune::trainable::synthetic::CurveTrainable;
use tune::trainable::{factory, TrainableFactory};

fn curve_factory() -> TrainableFactory {
    factory(|c, s| Box::new(CurveTrainable::new(c, s)))
}

fn spec(name: &str, samples: usize, iters: u64, seed: u64) -> ExperimentSpec {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = seed;
    spec
}

/// Two purchasable templates with identical shapes but an 8x price gap,
/// listed expensive-first. The legacy (cost-blind) scale-up takes the
/// first fit and pays $8/hour per node; the hardware-aware policy ranks
/// throughput per dollar and buys the $1 node. Identical shapes mean
/// placement, trial trajectories and scale-up counts stay the same —
/// the accrued bill is the only thing that moves.
#[test]
fn cost_aware_autoscaling_buys_cheaper_nodes() {
    let run = |hw_aware: bool| {
        let mut sp = spec("cost-aware", 32, 30, 7);
        sp.resources_per_trial = Resources::cpu(1.0);
        sp.hw_aware = hw_aware;
        let policy = AutoscalePolicy {
            node_template: Resources::cpu(4.0),
            templates: vec![
                NodeTemplate { shape: Resources::cpu(4.0), price_per_hour: 8.0 },
                NodeTemplate { shape: Resources::cpu(4.0), price_per_hour: 1.0 },
            ],
            min_nodes: 1,
            max_nodes: 4,
            scale_up_after: 2,
            scale_down_after: 1_000_000,
            scale_down_util: 0.0,
        };
        run_experiments(
            sp,
            SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            curve_factory(),
            RunOptions {
                cluster: Cluster::heterogeneous_priced(vec![(Resources::cpu(4.0), 1.0)]),
                exec: ExecMode::Sim,
                autoscale: Some(policy),
                ..Default::default()
            },
        )
    };
    let blind = run(false);
    let aware = run(true);
    for res in [&blind, &aware] {
        assert!(res.infeasible.is_none());
        assert_eq!(res.trials.len(), 32);
        assert!(res.stats.scale_ups > 0, "no scale-up: the scenario lost its pressure");
        assert!(res.stats.cost_accrued > 0.0);
    }
    // Same trials, same amount of work — strictly fewer dollars.
    assert_eq!(blind.stats.scale_ups, aware.stats.scale_ups);
    assert!(
        aware.stats.cost_accrued < blind.stats.cost_accrued,
        "cost-aware ${} should undercut cost-blind ${}",
        aware.stats.cost_accrued,
        blind.stats.cost_accrued
    );
}

/// `budget.max_cost = 0` is exhausted before the first launch: the run
/// fails fast with zero trials, exactly like an unsatisfiable resource
/// demand. A generous budget on the same priced cluster runs to
/// completion and bills a positive virtual-dollar amount.
#[test]
fn exhausted_cost_budget_fails_fast_before_any_launch() {
    let run = |max_cost: f64| {
        let mut sp = spec("budget", 8, 10, 3);
        sp.budget_max_cost = Some(max_cost);
        run_experiments(
            sp,
            SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build(),
            SchedulerKind::Fifo,
            SearchKind::Random,
            curve_factory(),
            RunOptions {
                cluster: Cluster::heterogeneous_priced(vec![(Resources::cpu(8.0), 2.0)]),
                exec: ExecMode::Sim,
                ..Default::default()
            },
        )
    };
    let broke = run(0.0);
    let err = broke.infeasible.expect("zero budget must fail fast");
    assert!(err.contains("cost budget exhausted"), "unexpected error: {err}");
    assert!(broke.trials.is_empty(), "no trial may launch on an exhausted budget");
    assert_eq!(broke.stats.cost_accrued, 0.0);

    let funded = run(1e9);
    assert!(funded.infeasible.is_none());
    assert_eq!(funded.trials.len(), 8);
    assert!(funded.stats.cost_accrued > 0.0, "priced nodes must accrue cost");
}

/// Learned routing on a heterogeneous fleet: a workload that steps 10x
/// faster on the 4-GPU shape (planted via sim shape factors) warms up
/// its throughput profiles and is then placed onto GPU nodes, so the
/// GPU shape ends up with both the higher learned steps/sec and the
/// bulk of the observed steps.
#[test]
fn gpu_favored_workloads_route_to_gpu_shapes() {
    let mut sp = spec("routing", 64, 20, 11);
    sp.resources_per_trial = Resources::cpu(1.0);
    sp.hw_aware = true;
    let mut runner = build_runner(
        sp,
        SpaceBuilder::new()
            .loguniform("lr", 1e-4, 1.0)
            .constant("workload", ParamValue::Str("gpu_heavy".into()))
            .build(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        curve_factory(),
        RunOptions {
            cluster: Cluster::heterogeneous_priced(vec![
                (Resources::cpu_gpu(8.0, 4.0), 4.0),
                (Resources::cpu_gpu(8.0, 4.0), 4.0),
                (Resources::cpu(8.0), 1.0),
                (Resources::cpu(8.0), 1.0),
            ]),
            exec: ExecMode::Sim,
            shape_factors: Some(ShapeFactors::new().rule("gpu_heavy", "c8g4", 0.1)),
            ..Default::default()
        },
    );
    let res = runner.run();
    assert!(res.infeasible.is_none());
    assert_eq!(res.trials.len(), 64);

    let prof = runner.debug_profiler();
    let gpu_sps = prof.predict("gpu_heavy", "c8g4").expect("GPU profile must be warm");
    let cpu_sps = prof.predict("gpu_heavy", "c8g0").expect("CPU profile must be warm");
    assert!(
        gpu_sps > 5.0 * cpu_sps,
        "planted 10x speedup not learned: gpu {gpu_sps} vs cpu {cpu_sps}"
    );
    let samples = |shape: &str| {
        prof.snapshot()
            .get("gpu_heavy")
            .and_then(|w| w.get(shape))
            .and_then(|p| p.get("samples"))
            .and_then(|s| s.as_u64())
            .unwrap_or(0)
    };
    assert!(
        samples("c8g4") > samples("c8g0"),
        "most steps should land on the fast shape ({} gpu vs {} cpu)",
        samples("c8g4"),
        samples("c8g0")
    );
}
