//! Integration over the real three-layer stack: AOT JAX/Pallas
//! artifacts driven through PJRT by the full coordinator (threads
//! executor). Requires `make artifacts`; tests skip (with a message)
//! when artifacts are absent.

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
    TrialStatus,
};
use tune::ray::{Cluster, Resources};
use tune::runtime::{Manifest, PjrtService};
use tune::trainable::jax_model::jax_factory;

fn service() -> Option<PjrtService> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping PJRT integration: run `make artifacts`");
        return None;
    }
    Some(PjrtService::spawn(dir).unwrap())
}

/// Grid-search the MLP over lr x activation (the paper's §4.3 example,
/// real compute): losses must improve and the best config must beat the
/// worst by a clear margin.
#[test]
fn mlp_grid_search_end_to_end() {
    let Some(svc) = service() else { return };
    let mut spec = ExperimentSpec::named("mlp-grid");
    spec.metric = "loss".into();
    spec.mode = Mode::Min;
    spec.max_iterations_per_trial = 8; // x5 PJRT steps each
    spec.max_concurrent = 3;
    let space = SpaceBuilder::new()
        .grid_f64("lr", &[0.5, 0.05, 0.0005])
        .grid_str("activation", &["relu", "tanh"])
        .build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Grid,
        jax_factory(svc.clone(), "mlp", 5),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(4.0)),
            exec: ExecMode::Threads,
            ..Default::default()
        },
    );
    svc.shutdown();
    assert_eq!(res.trials.len(), 6);
    assert_eq!(res.count(TrialStatus::Completed), 6);
    let best = res.best_metric().unwrap();
    let worst = res
        .trials
        .values()
        .filter_map(|t| t.best_metric)
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(best < 1.0, "best loss {best}");
    assert!(worst > best * 1.5, "no spread: best {best} worst {worst}");
}

/// ASHA over the MLP with checkpointing: bad lr trials are culled early,
/// checkpoint/restore round-trips real PJRT state.
#[test]
fn mlp_asha_with_checkpoints() {
    let Some(svc) = service() else { return };
    let mut spec = ExperimentSpec::named("mlp-asha");
    spec.metric = "loss".into();
    spec.mode = Mode::Min;
    spec.num_samples = 8;
    spec.max_iterations_per_trial = 9;
    spec.checkpoint_freq = 3;
    spec.max_concurrent = 4;
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 2.0)
        .choice_str("activation", &["relu", "tanh"])
        .build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 9 },
        SearchKind::Random,
        jax_factory(svc.clone(), "mlp", 5),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(4.0)),
            exec: ExecMode::Threads,
            ..Default::default()
        },
    );
    svc.shutdown();
    assert_eq!(res.trials.len(), 8);
    assert!(res.stats.checkpoints > 0);
    for t in res.trials.values() {
        assert!(t.status.is_terminal());
    }
}

/// The transformer LM trains through the full stack (Pallas attention +
/// fused-linear kernels inside the HLO): loss decreases from ~ln(128).
#[test]
fn transformer_lm_loss_decreases() {
    let Some(svc) = service() else { return };
    let mut spec = ExperimentSpec::named("tlm-smoke");
    spec.metric = "loss".into();
    spec.mode = Mode::Min;
    spec.num_samples = 1;
    spec.max_iterations_per_trial = 20; // 20 x 5 = 100 train steps
    let space = SpaceBuilder::new()
        .grid_f64("lr", &[0.3])
        .grid_str("activation", &["gelu"])
        .constant("momentum", tune::coordinator::ParamValue::F64(0.9))
        .build();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Grid,
        jax_factory(svc.clone(), "tlm", 5),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(2.0)),
            exec: ExecMode::Threads,
            ..Default::default()
        },
    );
    svc.shutdown();
    let t = res.trials.values().next().unwrap();
    assert_eq!(t.status, TrialStatus::Completed);
    let final_loss = t.last_result.as_ref().unwrap().metric(&res.schema, "loss").unwrap();
    // ln(128) = 4.85 at init; the affine chain has ~ln(4)=1.39 entropy.
    // 100 steps at lr=0.3 reaches < 2.5 (see EXPERIMENTS.md).
    assert!(final_loss < 2.5, "loss barely moved: {final_loss}");
}
