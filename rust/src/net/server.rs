//! The serve-side of the control plane: an accept loop, per-connection
//! verb threads, and the watch streamer with its backpressure policy.
//!
//! Threading model: one non-blocking accept loop (so it can observe
//! the stop flag between accepts) spawns a thread per connection.
//! Connection threads do blocking framed reads under a per-connection
//! read deadline and dispatch verbs against the shared [`ShardedHub`];
//! `submit` crosses into the owning shard over its bounded command
//! channel, `status` aggregates the per-shard cached cells without
//! touching any shard thread, and `watch` turns the connection into a
//! non-blocking status-delta stream.
//!
//! Backpressure, in order of preference: a full shard queue rejects
//! the *one* submission with a retryable error; a slow watch consumer
//! is shed (connection closed) once its unacknowledged bytes exceed
//! the cap. Watch streams are always sacrificed before submissions —
//! they are reconstructible from a fresh `watch`, an admission is not.
//!
//! Graceful drain: `stop {drain: true}` flips the server into
//! draining (new submissions rejected at the door), asks every shard
//! to finish its in-flight experiments, and keeps answering `status` /
//! `watch` until the last shard retires; then the accept loop exits
//! and [`ServerHandle::join`] hands back every experiment result.

// The unwraps here are deliberate (lock poisoning is fatal, as
// everywhere in the coordinator); the file opts out of the workspace
// unwrap gate.
#![allow(clippy::unwrap_used)]

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::runner::ExperimentResult;
use crate::coordinator::spec_file::SpecFile;
use crate::trainable::TrainableFactory;
use crate::util::json::Json;

use super::protocol::{
    error_reply, frame_bytes, ok_reply, read_frame, FrameError, FrameReader, ListenAddr,
    NetListener, NetStream, MAX_FRAME_BYTES,
};
use super::shard::{submission_from_spec, ShardedHub};

/// Maps a spec file's `workload` name to a trainable factory. The
/// binary injects its full workload table; tests inject a synthetic
/// one — the server itself has no workload opinions.
pub type WorkloadResolver = Arc<dyn Fn(&str) -> Result<TrainableFactory, String> + Send + Sync>;

/// Tunables for one server instance.
#[derive(Clone)]
pub struct ServeOptions {
    /// Per-connection read deadline: an idle persistent connection is
    /// retired after this long without a frame.
    pub read_timeout: Duration,
    /// Per-connection write deadline for blocking reply writes.
    pub write_timeout: Duration,
    /// Watch backpressure cap: a watcher with more than this many
    /// bytes in flight (queued locally + written but unacknowledged)
    /// is shed.
    pub watch_cap_bytes: usize,
    /// Per-frame size cap (requests and replies).
    pub max_frame_bytes: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            read_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(10),
            watch_cap_bytes: 256 * 1024,
            max_frame_bytes: MAX_FRAME_BYTES,
        }
    }
}

/// Monotonic counters exposed for tests, the bench and `status`
/// debugging. All relaxed: they order nothing.
#[derive(Default)]
pub struct ServerStats {
    /// Connections accepted.
    pub conns: AtomicU64,
    /// Request frames successfully decoded.
    pub frames_in: AtomicU64,
    /// Reply/stream frames queued for write.
    pub frames_out: AtomicU64,
    /// Submissions admitted.
    pub submits_ok: AtomicU64,
    /// Submissions rejected (duplicate, busy shard, draining, bad spec).
    pub submits_rejected: AtomicU64,
    /// Garbage/oversized frames answered with an error reply.
    pub protocol_errors: AtomicU64,
    /// Watch streams closed by the backpressure cap.
    pub watch_shed: AtomicU64,
}

struct ServerShared {
    hub: ShardedHub,
    resolver: WorkloadResolver,
    stats: ServerStats,
    opts: ServeOptions,
    /// Set by the `stop` verb (or `ServerHandle::shutdown`): the
    /// accept loop retires once the shards have too.
    stop: AtomicBool,
}

/// A running server: hold it to keep serving, `join` it to wait for
/// stop-and-drain and collect every experiment result.
pub struct ServerHandle {
    addr: ListenAddr,
    shared: Arc<ServerShared>,
    accept_join: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (TCP port 0 resolved to the real port).
    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    /// Server counters.
    pub fn stats(&self) -> &ServerStats {
        &self.shared.stats
    }

    /// The hub behind the server (tests submit in-process through it).
    pub fn hub(&self) -> &ShardedHub {
        &self.shared.hub
    }

    /// Programmatic stop — same effect as a `stop` verb from a client.
    pub fn shutdown(&self, drain: bool) {
        self.shared.hub.stop(drain);
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the server has stopped (via the `stop` verb or
    /// [`Self::shutdown`]) and every shard has retired, then return
    /// all experiment results.
    pub fn join(mut self) -> Vec<(String, ExperimentResult)> {
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        self.shared.hub.wait()
    }
}

/// Bind `addr` and start serving `hub` on background threads. Returns
/// once the listener is bound (so the caller can print the resolved
/// address and clients can connect immediately).
pub fn serve(
    addr: &ListenAddr,
    hub: ShardedHub,
    resolver: WorkloadResolver,
    opts: ServeOptions,
) -> io::Result<ServerHandle> {
    let (listener, bound) = NetListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let shared = Arc::new(ServerShared {
        hub,
        resolver,
        stats: ServerStats::default(),
        opts,
        stop: AtomicBool::new(false),
    });
    let shared2 = Arc::clone(&shared);
    let accept_join = std::thread::Builder::new()
        .name("tune-serve-accept".into())
        .spawn(move || accept_loop(&listener, &shared2))
        .expect("spawn accept loop");
    Ok(ServerHandle { addr: bound, shared, accept_join: Some(accept_join) })
}

fn accept_loop(listener: &NetListener, shared: &Arc<ServerShared>) {
    let mut conn_id = 0u64;
    loop {
        if shared.stop.load(Ordering::SeqCst) && shared.hub.shards_finished() {
            return;
        }
        match listener.accept() {
            Ok(stream) => {
                conn_id += 1;
                shared.stats.conns.fetch_add(1, Ordering::Relaxed);
                let shared = Arc::clone(shared);
                std::thread::Builder::new()
                    .name(format!("tune-conn-{conn_id}"))
                    .spawn(move || handle_conn(stream, &shared))
                    .expect("spawn connection thread");
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            // A failed accept (EMFILE, peer reset mid-handshake) must
            // not kill the control plane; back off and keep serving.
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

/// Write one reply frame; false = peer unreachable, drop the conn.
fn send(stream: &mut NetStream, shared: &ServerShared, msg: &Json) -> bool {
    shared.stats.frames_out.fetch_add(1, Ordering::Relaxed);
    stream.write_all(&frame_bytes(msg)).is_ok()
}

fn handle_conn(mut stream: NetStream, shared: &Arc<ServerShared>) {
    let _ = stream.set_read_timeout(Some(shared.opts.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.opts.write_timeout));
    loop {
        let req = match read_frame(&mut stream, shared.opts.max_frame_bytes) {
            Ok(Some(req)) => req,
            // Clean close between frames: the peer is done.
            Ok(None) => return,
            Err(FrameError::Garbage(e)) => {
                // Framing survived; answer and keep the connection.
                shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                if !send(&mut stream, shared, &error_reply(&format!("bad frame: {e}"))) {
                    return;
                }
                continue;
            }
            Err(FrameError::Oversized(n)) => {
                // The body was never consumed — the stream cannot be
                // resynchronized. Answer, then close.
                shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = send(
                    &mut stream,
                    shared,
                    &error_reply(&format!(
                        "frame of {n} bytes exceeds cap of {}; closing",
                        shared.opts.max_frame_bytes
                    )),
                );
                let _ = stream.shutdown();
                return;
            }
            // Torn frame, reset, or read-deadline expiry.
            Err(FrameError::Io(_)) => return,
        };
        shared.stats.frames_in.fetch_add(1, Ordering::Relaxed);
        let verb = req.get("verb").and_then(Json::as_str).unwrap_or("").to_string();
        match verb.as_str() {
            "ping" => {
                if !send(&mut stream, shared, &ok_reply(vec![])) {
                    return;
                }
            }
            "status" => {
                let status = shared.hub.status_json();
                if !send(&mut stream, shared, &ok_reply(vec![("status", status)])) {
                    return;
                }
            }
            "submit" => {
                let reply = match handle_submit(&req, shared) {
                    Ok(name) => {
                        shared.stats.submits_ok.fetch_add(1, Ordering::Relaxed);
                        ok_reply(vec![("name", Json::Str(name))])
                    }
                    Err(e) => {
                        shared.stats.submits_rejected.fetch_add(1, Ordering::Relaxed);
                        error_reply(&e)
                    }
                };
                if !send(&mut stream, shared, &reply) {
                    return;
                }
            }
            "stop" => {
                let drain = req.get("drain").and_then(Json::as_bool).unwrap_or(true);
                shared.hub.stop(drain);
                shared.stop.store(true, Ordering::SeqCst);
                if !send(
                    &mut stream,
                    shared,
                    &ok_reply(vec![("draining", Json::Bool(drain))]),
                ) {
                    return;
                }
            }
            "watch" => {
                if !send(&mut stream, shared, &ok_reply(vec![])) {
                    return;
                }
                watch_loop(stream, shared);
                return;
            }
            other => {
                if !send(
                    &mut stream,
                    shared,
                    &error_reply(&format!("unknown verb {other:?}")),
                ) {
                    return;
                }
            }
        }
    }
}

fn handle_submit(req: &Json, shared: &ServerShared) -> Result<String, String> {
    if shared.hub.stopping() {
        return Err("server is draining; submission rejected".into());
    }
    let text = req
        .get("spec")
        .and_then(Json::as_str)
        .ok_or("submit needs a \"spec\" field holding the spec-file text")?;
    let file = SpecFile::parse_str(text).map_err(|e| format!("parsing spec: {e:#}"))?;
    let factory = (shared.resolver)(&file.workload)?;
    let name = file.spec.name.clone();
    shared.hub.submit(submission_from_spec(file, factory))?;
    Ok(name)
}

/// Stream status deltas until the watcher hangs up, falls too far
/// behind (shed), or the server drains. The stream is non-blocking:
/// acks are read and deltas written from one thread, and a consumer
/// that stops reading OR stops acking accumulates in-flight bytes
/// until the cap sheds it.
fn watch_loop(mut stream: NetStream, shared: &ServerShared) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    let n = shared.hub.shard_count();
    let mut last_versions = vec![0u64; n];
    let mut reader = FrameReader::new(shared.opts.max_frame_bytes);
    // Bytes composed but not yet written to the socket.
    let mut outbuf: Vec<u8> = Vec::new();
    // (seq, frame bytes) written or queued, awaiting a client ack.
    let mut pending: VecDeque<(u64, usize)> = VecDeque::new();
    let mut in_flight = 0usize;
    let mut seq = 0u64;
    let mut buf = [0u8; 4096];
    loop {
        // Snapshot drain-state BEFORE composing deltas: a shard's
        // final status publish happens-before its thread exits, so a
        // `finished` observed here guarantees step 2 below sees the
        // terminal versions — the close at step 5 can never swallow
        // the last delta.
        let finished = shared.stop.load(Ordering::SeqCst) && shared.hub.shards_finished();
        // 1. Drain whatever acks arrived.
        loop {
            match stream.read(&mut buf) {
                Ok(0) => return, // watcher hung up
                Ok(got) => reader.feed(&buf[..got]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
        loop {
            match reader.next_frame() {
                Ok(Some(frame)) => {
                    if frame.get("verb").and_then(Json::as_str) == Some("ack") {
                        let acked =
                            frame.get("seq").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                        while pending.front().is_some_and(|(s, _)| *s <= acked) {
                            let (_, bytes) = pending.pop_front().unwrap();
                            in_flight = in_flight.saturating_sub(bytes);
                        }
                    }
                }
                Ok(None) => break,
                Err(_) => {
                    shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.shutdown();
                    return;
                }
            }
        }
        // 2. Compose a delta frame if any shard's status moved.
        let mut changed = Vec::new();
        for (k, last) in last_versions.iter_mut().enumerate() {
            let (v, status) = shared.hub.shard_status(k);
            if v > *last {
                *last = v;
                changed.push(Json::obj(vec![
                    ("shard", Json::Num(k as f64)),
                    ("version", Json::Num(v as f64)),
                    ("status", status),
                ]));
            }
        }
        if !changed.is_empty() {
            seq += 1;
            let frame = Json::obj(vec![
                ("event", Json::Str("status".into())),
                ("seq", Json::Num(seq as f64)),
                ("shards", Json::Arr(changed)),
            ]);
            let bytes = frame_bytes(&frame);
            pending.push_back((seq, bytes.len()));
            in_flight += bytes.len();
            outbuf.extend_from_slice(&bytes);
            shared.stats.frames_out.fetch_add(1, Ordering::Relaxed);
        }
        // 3. Flush as much as the socket accepts right now.
        while !outbuf.is_empty() {
            match stream.write(&outbuf) {
                Ok(0) => return,
                Ok(wrote) => {
                    outbuf.drain(..wrote);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
        // 4. Backpressure: shed a consumer that is too far behind.
        if in_flight > shared.opts.watch_cap_bytes {
            shared.stats.watch_shed.fetch_add(1, Ordering::Relaxed);
            let _ = stream.shutdown();
            return;
        }
        // 5. Drained server with nothing left to say: close politely.
        if finished && outbuf.is_empty() {
            let _ = stream.write_all(&frame_bytes(&Json::obj(vec![(
                "event",
                Json::Str("bye".into()),
            )])));
            let _ = stream.shutdown();
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}
