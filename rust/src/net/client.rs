//! Socket client for the serve control plane — what `tune submit` /
//! `status` / `stop` (and the QPS bench) speak. One [`Client`] is one
//! persistent connection; every verb is a request frame followed by
//! one reply frame, except `watch`, which turns the connection into a
//! stream of status-delta events that the client acknowledges.

// lint:allow(clock): connect retries and read deadlines are wall-clock
// by nature, like the rest of the net substrate.

use std::io::{self, Write};
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::protocol::{
    frame_bytes, read_frame, FrameError, ListenAddr, NetStream, MAX_FRAME_BYTES,
    PROTOCOL_VERSION,
};

/// One persistent control-plane connection.
pub struct Client {
    stream: NetStream,
    /// Request+reply bytes moved on this connection (for bytes/req
    /// accounting in the bench).
    bytes: u64,
}

impl Client {
    /// Dial the server with the default 30 s read deadline.
    pub fn connect(addr: &ListenAddr) -> io::Result<Client> {
        Client::connect_with_timeout(addr, Duration::from_secs(30))
    }

    /// Dial with an explicit read deadline (None = block forever).
    pub fn connect_with_timeout(
        addr: &ListenAddr,
        read_timeout: Duration,
    ) -> io::Result<Client> {
        let stream = NetStream::connect(addr)?;
        stream.set_read_timeout(Some(read_timeout))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        Ok(Client { stream, bytes: 0 })
    }

    /// Total request+reply bytes this connection has moved.
    pub fn bytes_moved(&self) -> u64 {
        self.bytes
    }

    fn request(&mut self, mut req: Json) -> Result<Json, String> {
        if let Json::Obj(obj) = &mut req {
            obj.insert("proto".into(), Json::Num(PROTOCOL_VERSION as f64));
        }
        let frame = frame_bytes(&req);
        self.bytes += frame.len() as u64;
        self.stream
            .write_all(&frame)
            .map_err(|e| format!("sending request: {e}"))?;
        match read_frame(&mut self.stream, MAX_FRAME_BYTES) {
            Ok(Some(reply)) => {
                self.bytes += 4 + reply.to_string().len() as u64;
                if reply.get("ok").and_then(Json::as_bool) == Some(false) {
                    let msg = reply
                        .get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("unspecified server error");
                    return Err(msg.to_string());
                }
                Ok(reply)
            }
            Ok(None) => Err("server closed the connection".into()),
            Err(FrameError::Io(e)) => Err(format!("reading reply: {e}")),
            Err(e) => Err(format!("bad reply: {e}")),
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), String> {
        self.request(Json::obj(vec![("verb", Json::Str("ping".into()))]))
            .map(|_| ())
    }

    /// Submit a spec file's *text*; the server parses and admits it.
    /// Returns the admitted experiment name.
    pub fn submit_spec_text(&mut self, spec_text: &str) -> Result<String, String> {
        let reply = self.request(Json::obj(vec![
            ("verb", Json::Str("submit".into())),
            ("spec", Json::Str(spec_text.to_string())),
        ]))?;
        Ok(reply
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string())
    }

    /// Aggregated hub status (the `status` field of the reply).
    pub fn status(&mut self) -> Result<Json, String> {
        let reply = self.request(Json::obj(vec![("verb", Json::Str("status".into()))]))?;
        reply
            .get("status")
            .cloned()
            .ok_or_else(|| "status reply missing \"status\"".into())
    }

    /// Ask the server to stop. `drain` = finish in-flight experiments
    /// first.
    pub fn stop(&mut self, drain: bool) -> Result<(), String> {
        self.request(Json::obj(vec![
            ("verb", Json::Str("stop".into())),
            ("drain", Json::Bool(drain)),
        ]))
        .map(|_| ())
    }

    /// Enter watch mode: stream status-delta events into `on_event`
    /// (acknowledging each, which is what keeps this client from
    /// being shed) until the server says bye, the callback returns
    /// `false`, or the stream ends. Consumes the client — a watch
    /// connection never returns to request/reply mode.
    pub fn watch(mut self, mut on_event: impl FnMut(&Json) -> bool) -> Result<(), String> {
        let frame = frame_bytes(&Json::obj(vec![
            ("verb", Json::Str("watch".into())),
            ("proto", Json::Num(PROTOCOL_VERSION as f64)),
        ]));
        self.stream
            .write_all(&frame)
            .map_err(|e| format!("sending watch request: {e}"))?;
        // The ok-reply that precedes the stream.
        match read_frame(&mut self.stream, MAX_FRAME_BYTES) {
            Ok(Some(reply)) if reply.get("ok").and_then(Json::as_bool) == Some(true) => {}
            Ok(Some(reply)) => {
                return Err(reply
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("watch rejected")
                    .to_string())
            }
            Ok(None) => return Err("server closed the connection".into()),
            Err(e) => return Err(format!("bad watch reply: {e}")),
        }
        loop {
            match read_frame(&mut self.stream, MAX_FRAME_BYTES) {
                Ok(Some(event)) => {
                    if event.get("event").and_then(Json::as_str) == Some("bye") {
                        return Ok(());
                    }
                    if let Some(seq) = event.get("seq").and_then(Json::as_f64) {
                        let ack = frame_bytes(&Json::obj(vec![
                            ("verb", Json::Str("ack".into())),
                            ("seq", Json::Num(seq)),
                        ]));
                        self.stream
                            .write_all(&ack)
                            .map_err(|e| format!("sending ack: {e}"))?;
                    }
                    if !on_event(&event) {
                        return Ok(());
                    }
                }
                // Shed or server gone: the stream just ends.
                Ok(None) => return Ok(()),
                Err(FrameError::Io(e)) => return Err(format!("watch stream: {e}")),
                Err(e) => return Err(format!("bad watch frame: {e}")),
            }
        }
    }
}

/// Dial-with-retry until the server answers a ping or `total` elapses
/// — the standard way to wait out a server that is still binding.
pub fn wait_until_up(addr: &ListenAddr, total: Duration) -> Result<Client, String> {
    let deadline = Instant::now() + total;
    loop {
        match Client::connect_with_timeout(addr, Duration::from_secs(5)) {
            Ok(mut c) => match c.ping() {
                Ok(()) => return Ok(c),
                Err(e) if Instant::now() >= deadline => {
                    return Err(format!("server at {addr} not answering: {e}"))
                }
                Err(_) => {}
            },
            Err(e) if Instant::now() >= deadline => {
                return Err(format!("cannot reach {addr}: {e}"))
            }
            Err(_) => {}
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}
