//! Wire protocol for the serve control plane: length-prefixed JSON
//! frames over TCP or Unix-domain sockets.
//!
//! A frame is a 4-byte big-endian length followed by exactly that many
//! bytes of UTF-8 JSON. Requests are objects with a `"verb"` key
//! (`submit` / `status` / `stop` / `watch` / `ping` / `ack`); replies
//! are objects with `"ok": true|false`. The framing is deliberately
//! dumb: no compression, no multiplexing, no version negotiation
//! beyond a `proto` field — a control plane moves kilobytes, and every
//! client in any language can speak it with a dozen lines of code.
//!
//! Error taxonomy, which the server's connection loop leans on:
//!
//! * [`FrameError::Garbage`] — the length header was sane and fully
//!   consumed, but the body is not valid JSON. Framing is intact, so
//!   the server replies with an error frame and keeps the connection.
//! * [`FrameError::Oversized`] — the header declares more than the
//!   cap. The body has NOT been consumed and cannot be trusted enough
//!   to skip, so the server replies with an error frame and closes.
//! * [`FrameError::Io`] — the peer vanished (torn frame:
//!   `UnexpectedEof` mid-frame) or a read deadline fired
//!   (`WouldBlock`/`TimedOut`). The connection is dropped.
//!
//! A clean EOF *between* frames is not an error: [`read_frame`]
//! returns `Ok(None)` and the server retires the connection.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

use crate::util::json::{parse, Json};

/// Protocol revision carried in every request (`"proto"`); bumped on
/// incompatible changes.
pub const PROTOCOL_VERSION: u64 = 1;

/// Default per-frame size cap. A submit frame is a spec file (a few
/// KiB); a megabyte already means a confused or hostile peer.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Length-header size.
const HEADER_BYTES: usize = 4;

/// Where a serve control plane listens (or a client dials).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ListenAddr {
    /// TCP `host:port` (port 0 = kernel-assigned, reported on bind).
    Tcp(String),
    /// Unix-domain socket at the given filesystem path.
    Unix(PathBuf),
}

impl ListenAddr {
    /// Parse an address argument: `unix:/path/to.sock` selects a
    /// Unix-domain socket, anything else must look like `host:port`.
    pub fn parse(text: &str) -> Result<ListenAddr, String> {
        if let Some(path) = text.strip_prefix("unix:") {
            if path.is_empty() {
                return Err("unix: address needs a socket path".into());
            }
            return Ok(ListenAddr::Unix(PathBuf::from(path)));
        }
        match text.rsplit_once(':') {
            Some((host, port)) if !host.is_empty() && port.parse::<u16>().is_ok() => {
                Ok(ListenAddr::Tcp(text.to_string()))
            }
            _ => Err(format!(
                "bad address {text:?}: expected host:port or unix:/path.sock"
            )),
        }
    }
}

impl std::fmt::Display for ListenAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ListenAddr::Tcp(hp) => write!(f, "{hp}"),
            ListenAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// A bound listener over either transport.
pub enum NetListener {
    /// TCP listener.
    Tcp(TcpListener),
    /// Unix-domain listener.
    Unix(UnixListener),
}

impl NetListener {
    /// Bind `addr`. A pre-existing Unix socket file is removed first
    /// (the previous server is dead or it would still hold the bind);
    /// TCP port 0 resolves to a kernel-assigned port, readable from
    /// the returned display address.
    pub fn bind(addr: &ListenAddr) -> io::Result<(NetListener, ListenAddr)> {
        match addr {
            ListenAddr::Tcp(hp) => {
                let l = TcpListener::bind(hp.as_str())?;
                let actual = l.local_addr()?;
                Ok((NetListener::Tcp(l), ListenAddr::Tcp(actual.to_string())))
            }
            ListenAddr::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                Ok((NetListener::Unix(l), ListenAddr::Unix(path.clone())))
            }
        }
    }

    /// Toggle accept-loop blocking (the server polls non-blocking so
    /// it can observe its stop flag between accepts).
    pub fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            NetListener::Tcp(l) => l.set_nonblocking(nb),
            NetListener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    /// Accept one connection (transport-erased).
    pub fn accept(&self) -> io::Result<NetStream> {
        match self {
            NetListener::Tcp(l) => l.accept().map(|(s, _)| NetStream::Tcp(s)),
            NetListener::Unix(l) => l.accept().map(|(s, _)| NetStream::Unix(s)),
        }
    }
}

/// One connected stream over either transport.
#[derive(Debug)]
pub enum NetStream {
    /// A TCP connection.
    Tcp(TcpStream),
    /// A Unix-domain connection.
    Unix(UnixStream),
}

impl NetStream {
    /// Dial a server.
    pub fn connect(addr: &ListenAddr) -> io::Result<NetStream> {
        match addr {
            ListenAddr::Tcp(hp) => TcpStream::connect(hp.as_str()).map(NetStream::Tcp),
            ListenAddr::Unix(p) => UnixStream::connect(p).map(NetStream::Unix),
        }
    }

    /// Read deadline: a blocked read fails with
    /// `WouldBlock`/`TimedOut` after `dur` (None = wait forever).
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_read_timeout(dur),
            NetStream::Unix(s) => s.set_read_timeout(dur),
        }
    }

    /// Write deadline, same contract as the read side.
    pub fn set_write_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_write_timeout(dur),
            NetStream::Unix(s) => s.set_write_timeout(dur),
        }
    }

    /// Non-blocking mode (the watch loop interleaves ack reads with
    /// delta writes on one thread).
    pub fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.set_nonblocking(nb),
            NetStream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    /// Close both directions; the peer's next read sees EOF.
    pub fn shutdown(&self) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            NetStream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        }
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Unix(s) => s.flush(),
        }
    }
}

/// Why a frame could not be produced. See the module docs for how the
/// server maps each variant to reply-and-keep vs close.
#[derive(Debug)]
pub enum FrameError {
    /// Transport failure: torn frame (EOF mid-frame), reset, or an
    /// expired read deadline.
    Io(io::Error),
    /// The header declared more bytes than the cap; the body was not
    /// consumed, so the stream cannot be resynchronized.
    Oversized(usize),
    /// The body was fully consumed but is not valid JSON; framing is
    /// intact and the connection can continue.
    Garbage(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io: {e}"),
            FrameError::Oversized(n) => {
                write!(f, "frame of {n} bytes exceeds cap of {MAX_FRAME_BYTES}")
            }
            FrameError::Garbage(e) => write!(f, "bad frame body: {e}"),
        }
    }
}

/// Encode one message as a frame, appended to `out` (callers batch
/// several frames into one write).
pub fn encode_frame(msg: &Json, out: &mut Vec<u8>) {
    let body = msg.to_string();
    let len = body.len() as u32;
    out.extend_from_slice(&len.to_be_bytes());
    out.extend_from_slice(body.as_bytes());
}

/// Encode one message as an owned frame buffer.
pub fn frame_bytes(msg: &Json) -> Vec<u8> {
    let mut out = Vec::new();
    encode_frame(msg, &mut out);
    out
}

/// Blocking frame read. `Ok(None)` = clean EOF at a frame boundary
/// (the peer hung up between requests). Honors whatever read deadline
/// is set on the stream (deadline expiry surfaces as
/// [`FrameError::Io`]).
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Option<Json>, FrameError> {
    let mut header = [0u8; HEADER_BYTES];
    // Distinguish "no frame at all" (clean close) from a torn header.
    let mut got = 0;
    while got < HEADER_BYTES {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(FrameError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "torn frame header",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let len = u32::from_be_bytes(header) as usize;
    if len == 0 {
        return Err(FrameError::Garbage("empty frame body".into()));
    }
    if len > max {
        return Err(FrameError::Oversized(len));
    }
    let mut body = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut body[got..]) {
            Ok(0) => {
                return Err(FrameError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "torn frame body",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    match std::str::from_utf8(&body) {
        Ok(text) => match parse(text) {
            Ok(j) => Ok(Some(j)),
            Err(e) => Err(FrameError::Garbage(e)),
        },
        Err(e) => Err(FrameError::Garbage(format!("frame body not UTF-8: {e}"))),
    }
}

/// Incremental frame decoder for non-blocking streams: feed whatever
/// bytes arrived, pop complete frames. The watch loop uses this to
/// read client acks without ever blocking its delta writes.
pub struct FrameReader {
    buf: Vec<u8>,
    max: usize,
}

impl FrameReader {
    /// A decoder enforcing the given per-frame cap.
    pub fn new(max: usize) -> FrameReader {
        FrameReader { buf: Vec::new(), max }
    }

    /// Append newly-received bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame; `Ok(None)` = need more bytes.
    /// Oversized and garbage frames carry the same
    /// keep-vs-close semantics as [`read_frame`].
    pub fn next_frame(&mut self) -> Result<Option<Json>, FrameError> {
        if self.buf.len() < HEADER_BYTES {
            return Ok(None);
        }
        let mut header = [0u8; HEADER_BYTES];
        header.copy_from_slice(&self.buf[..HEADER_BYTES]);
        let len = u32::from_be_bytes(header) as usize;
        if len == 0 {
            self.buf.drain(..HEADER_BYTES);
            return Err(FrameError::Garbage("empty frame body".into()));
        }
        if len > self.max {
            return Err(FrameError::Oversized(len));
        }
        if self.buf.len() < HEADER_BYTES + len {
            return Ok(None);
        }
        let body: Vec<u8> = self.buf[HEADER_BYTES..HEADER_BYTES + len].to_vec();
        self.buf.drain(..HEADER_BYTES + len);
        match std::str::from_utf8(&body) {
            Ok(text) => match parse(text) {
                Ok(j) => Ok(Some(j)),
                Err(e) => Err(FrameError::Garbage(e)),
            },
            Err(e) => Err(FrameError::Garbage(format!("frame body not UTF-8: {e}"))),
        }
    }
}

/// A `{"ok": false, "error": ...}` reply frame body.
pub fn error_reply(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// A `{"ok": true, ...extra}` reply frame body.
pub fn ok_reply(extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(extra);
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let msg = Json::obj(vec![
            ("verb", Json::Str("status".into())),
            ("proto", Json::Num(PROTOCOL_VERSION as f64)),
        ]);
        let bytes = frame_bytes(&msg);
        assert_eq!(bytes.len(), 4 + msg.to_string().len());
        let mut cursor = std::io::Cursor::new(bytes);
        let back = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert_eq!(back, msg);
        // Clean EOF at the frame boundary.
        assert!(read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().is_none());
    }

    #[test]
    fn torn_and_oversized_and_garbage_frames() {
        // Torn: header promises 100 bytes, stream ends after 3.
        let mut torn = 100u32.to_be_bytes().to_vec();
        torn.extend_from_slice(b"abc");
        let mut cursor = std::io::Cursor::new(torn);
        match read_frame(&mut cursor, MAX_FRAME_BYTES) {
            Err(FrameError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof)
            }
            other => panic!("{other:?}"),
        }
        // Oversized: header alone condemns the frame.
        let big = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes().to_vec();
        let mut cursor = std::io::Cursor::new(big);
        assert!(matches!(
            read_frame(&mut cursor, MAX_FRAME_BYTES),
            Err(FrameError::Oversized(_))
        ));
        // Garbage: well-framed, unparseable body — then the NEXT frame
        // on the same stream still decodes (framing survived).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&5u32.to_be_bytes());
        bytes.extend_from_slice(b"{oops");
        encode_frame(&Json::obj(vec![("ok", Json::Bool(true))]), &mut bytes);
        let mut cursor = std::io::Cursor::new(bytes);
        assert!(matches!(
            read_frame(&mut cursor, MAX_FRAME_BYTES),
            Err(FrameError::Garbage(_))
        ));
        let next = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().unwrap();
        assert_eq!(next.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn incremental_reader_handles_split_frames() {
        let a = Json::obj(vec![("verb", Json::Str("ack".into())), ("seq", Json::Num(1.0))]);
        let b = Json::obj(vec![("verb", Json::Str("ack".into())), ("seq", Json::Num(2.0))]);
        let mut bytes = Vec::new();
        encode_frame(&a, &mut bytes);
        encode_frame(&b, &mut bytes);
        let mut r = FrameReader::new(MAX_FRAME_BYTES);
        // Drip-feed one byte at a time; frames pop exactly when whole.
        let mut seen = Vec::new();
        for byte in bytes {
            r.feed(&[byte]);
            while let Some(f) = r.next_frame().unwrap() {
                seen.push(f);
            }
        }
        assert_eq!(seen, vec![a, b]);
    }

    #[test]
    fn addr_parse() {
        assert_eq!(
            ListenAddr::parse("127.0.0.1:4321").unwrap(),
            ListenAddr::Tcp("127.0.0.1:4321".into())
        );
        assert_eq!(
            ListenAddr::parse("unix:/tmp/tune.sock").unwrap(),
            ListenAddr::Unix(PathBuf::from("/tmp/tune.sock"))
        );
        assert!(ListenAddr::parse("unix:").is_err());
        assert!(ListenAddr::parse("no-port").is_err());
        assert!(ListenAddr::parse(":123").is_err());
        assert!(ListenAddr::parse("host:notaport").is_err());
    }
}
