//! N-way sharded hub: many [`ExperimentHub`]s over ONE worker fleet.
//!
//! The single-hub serve loop has a structural ceiling: every
//! submission, status render and completion event funnels through one
//! thread. A [`ShardedHub`] splits the *coordinator* state N ways —
//! experiments are hashed by name to a shard, each shard thread runs
//! its own [`ExperimentHub`] over a [`SharedPoolClient`] view of one
//! shared [`SharedPool`] — while the *workers* stay one fleet, so
//! shards contend for steps, not threads.
//!
//! Routing is deterministic (FNV-1a of the experiment name, mod N):
//! concurrent submissions of the same name always land on the same
//! shard, whose single-threaded command loop admits exactly one of
//! them. Per-shard durable state lives under `root/shards/<k>/`, so
//! two shards never write the same path.
//!
//! Status is pull-free: each shard renders its status at most every
//! 100 ms and publishes into its [`StatusCell`] only when the rendered
//! text actually changed; readers aggregate the cached cells without
//! ever touching a shard thread. The cell's version counter is what
//! `watch` streams diff against.
//!
//! [`SharedPoolClient`]: crate::coordinator::executor::SharedPoolClient

// The unwraps here are deliberate: lock poisoning (a panicked shard or
// reader) is unrecoverable for the process, matching the rest of the
// coordinator. The file opts out of the workspace unwrap gate.
#![allow(clippy::unwrap_used)]

// lint:allow(clock): shard loops slice real wall time (run_for budgets,
// status heartbeats, command-channel parks) — this module is part of
// the wall-clock serving substrate, like executor.rs.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::executor::SharedPool;
use crate::coordinator::hub::{ExperimentHub, Submission};
use crate::coordinator::runner::ExperimentResult;
use crate::ray::Resources;
use crate::util::json::Json;

/// How long a shard drives its hub between command-channel drains.
const RUN_SLICE: Duration = Duration::from_millis(25);
/// Minimum interval between status renders (change detection requires
/// a render; this bounds how much CPU an idle-ish shard spends on it).
const RENDER_EVERY: Duration = Duration::from_millis(100);
/// Bounded per-shard command queue: submits beyond this shed with a
/// retryable error instead of queueing unboundedly.
const SHARD_QUEUE_DEPTH: usize = 64;

/// FNV-1a 64-bit — a stable, dependency-free name hash. Experiment →
/// shard routing must be deterministic across processes and runs
/// (SipHash's per-process keys would scatter re-submissions).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Which shard (of `n`) owns the experiment with this name.
pub fn shard_of(name: &str, n: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    (fnv1a(name.as_bytes()) % n as u64) as usize
}

/// Filesystem-safe experiment-directory name: alphanumerics, `-`, `_`
/// and `.` pass through; everything else becomes `_`. Shared by the
/// sharded hub and the legacy file-queue serve path so both layouts
/// agree on directory names.
pub fn sanitize_name(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Build a hub submission from a parsed spec file and a resolved
/// trainable factory — the one translation both the socket server and
/// the legacy file-queue ingest use, so the two admission paths can
/// never drift.
pub fn submission_from_spec(
    file: crate::coordinator::spec_file::SpecFile,
    factory: crate::trainable::TrainableFactory,
) -> Submission {
    let mut sub = Submission::new(file.spec, file.space, file.scheduler, file.search, factory);
    sub.cluster = file.cluster;
    sub.autoscale = file.autoscale;
    sub.weight = file.weight;
    sub
}

/// One shard's published status snapshot, read lock-free-ish by
/// aggregators (version first, then the cached JSON under a mutex).
struct StatusCell {
    /// Bumped once per *changed* publish; watchers diff against it.
    version: AtomicU64,
    /// The shard hub's last rendered `status_json`.
    json: Mutex<Json>,
}

enum ShardCmd {
    Submit { sub: Submission, reply: mpsc::Sender<Result<(), String>> },
    Stop { drain: bool },
}

struct Shard {
    tx: SyncSender<ShardCmd>,
    cell: Arc<StatusCell>,
}

/// Configuration for a [`ShardedHub`].
pub struct ShardedHubOptions {
    /// Number of hub shards (clamped to ≥ 1).
    pub shards: usize,
    /// Worker threads in the one shared fleet (ignored when
    /// `worker_caps` is set — then one worker per capacity vector).
    pub workers: usize,
    /// Per-worker capacity vectors (None = capacity-oblivious fleet).
    pub worker_caps: Option<Vec<Resources>>,
    /// Global live-trial budget, split evenly across shards
    /// (0 = unbounded).
    pub max_live: usize,
    /// Durable root: experiment `k` of shard `s` persists under
    /// `root/shards/<s>/experiments/<name>`. None = in-memory only.
    pub root: Option<PathBuf>,
    /// Snapshot cadence forwarded to each submission that has no
    /// explicit cadence of its own.
    pub snapshot_every: u64,
}

impl Default for ShardedHubOptions {
    fn default() -> Self {
        ShardedHubOptions {
            shards: 1,
            workers: 4,
            worker_caps: None,
            max_live: 0,
            root: None,
            snapshot_every: 50,
        }
    }
}

/// N hub shards over one shared worker fleet. `submit` / `status_json`
/// / `stop` all take `&self` — the struct is shared across server
/// connection threads behind an `Arc`.
pub struct ShardedHub {
    shards: Vec<Shard>,
    joins: Mutex<Vec<JoinHandle<Vec<(String, ExperimentResult)>>>>,
    stopping: AtomicBool,
    max_live: usize,
    workers: usize,
    root: Option<PathBuf>,
    snapshot_every: u64,
    /// Declared last: the fleet drops (joining its worker threads)
    /// only after the shard joins above have retired every hub.
    _pool: SharedPool,
}

impl ShardedHub {
    /// Spawn the fleet and `opts.shards` shard threads.
    pub fn new(opts: ShardedHubOptions) -> ShardedHub {
        let n = opts.shards.max(1);
        let pool = match &opts.worker_caps {
            Some(caps) => SharedPool::with_capacities(caps.clone()),
            None => SharedPool::new(opts.workers),
        };
        let workers = pool.num_workers();
        let per_shard_live = if opts.max_live == 0 { 0 } else { opts.max_live.div_ceil(n) };
        let frac = 1.0 / n as f64;
        let mut shards = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for k in 0..n {
            let (tx, rx) = mpsc::sync_channel(SHARD_QUEUE_DEPTH);
            let cell = Arc::new(StatusCell {
                version: AtomicU64::new(0),
                json: Mutex::new(Json::Null),
            });
            let hub = ExperimentHub::over_client(pool.client(frac), per_shard_live);
            let cell2 = Arc::clone(&cell);
            let join = std::thread::Builder::new()
                .name(format!("tune-shard-{k}"))
                .spawn(move || shard_main(hub, rx, &cell2))
                .expect("spawn shard thread");
            shards.push(Shard { tx, cell });
            joins.push(join);
        }
        ShardedHub {
            shards,
            joins: Mutex::new(joins),
            stopping: AtomicBool::new(false),
            max_live: opts.max_live,
            workers,
            root: opts.root,
            snapshot_every: opts.snapshot_every,
            _pool: pool,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// True once [`Self::stop`] has been called (new submissions are
    /// rejected from then on).
    pub fn stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }

    /// Route a submission to its name's shard and wait for the
    /// admission verdict. Errors are per-submission: a full shard
    /// queue ("busy"), a duplicate name, or a hub setup failure never
    /// affects other experiments.
    pub fn submit(&self, mut sub: Submission) -> Result<(), String> {
        if self.stopping() {
            return Err("server is draining; submission rejected".into());
        }
        let name = sub.spec.name.clone();
        if name.is_empty() {
            return Err("experiment name must not be empty".into());
        }
        let k = shard_of(&name, self.shards.len());
        if sub.experiment_dir.is_none() {
            if let Some(root) = &self.root {
                sub.experiment_dir = Some(
                    root.join("shards")
                        .join(k.to_string())
                        .join("experiments")
                        .join(sanitize_name(&name)),
                );
                sub.snapshot_every = self.snapshot_every;
            }
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        match self.shards[k].tx.try_send(ShardCmd::Submit { sub, reply: reply_tx }) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                return Err(format!(
                    "shard {k} is busy ({SHARD_QUEUE_DEPTH} commands queued); retry"
                ))
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(format!("shard {k} has shut down"))
            }
        }
        match reply_rx.recv_timeout(Duration::from_secs(30)) {
            Ok(verdict) => verdict,
            Err(_) => Err(format!("shard {k} did not answer the submission")),
        }
    }

    /// Sum of per-shard status versions — monotonic, bumps whenever
    /// any shard's published status changes.
    pub fn status_version(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.cell.version.load(Ordering::SeqCst))
            .sum()
    }

    /// One shard's `(version, cached status)` pair, for watch deltas.
    /// Returns `Json::Null` status before the shard's first publish.
    pub fn shard_status(&self, k: usize) -> (u64, Json) {
        let cell = &self.shards[k].cell;
        let v = cell.version.load(Ordering::SeqCst);
        let j = cell.json.lock().unwrap().clone();
        (v, j)
    }

    /// Aggregated status assembled from the per-shard cached cells
    /// (no shard round-trips): experiments in shard order, each
    /// annotated with its `shard`, under pool-wide header fields.
    pub fn status_json(&self) -> Json {
        let mut experiments = Vec::new();
        let mut active = 0usize;
        let mut version = 0u64;
        for (k, shard) in self.shards.iter().enumerate() {
            version += shard.cell.version.load(Ordering::SeqCst);
            let j = shard.cell.json.lock().unwrap().clone();
            active += j.get("active").and_then(Json::as_f64).unwrap_or(0.0) as usize;
            if let Some(arr) = j.get("experiments").and_then(Json::as_arr) {
                for e in arr {
                    if let Some(obj) = e.as_obj() {
                        let mut obj = obj.clone();
                        obj.insert("shard".to_string(), Json::Num(k as f64));
                        experiments.push(Json::Obj(obj));
                    }
                }
            }
        }
        Json::obj(vec![
            ("workers", Json::Num(self.workers as f64)),
            ("shards", Json::Num(self.shards.len() as f64)),
            ("max_live", Json::Num(self.max_live as f64)),
            ("active", Json::Num(active as f64)),
            ("version", Json::Num(version as f64)),
            ("experiments", Json::Arr(experiments)),
        ])
    }

    /// Number of experiments still active across all shards, per the
    /// cached cells.
    pub fn active_count(&self) -> usize {
        self.status_json()
            .get("active")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as usize
    }

    /// Ask every shard to stop. `drain` = finish in-flight experiments
    /// first; otherwise they are abandoned (their durable snapshots
    /// survive for `tune run --resume`). Idempotent.
    pub fn stop(&self, drain: bool) {
        self.stopping.store(true, Ordering::SeqCst);
        for s in &self.shards {
            // `send` (not try_send): stop must get through even when
            // the command queue is momentarily full. The shard drains
            // its queue every RUN_SLICE, so this blocks briefly at
            // worst; a disconnected shard has already stopped.
            let _ = s.tx.send(ShardCmd::Stop { drain });
        }
    }

    /// True when every shard thread has exited (after a stop, drained
    /// or not). The accept loop polls this to know when to retire.
    pub fn shards_finished(&self) -> bool {
        self.joins.lock().unwrap().iter().all(|j| j.is_finished())
    }

    /// Join every shard thread and collect `(name, result)` pairs
    /// (shard order, submission order within a shard). Call after
    /// [`Self::stop`]; a second call returns an empty vec.
    pub fn wait(&self) -> Vec<(String, ExperimentResult)> {
        let joins: Vec<_> = self.joins.lock().unwrap().drain(..).collect();
        let mut all = Vec::new();
        for j in joins {
            if let Ok(results) = j.join() {
                all.extend(results);
            }
        }
        all
    }
}

impl Drop for ShardedHub {
    fn drop(&mut self) {
        self.stop(false);
        let _ = self.wait();
        // `_pool` drops last (field order), joining the worker fleet
        // now that no shard hub holds a handle.
    }
}

fn apply_cmd(
    cmd: ShardCmd,
    hub: &mut ExperimentHub,
    seen: &mut BTreeSet<String>,
    stopping: &mut bool,
    drain: &mut bool,
) {
    match cmd {
        ShardCmd::Submit { sub, reply } => {
            let verdict = if *stopping {
                Err("server is draining; submission rejected".into())
            } else {
                let name = sub.spec.name.clone();
                if seen.contains(&name) {
                    Err(format!("experiment {name:?} already submitted"))
                } else {
                    hub.submit(sub).map(|_| {
                        seen.insert(name);
                    })
                }
            };
            // A vanished submitter (timed out, disconnected) is its
            // problem; the admission above already happened.
            let _ = reply.send(verdict);
        }
        ShardCmd::Stop { drain: d } => {
            *stopping = true;
            *drain = d;
        }
    }
}

/// Render the hub status and publish it into the cell iff it changed
/// since the last publish; the version counter bumps only on change,
/// which is exactly what watch-delta diffing needs.
fn publish(hub: &ExperimentHub, cell: &StatusCell, last_text: &mut String) {
    let status = hub.status_json();
    let text = status.to_string();
    if text != *last_text {
        *cell.json.lock().unwrap() = status;
        cell.version.fetch_add(1, Ordering::SeqCst);
        *last_text = text;
    }
}

fn shard_main(
    mut hub: ExperimentHub,
    rx: Receiver<ShardCmd>,
    cell: &StatusCell,
) -> Vec<(String, ExperimentResult)> {
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut stopping = false;
    let mut drain = true;
    let mut last_text = String::new();
    let mut last_render = Instant::now();
    publish(&hub, cell, &mut last_text);
    loop {
        // Apply everything already queued.
        let mut applied = false;
        loop {
            match rx.try_recv() {
                Ok(cmd) => {
                    apply_cmd(cmd, &mut hub, &mut seen, &mut stopping, &mut drain);
                    applied = true;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Owner gone: finish what is running, then exit.
                    stopping = true;
                    break;
                }
            }
        }
        let active = hub.run_for(RUN_SLICE);
        if applied || last_render.elapsed() >= RENDER_EVERY {
            last_render = Instant::now();
            publish(&hub, cell, &mut last_text);
        }
        if stopping && (!drain || !active) {
            break;
        }
        if !active && !stopping {
            // Idle: park on the command channel instead of spinning.
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(cmd) => apply_cmd(cmd, &mut hub, &mut seen, &mut stopping, &mut drain),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => stopping = true,
            }
        }
    }
    // Publish the terminal snapshot (every experiment's final state)
    // BEFORE draining results out of the hub, so late status readers
    // see "finished", not an empty hub.
    publish(&hub, cell, &mut last_text);
    hub.take_results()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_routing_is_deterministic_and_spread() {
        let names: Vec<String> = (0..256).map(|i| format!("exp-{i}")).collect();
        let mut counts = vec![0usize; 4];
        for n in &names {
            let k = shard_of(n, 4);
            assert_eq!(k, shard_of(n, 4)); // stable
            counts[k] += 1;
        }
        // FNV over distinct names must not collapse onto few shards.
        assert!(counts.iter().all(|&c| c > 16), "skewed: {counts:?}");
        assert_eq!(shard_of("anything", 1), 0);
    }

    #[test]
    fn sanitize_name_keeps_safe_chars() {
        assert_eq!(sanitize_name("exp-1_ok.v2"), "exp-1_ok.v2");
        assert_eq!(sanitize_name("a/b c:d"), "a_b_c_d");
    }
}
