//! The network control plane: `tune serve` as a socket service.
//!
//! Three layers, bottom up:
//!
//! * [`protocol`] — length-prefixed JSON frames over TCP or Unix
//!   sockets, with an error taxonomy that distinguishes recoverable
//!   garbage from unrecoverable framing loss.
//! * [`shard`] — [`ShardedHub`]: N `ExperimentHub` shards over ONE
//!   shared worker fleet, experiments routed by a deterministic name
//!   hash, status aggregated from per-shard cached snapshots.
//! * [`server`] / [`client`] — the accept loop, verb dispatch, watch
//!   streaming with slow-consumer shedding, and the matching client.
//!
//! See ARCHITECTURE.md ("The network control plane") for the frame
//! format, verb table and drain semantics.

pub mod client;
pub mod protocol;
pub mod server;
pub mod shard;

pub use client::{wait_until_up, Client};
pub use protocol::ListenAddr;
pub use server::{serve, ServeOptions, ServerHandle, WorkloadResolver};
pub use shard::{shard_of, ShardedHub, ShardedHubOptions};
