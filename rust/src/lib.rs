//! # tune — a reproduction of *Tune: A Research Platform for Distributed
//! # Model Selection and Training* (Liaw et al., 2018)
//!
//! A rust coordinator implementing the paper's narrow-waist APIs between
//! training scripts and hyperparameter-search algorithms, executing over
//! a Ray-like substrate, with the actual training workloads AOT-compiled
//! from JAX/Pallas to HLO and executed through PJRT — python never runs
//! on the request path.
//!
//! * [`coordinator`] — trials, the scheduler API, Table 1's algorithms
//!   (FIFO / HyperBand / ASHA / median stopping / PBT), search
//!   (grid / random / TPE), the runner, `run_experiments`.
//! * [`ray`] — the substrate: resources, cluster, two-level placement,
//!   object store, fault injection.
//! * [`trainable`] — the user API (class-based + cooperative function),
//!   synthetic benchmark workloads.
//! * [`runtime`] — PJRT: load HLO artifacts, drive real training steps.
//! * [`net`] — the serve control plane: framed socket protocol,
//!   sharded hub, server and client.
//! * [`checkpoint`] / [`logger`] — durability and observability.
//! * [`util`] — JSON, deterministic RNG, bench/prop harnesses.
//!
//! ## Quickstart (§4.3 of the paper)
//!
//! ```
//! use tune::coordinator::{run_experiments, ExperimentSpec, Mode,
//!                         RunOptions, SchedulerKind, SearchKind};
//! use tune::coordinator::spec::SpaceBuilder;
//! use tune::trainable::{factory, synthetic::CurveTrainable};
//!
//! let mut spec = ExperimentSpec::named("quickstart");
//! spec.metric = "accuracy".into();
//! spec.mode = Mode::Max;
//! spec.max_iterations_per_trial = 50;
//! let space = SpaceBuilder::new()
//!     .grid_f64("lr", &[0.01, 0.001, 0.0001])
//!     .grid_str("activation", &["relu", "tanh"])
//!     .build();
//! let result = run_experiments(
//!     spec, space,
//!     SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 50 },
//!     SearchKind::Grid,
//!     factory(|c, s| Box::new(CurveTrainable::new(c, s))),
//!     RunOptions::default(),
//! );
//! assert_eq!(result.trials.len(), 6);
//! ```

#![warn(missing_docs)]

pub mod checkpoint;
pub mod coordinator;
pub mod logger;
pub mod net;
pub mod ray;
pub mod runtime;
pub mod trainable;
pub mod util;
