//! `tune` CLI — experiment launcher, multi-experiment server and
//! analysis tool.
//!
//! Subcommands:
//!   run        run a model-selection experiment (sim or jax workloads)
//!   serve      long-running multi-experiment coordinator (shared pool)
//!   submit     queue a spec file onto a running `tune serve`
//!   status     print a server's published experiment status
//!   stop       ask a running `tune serve` to shut down
//!   shootout   compare all schedulers on the synthetic benchmark (C1)
//!   loc-table  regenerate the paper's Table 1 (LoC per algorithm)
//!   analyze    summarize a JSONL log directory
//!
//! Hand-rolled argument parsing: the offline dependency set has no clap.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tune::coordinator::hub::{ExperimentHub, Submission};
use tune::coordinator::persist::write_atomic;
use tune::coordinator::spec::{SearchSpace, SpaceBuilder};
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
    SpecFile,
};
use tune::logger::ExperimentAnalysis;
use tune::net::{
    serve, wait_until_up, Client, ListenAddr, ServeOptions, ShardedHub, ShardedHubOptions,
    WorkloadResolver,
};
use tune::ray::{AutoscalePolicy, Cluster, NodeTemplate, Resources};
use tune::runtime::{Manifest, PjrtService};
use tune::trainable::jax_model::jax_factory;
use tune::trainable::synthetic::{CurveTrainable, NonStationaryTrainable};
use tune::trainable::{factory, TrainableFactory};
use tune::util::loc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            usage();
            return;
        }
    };
    let flags = Flags::parse(&rest);
    match cmd {
        "run" => cmd_run(&flags),
        "serve" => cmd_serve(&flags),
        "submit" => cmd_submit(&flags),
        "status" => cmd_status(&flags),
        "stop" => cmd_stop(&flags),
        "shootout" => cmd_shootout(&flags),
        "loc-table" => cmd_loc_table(),
        "analyze" => cmd_analyze(&flags),
        "help" | "--help" | "-h" => usage(),
        other => {
            eprintln!("unknown command {other:?}\n");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    println!(
        "tune — distributed model selection (Liaw et al. 2018 reproduction)

USAGE: tune <command> [--flag value ...]

COMMANDS
  run        --spec FILE.json   declarative experiment spec (see configs/)
             --workload curve|jax-mlp|jax-tlm|pbt-sim  (default curve)
             --scheduler fifo|asha|hyperband|median|pbt (default asha)
             --search grid|random|tpe|evolution          (default random)
             --samples N        trials (default 32)
             --iters N          max iterations per trial (default 81)
             --nodes N          cluster nodes (default 4)
             --cpus-per-node F  (default 8)
             --gpus-per-node F  (default 0)
             --cpus-per-trial F resource demand per trial (default 1)
             --gpus-per-trial F fractional GPUs allowed (default 0; a
                                demand no node can hold fails fast)
             --autoscale-max-nodes N  enable elastic autoscaling up to N
                                nodes (template = the per-node shape);
                                idle nodes drain and retire, their
                                trials checkpoint-then-requeue
             --autoscale-min-nodes N    never drain below N (default 1)
             --autoscale-up-after N     pressure ticks per scale-up (4)
             --autoscale-down-after N   idle ticks before a drain (200)
             --autoscale-down-util F    drain nodes at or below this
                                utilization fraction (default 0.0:
                                fully idle only)
             --node-price F     virtual $/hour per node (cluster and
                                autoscale template); enables cost
                                accrual on the virtual clock
             --hw-aware         learned-throughput placement and
                                cost-aware autoscaling (online
                                steps/sec profiles per workload class
                                and node shape)
             --max-cost F       hard virtual-dollar budget: the run
                                fails fast once accrued cost reaches it
             --exec sim|threads|pool  executor (default per workload)
             --workers N        pool worker threads (default 4)
             --worker-cpus F --worker-gpus F  per-worker capacity
                                vectors for --exec pool: admission is a
                                vector fit instead of a slot count
             --metric NAME --mode min|max
             --log-dir DIR      write JSONL logs (no durability)
             --exp-dir DIR      durable experiment directory: JSONL logs,
                                spilled checkpoints and periodic atomic
                                state snapshots (crash-safe)
             --resume           continue the experiment in --exp-dir from
                                its latest snapshot
             --snapshot-every N snapshot cadence in results (default 50)
             --ckpt-mem-mb N    cap checkpoint-store memory residency at
                                N MiB (cold chunks spill to --exp-dir's
                                chunk tier; 0 = unbounded)
             --seed N
  serve      --listen ADDR      serve the control plane on a socket:
                                HOST:PORT (TCP, port 0 = pick) or
                                unix:/path.sock; clients connect with
                                submit/status/stop --addr ADDR
             --shards N         hub shards over the one worker fleet
                                (experiments hashed by name; default 1)
             --exp-dir DIR      durable root; results land under
                                DIR/shards/<k>/experiments/<name>/
             --workers N        pool worker threads (default 4)
             --worker-cpus F --worker-gpus F  per-worker capacities:
                                admission + fair share become resource
                                vectors instead of slot counts
             --max-live N       global live-trial budget split across
                                experiments (default 4 x workers)
             (without --listen: DEPRECATED file-queue mode — specs
              dropped into DIR/queue/ are ingested, status published
              to DIR/serve.status.json; --drain exits once idle)
  submit     --addr ADDR --spec FILE.json
                                validate FILE and submit it over the
                                socket (spec field \"weight\" sets its
                                share); --exp-dir DIR uses the
                                deprecated file queue instead
  status     --addr ADDR        print the server's experiment table
                                (--exp-dir DIR reads the deprecated
                                status file instead)
  stop       --addr ADDR        ask the server to shut down; --no-drain
                                abandons in-flight experiments instead
                                of finishing them (--exp-dir DIR writes
                                the deprecated stop file instead)
  shootout   --samples N --iters N   compare all schedulers (sim, C1)
  loc-table  regenerate Table 1 (lines of code per algorithm)
  analyze    --log-dir DIR --metric NAME --mode min|max
             (accepts an --exp-dir experiment directory too; prints its
              manifest and snapshot status when present)"
    );
}

struct Flags(BTreeMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut m = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                m.insert(key.to_string(), val);
            } else {
                eprintln!("ignoring stray argument {a:?}");
            }
            i += 1;
        }
        Flags(m)
    }
    fn get(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
    fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.0.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.0.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn scheduler_kind(name: &str, iters: u64, space: &SearchSpace) -> SchedulerKind {
    match name {
        "fifo" => SchedulerKind::Fifo,
        "asha" => SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: iters },
        "hyperband" => SchedulerKind::HyperBand { max_t: iters, eta: 3.0 },
        "median" | "median_stopping" => {
            SchedulerKind::MedianStopping { grace_period: iters / 10 + 1, min_samples: 3 }
        }
        "pbt" => SchedulerKind::Pbt {
            perturbation_interval: (iters / 10).max(1),
            space: space.clone(),
        },
        other => {
            eprintln!("unknown scheduler {other:?}");
            std::process::exit(2);
        }
    }
}

/// `--worker-cpus`/`--worker-gpus`: per-worker capacity vectors for the
/// pool executor (None unless at least one flag is present).
fn worker_caps(flags: &Flags, workers: usize) -> Option<Vec<Resources>> {
    if !flags.0.contains_key("worker-cpus") && !flags.0.contains_key("worker-gpus") {
        return None;
    }
    let cap = Resources::cpu_gpu(
        flags.get_f64("worker-cpus", 1.0),
        flags.get_f64("worker-gpus", 0.0),
    );
    Some(vec![cap; workers.max(1)])
}

/// `--ckpt-mem-mb N` caps the checkpoint store's memory residency at N
/// MiB; cold chunks spill to the experiment directory's chunk tier.
fn ckpt_mem_budget(flags: &Flags) -> Option<usize> {
    let mb = flags.get_u64("ckpt-mem-mb", 0);
    if mb == 0 {
        None
    } else {
        Some((mb as usize) << 20)
    }
}

/// `--autoscale-max-nodes N` (plus the per-node shape flags) enables an
/// elastic autoscaler whose template matches the cluster's node shape;
/// `--node-price F` prices that template in virtual $/hour.
fn autoscale_policy(
    flags: &Flags,
    node_shape: &Resources,
    min_nodes: usize,
) -> Option<AutoscalePolicy> {
    let max_nodes = flags.get_u64("autoscale-max-nodes", 0) as usize;
    if max_nodes == 0 {
        return None;
    }
    let templates = match flags.0.get("node-price") {
        Some(_) => vec![NodeTemplate {
            shape: node_shape.clone(),
            price_per_hour: flags.get_f64("node-price", 0.0),
        }],
        None => Vec::new(),
    };
    let policy = AutoscalePolicy {
        node_template: node_shape.clone(),
        templates,
        min_nodes: flags.get_u64("autoscale-min-nodes", min_nodes as u64) as usize,
        max_nodes,
        scale_up_after: flags.get_u64("autoscale-up-after", 4),
        scale_down_after: flags.get_u64("autoscale-down-after", 200),
        scale_down_util: flags.get_f64("autoscale-down-util", 0.0),
    };
    if let Err(e) = policy.validate() {
        eprintln!("bad --autoscale-* flags: {e}");
        std::process::exit(2);
    }
    Some(policy)
}

/// `--exec`/`--workers` override of a workload's default executor.
fn exec_override(flags: &Flags, default: ExecMode) -> ExecMode {
    match flags.0.get("exec").map(|s| s.as_str()) {
        None => default,
        Some("sim") => ExecMode::Sim,
        Some("threads") => ExecMode::Threads,
        Some("pool") => ExecMode::Pool { workers: flags.get_u64("workers", 4) as usize },
        Some(other) => {
            eprintln!("unknown executor {other:?} (expected sim|threads|pool)");
            std::process::exit(2);
        }
    }
}

fn search_kind(name: &str) -> SearchKind {
    match name {
        "grid" => SearchKind::Grid,
        "random" => SearchKind::Random,
        "tpe" => SearchKind::Tpe,
        "evolution" => SearchKind::Evolution,
        other => {
            eprintln!("unknown search {other:?}");
            std::process::exit(2);
        }
    }
}

fn cmd_run(flags: &Flags) {
    if let Some(path) = flags.0.get("spec") {
        return run_spec_file(std::path::Path::new(path), flags);
    }
    let workload = flags.get("workload", "curve");
    let iters = flags.get_u64("iters", 81);
    let samples = flags.get_u64("samples", 32) as usize;
    let nodes = flags.get_u64("nodes", 4) as usize;
    let cpus = flags.get_f64("cpus-per-node", 8.0);
    let gpus = flags.get_f64("gpus-per-node", 0.0);
    let seed = flags.get_u64("seed", 0);

    // Workload-specific defaults.
    let (space, fac, metric, mode, exec): (SearchSpace, TrainableFactory, String, Mode, ExecMode) =
        match workload.as_str() {
            "curve" => (
                SpaceBuilder::new()
                    .loguniform("lr", 1e-4, 1.0)
                    .uniform("momentum", 0.8, 0.99)
                    .build(),
                factory(|c, s| Box::new(CurveTrainable::new(c, s))),
                "accuracy".into(),
                Mode::Max,
                ExecMode::Sim,
            ),
            "pbt-sim" => (
                SpaceBuilder::new().loguniform("lr", 1e-4, 0.5).build(),
                factory(|c, s| Box::new(NonStationaryTrainable::new(c, s))),
                "score".into(),
                Mode::Max,
                ExecMode::Sim,
            ),
            "jax-mlp" | "jax-tlm" => {
                let family = if workload == "jax-mlp" { "mlp" } else { "tlm" };
                let acts: &[&str] =
                    if family == "mlp" { &["relu", "tanh"] } else { &["gelu", "relu"] };
                let svc = PjrtService::spawn(Manifest::default_dir())
                    .expect("artifacts missing: run `make artifacts`");
                (
                    SpaceBuilder::new()
                        .loguniform("lr", 1e-3, 1.0)
                        .uniform("momentum", 0.5, 0.99)
                        .choice_str("activation", acts)
                        .build(),
                    jax_factory(svc, if family == "mlp" { "mlp" } else { "tlm" }, 5),
                    "loss".into(),
                    Mode::Min,
                    ExecMode::Threads,
                )
            }
            other => {
                eprintln!("unknown workload {other:?}");
                std::process::exit(2);
            }
        };

    let mut spec = ExperimentSpec::named(&format!("run-{workload}"));
    spec.metric = flags.get("metric", &metric);
    spec.mode = match flags.get("mode", if mode == Mode::Max { "max" } else { "min" }).as_str() {
        "max" => Mode::Max,
        _ => Mode::Min,
    };
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = seed;
    spec.checkpoint_freq = (iters / 10).max(1);
    spec.resources_per_trial = Resources::cpu_gpu(
        flags.get_f64("cpus-per-trial", 1.0),
        flags.get_f64("gpus-per-trial", 0.0),
    );
    if let Err(e) = spec.resources_per_trial.validate_demand() {
        eprintln!("bad --cpus-per-trial/--gpus-per-trial: {e}");
        std::process::exit(2);
    }
    spec.hw_aware = flags.0.get("hw-aware").is_some();
    if flags.0.get("max-cost").is_some() {
        spec.budget_max_cost = Some(flags.get_f64("max-cost", 0.0));
    }
    let max_cost = spec.budget_max_cost;

    let sched = scheduler_kind(&flags.get("scheduler", "asha"), iters, &space);
    let search = search_kind(&flags.get("search", "random"));
    let exec = exec_override(flags, exec);
    let exec_label = exec.label();
    let node_shape = Resources::cpu_gpu(cpus, gpus);
    let node_price = flags.get_f64("node-price", 0.0);
    let cluster = if node_price > 0.0 {
        Cluster::heterogeneous_priced(
            (0..nodes.max(1)).map(|_| (node_shape.clone(), node_price)).collect(),
        )
    } else {
        Cluster::uniform(nodes, node_shape.clone())
    };
    let opts = RunOptions {
        cluster,
        exec,
        progress_every: flags.get_u64("progress-every", 200),
        log_dir: flags.0.get("log-dir").map(PathBuf::from),
        experiment_dir: flags.0.get("exp-dir").map(PathBuf::from),
        snapshot_every: flags.get_u64("snapshot-every", 50),
        resume: flags.0.get("resume").is_some(),
        autoscale: autoscale_policy(flags, &node_shape, 1),
        worker_caps: worker_caps(flags, flags.get_u64("workers", 4) as usize),
        checkpoint_mem_budget: ckpt_mem_budget(flags),
        shape_factors: None,
    };

    let label = sched.label();
    let res = run_experiments(spec, space, sched, search, fac, opts);
    if let Some(e) = &res.infeasible {
        eprintln!("\nexperiment failed fast (no trial launched): {e}");
        std::process::exit(1);
    }
    println!("\n== experiment complete ==");
    println!("scheduler            : {label}");
    println!("executor             : {exec_label}");
    println!("trials               : {}", res.trials.len());
    println!(
        "completed/stopped/err: {}/{}/{}",
        res.stats.completed, res.stats.stopped_early, res.stats.errored
    );
    println!("duration             : {:.1}s  (budget used {:.1} trial-s)", res.duration_s, res.budget_used_s);
    println!("checkpoints/restores : {}/{}", res.stats.checkpoints, res.stats.restores);
    if res.ckpt.saved > 0 {
        println!(
            "ckpt store           : {:.1}x dedup ({:.1} logical MiB, {:.1} physical MiB, {} chunks)",
            res.ckpt.dedup_ratio(),
            res.ckpt.logical_bytes as f64 / (1 << 20) as f64,
            res.ckpt.physical_bytes as f64 / (1 << 20) as f64,
            res.ckpt.unique_chunks
        );
    }
    println!(
        "placement            : {} local, {} spilled ({:.0}% spill)",
        res.placement.local,
        res.placement.spilled,
        res.placement.spill_fraction() * 100.0
    );
    println!(
        "mean utilization     : cpu {:.0}%, gpu {:.0}%",
        res.mean_cpu_utilization() * 100.0,
        res.mean_gpu_utilization() * 100.0
    );
    if res.stats.scale_ups + res.stats.scale_downs > 0 {
        println!(
            "autoscale            : +{} nodes, -{} nodes, {} preemption(s) (0 trials lost)",
            res.stats.scale_ups, res.stats.scale_downs, res.stats.preemptions
        );
    }
    if max_cost.is_some() || res.stats.cost_accrued > 0.0 {
        let budget = max_cost.map(|m| format!(" (budget ${m:.2})")).unwrap_or_default();
        println!("cost accrued         : ${:.4}{budget}", res.stats.cost_accrued);
    }
    if let (Some(best), Some(m)) = (res.best, res.best_metric()) {
        println!(
            "best trial           : #{best}  best metric {m:.4} after {} iters",
            res.trials[&best].iteration
        );
        println!(
            "best config          : {}",
            tune::coordinator::trial::config_str(&res.trials[&best].config)
        );
    }
}


/// Resolve a workload name to (factory, exec mode) without killing the
/// process: `tune serve` rejects a bad submission with this error while
/// other users' experiments keep running.
fn try_workload_factory(workload: &str) -> Result<(TrainableFactory, ExecMode), String> {
    Ok(match workload {
        "curve" => (
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            ExecMode::Sim,
        ),
        "pbt-sim" => (
            factory(|c, s| Box::new(NonStationaryTrainable::new(c, s))),
            ExecMode::Sim,
        ),
        "const" => (
            factory(|c, s| Box::new(tune::trainable::synthetic::ConstTrainable::new(c, s))),
            ExecMode::Sim,
        ),
        "jax-mlp" | "jax-tlm" => {
            let family: &'static str = if workload == "jax-mlp" { "mlp" } else { "tlm" };
            let svc = PjrtService::spawn(Manifest::default_dir()).map_err(|e| {
                format!("workload {workload:?} needs compiled artifacts (run `make artifacts`): {e:#}")
            })?;
            (jax_factory(svc, family, 5), ExecMode::Threads)
        }
        other => return Err(format!("unknown workload {other:?}")),
    })
}

/// CLI-fatal variant for the single-experiment `tune run` path.
fn workload_factory(workload: &str) -> (TrainableFactory, ExecMode) {
    try_workload_factory(workload).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

/// `tune run --spec file.json`: the declarative §4.3 form.
fn run_spec_file(path: &std::path::Path, flags: &Flags) {
    let f = tune::coordinator::SpecFile::load(path).unwrap_or_else(|e| {
        eprintln!("spec error: {e:#}");
        std::process::exit(2);
    });
    let (fac, exec) = workload_factory(&f.workload);
    let opts = RunOptions {
        cluster: f.cluster,
        exec: exec_override(flags, exec),
        progress_every: flags.get_u64("progress-every", 200),
        log_dir: flags
            .0
            .get("log-dir")
            .map(PathBuf::from)
            .or_else(|| Some(PathBuf::from(format!("tune_logs/{}", f.spec.name)))),
        experiment_dir: flags.0.get("exp-dir").map(PathBuf::from),
        snapshot_every: flags.get_u64("snapshot-every", 50),
        resume: flags.0.get("resume").is_some(),
        autoscale: f.autoscale,
        worker_caps: worker_caps(flags, flags.get_u64("workers", 4) as usize),
        checkpoint_mem_budget: ckpt_mem_budget(flags),
        shape_factors: None,
    };
    let label = f.scheduler.label();
    println!("spec {:?}: workload={} scheduler={} trials={}",
             f.spec.name, f.workload, label, f.spec.num_samples);
    let res = run_experiments(f.spec, f.space, f.scheduler, f.search, fac, opts);
    if let Some(e) = &res.infeasible {
        eprintln!("\nexperiment failed fast (no trial launched): {e}");
        std::process::exit(1);
    }
    println!("\n== {} complete: {} trials, best {} ==",
             label,
             res.trials.len(),
             res.best_metric().map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into()));
    if let Some(best) = res.best {
        println!("best config: {}",
                 tune::coordinator::trial::config_str(&res.trials[&best].config));
    }
}

/// File-name-safe slug of an experiment name (result directory).
fn sanitize_name(name: &str) -> String {
    let slug: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') { c } else { '-' })
        .collect();
    if slug.is_empty() { "experiment".into() } else { slug }
}

/// Queued spec files, oldest-name-first (submission order is the file
/// name order; `tune submit` preserves the caller's file name).
fn queued_specs(queue: &Path) -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(queue) else { return Vec::new() };
    let mut specs: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |e| e == "json"))
        .collect();
    specs.sort();
    specs
}

/// Pull every queued spec into the hub. Accepted specs are deleted from
/// the queue; malformed ones are renamed `*.rejected` with a note.
fn ingest_queue(
    hub: &mut ExperimentHub,
    root: &Path,
    queue: &Path,
    seen: &mut std::collections::BTreeSet<String>,
) -> usize {
    let mut accepted = 0;
    for path in queued_specs(queue) {
        let reject = |path: &Path, why: &str| {
            eprintln!("serve: rejecting {path:?}: {why}");
            let mut to = path.as_os_str().to_os_string();
            to.push(".rejected");
            std::fs::rename(path, &to).ok();
        };
        let f = match SpecFile::load(&path) {
            Ok(f) => f,
            Err(e) => {
                reject(&path, &format!("{e:#}"));
                continue;
            }
        };
        let name = sanitize_name(&f.spec.name);
        if seen.contains(&name) {
            reject(&path, "an experiment with this name was already served");
            continue;
        }
        // A bad workload (typo, missing jax artifacts) rejects this
        // submission only — it must never exit/panic the shared server.
        let factory = match try_workload_factory(&f.workload) {
            Ok((factory, _exec)) => factory,
            Err(e) => {
                reject(&path, &e);
                continue;
            }
        };
        let mut sub = Submission::new(f.spec, f.space, f.scheduler, f.search, factory);
        sub.cluster = f.cluster;
        sub.autoscale = f.autoscale;
        sub.weight = f.weight;
        sub.experiment_dir = Some(root.join("experiments").join(&name));
        match hub.submit(sub) {
            Ok(_) => {
                seen.insert(name.clone());
                std::fs::remove_file(&path).ok();
                println!("serve: admitted experiment {name:?}");
                accepted += 1;
            }
            Err(e) => reject(&path, &e),
        }
    }
    accepted
}

/// Minimum gap between `serve.status.json` rewrites in the file-queue
/// fallback. The table is a poll target, not a log: writers that dump
/// an identical file every 300 ms tick just burn fsyncs.
const STATUS_WRITE_EVERY: Duration = Duration::from_millis(250);

/// Rate-limited atomic publisher for the file-queue fallback's status
/// table: writes only when the rendered status actually changed, and at
/// most once per [`STATUS_WRITE_EVERY`] unless forced (final publish).
struct StatusPublisher {
    path: PathBuf,
    last_text: String,
    last_write: Instant,
}

impl StatusPublisher {
    fn new(root: &Path) -> StatusPublisher {
        StatusPublisher {
            path: root.join("serve.status.json"),
            last_text: String::new(),
            // lint:allow(clock): status rate limiting is wall-clock by definition.
            last_write: Instant::now(),
        }
    }

    fn publish(&mut self, hub: &ExperimentHub, force: bool) {
        let text = hub.status_json().to_string();
        if text == self.last_text {
            return; // nothing changed: an idle server writes nothing
        }
        // lint:allow(clock): status rate limiting is wall-clock by definition.
        let now = Instant::now();
        if !force && now.duration_since(self.last_write) < STATUS_WRITE_EVERY {
            return; // changed, but inside the window: next tick catches it
        }
        if let Err(e) = write_atomic(&self.path, &text) {
            eprintln!("serve: writing status file: {e}");
        }
        self.last_text = text;
        self.last_write = now;
    }
}

/// `tune serve`: the long-running multi-experiment coordinator. With
/// `--listen`, serves the socket control plane: N hub shards over one
/// shared worker fleet, clients speaking the framed protocol via
/// `submit`/`status`/`stop --addr`. Without it, falls back to the
/// DEPRECATED file-queue control plane (queue/ for submissions,
/// serve.status.json for status, serve.stop to shut down).
fn cmd_serve(flags: &Flags) {
    if let Some(listen) = flags.0.get("listen") {
        return cmd_serve_net(flags, listen);
    }
    eprintln!(
        "serve: file-queue mode is deprecated; prefer `tune serve --listen HOST:PORT` \
         (or unix:/path.sock) with `tune submit/status/stop --addr ADDR`"
    );
    let root = PathBuf::from(flags.get("exp-dir", "tune_serve"));
    let workers = flags.get_u64("workers", 4) as usize;
    let max_live = flags.get_u64("max-live", 4 * workers as u64) as usize;
    let drain = flags.0.contains_key("drain");
    let queue = root.join("queue");
    if let Err(e) = std::fs::create_dir_all(&queue) {
        eprintln!("serve: cannot create queue dir {queue:?}: {e}");
        std::process::exit(1);
    }
    let stop_file = root.join("serve.stop");
    std::fs::remove_file(&stop_file).ok(); // stale stop from a past server

    // --worker-cpus/--worker-gpus turn the shared pool capacity-aware:
    // live trainables are admitted by vector fit across all experiments
    // and fair share is dealt as resource-weighted slices.
    let mut hub = match worker_caps(flags, workers) {
        Some(caps) => ExperimentHub::with_capacities(caps, max_live),
        None => ExperimentHub::new(workers, max_live),
    };
    let mut seen = std::collections::BTreeSet::new();
    let mut publisher = StatusPublisher::new(&root);
    let mut served = 0usize;
    println!(
        "serve: {} workers, {} live-trial slots; queue at {:?}",
        workers, max_live, queue
    );
    loop {
        served += ingest_queue(&mut hub, &root, &queue, &mut seen);
        let any_active = hub.run_for(std::time::Duration::from_millis(300));
        publisher.publish(&hub, false);
        if stop_file.exists() {
            std::fs::remove_file(&stop_file).ok();
            println!(
                "serve: stop requested ({} experiment(s) still active)",
                hub.active_count()
            );
            break;
        }
        if drain && !any_active && queued_specs(&queue).is_empty() {
            println!("serve: drained ({served} experiment(s) served)");
            break;
        }
        if !any_active {
            // Nothing running: idle politely between queue polls.
            std::thread::sleep(std::time::Duration::from_millis(200));
        }
    }
    publisher.publish(&hub, true);
}

/// `tune serve --listen`: the sharded socket control plane.
fn cmd_serve_net(flags: &Flags, listen: &str) {
    let addr = match ListenAddr::parse(listen) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("serve: bad --listen {listen:?}: {e}");
            std::process::exit(2);
        }
    };
    let workers = flags.get_u64("workers", 4) as usize;
    let max_live = flags.get_u64("max-live", 4 * workers as u64) as usize;
    let shards = (flags.get_u64("shards", 1) as usize).max(1);
    let root = PathBuf::from(flags.get("exp-dir", "tune_serve"));
    if let Err(e) = std::fs::create_dir_all(&root) {
        eprintln!("serve: cannot create {root:?}: {e}");
        std::process::exit(1);
    }
    let hub = ShardedHub::new(ShardedHubOptions {
        shards,
        workers,
        worker_caps: worker_caps(flags, workers),
        max_live,
        root: Some(root.clone()),
        snapshot_every: flags.get_u64("snapshot-every", 50),
    });
    let resolver: WorkloadResolver =
        Arc::new(|workload| try_workload_factory(workload).map(|(factory, _exec)| factory));
    let handle = match serve(&addr, hub, resolver, ServeOptions::default()) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("serve: cannot listen on {addr}: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "serve: listening on {} ({} shard(s), {} workers, {} live-trial slots); results under {:?}",
        handle.addr(),
        shards,
        workers,
        max_live,
        root
    );
    let results = handle.join();
    println!("serve: stopped ({} experiment(s) completed)", results.len());
}

/// Parse a `--addr` socket address or exit with the parse error.
fn parse_addr_or_exit(cmd: &str, addr: &str) -> ListenAddr {
    ListenAddr::parse(addr).unwrap_or_else(|e| {
        eprintln!("{cmd}: bad --addr {addr:?}: {e}");
        std::process::exit(2);
    })
}

/// Dial a serve control plane (short retry window: the server the
/// caller just started may still be binding) or exit with the error.
fn connect_or_exit(cmd: &str, addr: &ListenAddr) -> Client {
    wait_until_up(addr, Duration::from_secs(2)).unwrap_or_else(|e| {
        eprintln!("{cmd}: {e}");
        std::process::exit(1);
    })
}

/// `tune submit`: validate a spec file and submit it to a server —
/// over the socket with `--addr`, or onto the DEPRECATED file queue
/// with `--exp-dir`.
fn cmd_submit(flags: &Flags) {
    let Some(spec_path) = flags.0.get("spec").map(PathBuf::from) else {
        eprintln!("submit: --spec FILE.json is required");
        std::process::exit(2);
    };
    // Validate before queueing so the user gets the parse error, not
    // a serve-side rejection note.
    let f = SpecFile::load(&spec_path).unwrap_or_else(|e| {
        eprintln!("submit: spec error: {e:#}");
        std::process::exit(2);
    });
    let text = match std::fs::read_to_string(&spec_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("submit: cannot re-read {spec_path:?}: {e}");
            std::process::exit(1);
        }
    };
    if let Some(addr) = flags.0.get("addr") {
        let addr = parse_addr_or_exit("submit", addr);
        let mut client = connect_or_exit("submit", &addr);
        match client.submit_spec_text(&text) {
            Ok(name) => println!(
                "submitted {:?} (experiment {:?}, weight {}) to {}",
                spec_path, name, f.weight, addr
            ),
            Err(e) => {
                eprintln!("submit: server rejected the spec: {e}");
                std::process::exit(1);
            }
        }
        return;
    }
    let root = PathBuf::from(flags.get("exp-dir", "tune_serve"));
    let queue = root.join("queue");
    if let Err(e) = std::fs::create_dir_all(&queue) {
        eprintln!("submit: cannot create queue dir {queue:?}: {e}");
        std::process::exit(1);
    }
    // Key the queue entry by the validated experiment name, not the
    // caller's file stem: two users submitting different experiments
    // from files that happen to share a name must not clobber each
    // other's still-queued submission.
    let target = queue.join(format!("{}.json", sanitize_name(&f.spec.name)));
    if target.exists() {
        eprintln!(
            "submit: an experiment named {:?} is already queued at {target:?}; \
             pick a different \"name\" or wait for the server to ingest it",
            f.spec.name
        );
        std::process::exit(1);
    }
    if let Err(e) = write_atomic(&target, &text) {
        eprintln!("submit: cannot queue spec at {target:?}: {e}");
        std::process::exit(1);
    }
    println!(
        "submitted {:?} (experiment {:?}, weight {}) to {:?}",
        spec_path, f.spec.name, f.weight, queue
    );
}

/// Render a status document (from either control plane) as the
/// standard experiment table. Sharded status (a `shards` field) grows
/// a per-experiment shard column; legacy file-queue status keeps the
/// original columns.
fn print_status_table(s: &tune::util::json::Json) {
    let num = |k: &str| s.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
    let sharded = s.get("shards").is_some();
    println!(
        "serve: {} workers, {} live-trial slots, {} active experiment(s){}",
        num("workers"),
        num("max_live"),
        num("active"),
        if sharded { format!(", {} shard(s)", num("shards")) } else { String::new() },
    );
    if sharded {
        println!(
            "{:<24} {:>5} {:>9} {:>7} {:>8} {:>8} {:>12} {:>6} {:>6}",
            "experiment", "shard", "state", "weight", "trials", "running", "best", "cpu%", "gpu%"
        );
        println!("{}", "-".repeat(94));
    } else {
        println!(
            "{:<24} {:>9} {:>7} {:>8} {:>8} {:>12} {:>6} {:>6}",
            "experiment", "state", "weight", "trials", "running", "best", "cpu%", "gpu%"
        );
        println!("{}", "-".repeat(88));
    }
    for e in s.get("experiments").and_then(|e| e.as_arr()).unwrap_or(&[]) {
        let get = |k: &str| e.get(k).and_then(|v| v.as_str()).unwrap_or("?").to_string();
        let n = |k: &str| e.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
        let frac = |k: &str| e.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) * 100.0;
        let best = e
            .get("best_metric")
            .and_then(|v| v.as_f64())
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "-".into());
        if sharded {
            println!(
                "{:<24} {:>5} {:>9} {:>7} {:>8} {:>8} {:>12} {:>6.0} {:>6.0}",
                get("name"),
                n("shard"),
                get("state"),
                n("weight"),
                n("trials"),
                n("running"),
                best,
                frac("util_cpu"),
                frac("util_gpu"),
            );
        } else {
            println!(
                "{:<24} {:>9} {:>7} {:>8} {:>8} {:>12} {:>6.0} {:>6.0}",
                get("name"),
                get("state"),
                n("weight"),
                n("trials"),
                n("running"),
                best,
                frac("util_cpu"),
                frac("util_gpu"),
            );
        }
    }
}

/// `tune status`: print the server's experiment table — over the
/// socket with `--addr`, or from the DEPRECATED published status file
/// with `--exp-dir`.
fn cmd_status(flags: &Flags) {
    if let Some(addr) = flags.0.get("addr") {
        let addr = parse_addr_or_exit("status", addr);
        let mut client = connect_or_exit("status", &addr);
        match client.status() {
            Ok(s) => print_status_table(&s),
            Err(e) => {
                eprintln!("status: {e}");
                std::process::exit(1);
            }
        }
        return;
    }
    let root = PathBuf::from(flags.get("exp-dir", "tune_serve"));
    let path = root.join("serve.status.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!(
            "status: no status file at {path:?} (is `tune serve --exp-dir {}` running?)",
            root.display()
        );
        std::process::exit(1);
    };
    let s = tune::util::json::parse(&text).unwrap_or_else(|e| {
        eprintln!("status: unreadable status file: {e}");
        std::process::exit(1);
    });
    print_status_table(&s);
}

/// `tune stop`: ask a running server to shut down — over the socket
/// with `--addr` (drains in-flight experiments unless `--no-drain`),
/// or via the DEPRECATED stop file with `--exp-dir`.
fn cmd_stop(flags: &Flags) {
    if let Some(addr) = flags.0.get("addr") {
        let addr = parse_addr_or_exit("stop", addr);
        let drain = !flags.0.contains_key("no-drain");
        let mut client = connect_or_exit("stop", &addr);
        if let Err(e) = client.stop(drain) {
            eprintln!("stop: {e}");
            std::process::exit(1);
        }
        println!(
            "stop requested at {addr} ({} in-flight experiments)",
            if drain { "draining" } else { "abandoning" }
        );
        return;
    }
    let root = PathBuf::from(flags.get("exp-dir", "tune_serve"));
    if let Err(e) = write_atomic(&root.join("serve.stop"), "stop\n") {
        eprintln!("stop: cannot write stop file under {root:?}: {e}");
        std::process::exit(1);
    }
    println!("stop requested for server at {:?}", root);
}

fn cmd_shootout(flags: &Flags) {
    let samples = flags.get_u64("samples", 64) as usize;
    let iters = flags.get_u64("iters", 81);
    let seed = flags.get_u64("seed", 0);
    println!("C1: schedulers on {samples} random curve trials, max_t={iters} (virtual time)\n");
    println!(
        "{:<18} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "scheduler", "best acc", "budget(s)", "duration(s)", "stopped", "results"
    );
    println!("{}", "-".repeat(78));
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    for name in ["fifo", "median", "asha", "hyperband"] {
        let mut spec = ExperimentSpec::named(&format!("shootout-{name}"));
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.num_samples = samples;
        spec.max_iterations_per_trial = iters;
        spec.seed = seed;
        let sched = scheduler_kind(name, iters, &space);
        let res = run_experiments(
            spec,
            space.clone(),
            sched,
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            RunOptions {
                cluster: Cluster::uniform(4, Resources::cpu(8.0)),
                ..Default::default()
            },
        );
        println!(
            "{:<18} {:>10.4} {:>12.0} {:>12.0} {:>10} {:>10}",
            name,
            res.best_metric().unwrap_or(0.0),
            res.budget_used_s,
            res.duration_s,
            res.stats.stopped_early,
            res.stats.results
        );
    }
}

fn cmd_loc_table() {
    let rows = loc::table1(&PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    loc::print_table1(&rows);
}

fn cmd_analyze(flags: &Flags) {
    let dir = flags
        .0
        .get("exp-dir")
        .or_else(|| flags.0.get("log-dir"))
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("tune_logs"));
    let metric = flags.get("metric", "loss");
    let mode = if flags.get("mode", "min") == "max" { Mode::Max } else { Mode::Min };
    // Durable experiment directories carry a manifest + snapshot; show
    // their status so users can see whether the run is resumable.
    // `open` is read-only: analyze must work on read-only mounts and
    // never scaffold checkpoints/ into plain log dirs.
    if dir.join("experiment.meta.json").exists() {
        let exp = tune::coordinator::ExperimentDir::open(dir.clone());
        if let Some(m) = exp.read_manifest() {
            let get = |k: &str| m.get(k).and_then(|v| v.as_str()).unwrap_or("?").to_string();
            println!(
                "experiment {:?}: scheduler={} exec={} (durable dir)",
                get("name"),
                get("scheduler"),
                get("exec"),
            );
            if let Some(r) = m.get("resources_per_trial").and_then(|r| r.as_obj()) {
                let parts: Vec<String> = r
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|f| format!("{k}={f}")))
                    .collect();
                println!("resources_per_trial: {}", parts.join(", "));
            }
            match exp.read_snapshot() {
                Some(s) => {
                    let finished =
                        s.get("finished").and_then(|v| v.as_bool()).unwrap_or(false);
                    // Count only records a resume would actually fold:
                    // stale-epoch leftovers from a base-write crash
                    // window are skipped by restore, so do not report
                    // them as pending incremental state.
                    let epoch = s.get("delta_epoch").and_then(|v| v.as_u64()).unwrap_or(0);
                    let deltas = exp
                        .read_deltas()
                        .iter()
                        .filter(|d| d.get("epoch").and_then(|v| v.as_u64()) == Some(epoch))
                        .count();
                    println!(
                        "snapshot: {} at experiment time {:.1}s{}{}",
                        if finished { "final" } else { "mid-run" },
                        s.get("now").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        if deltas > 0 {
                            format!(" (+{deltas} incremental delta record(s))")
                        } else {
                            String::new()
                        },
                        if finished { "" } else { " — resumable with `tune run --resume`" },
                    );
                    // Mean cluster utilization, from the persisted
                    // per-result samples (SchedulerCtx sees the same
                    // numbers live).
                    let stats = s.get("stats");
                    let results = stats
                        .and_then(|st| st.get("results"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0);
                    if results > 0.0 {
                        let sum = |k: &str| {
                            stats
                                .and_then(|st| st.get(k))
                                .and_then(|v| v.as_f64())
                                .unwrap_or(0.0)
                        };
                        println!(
                            "mean cluster utilization: cpu {:.0}%, gpu {:.0}%",
                            sum("util_cpu_sum") / results * 100.0,
                            sum("util_gpu_sum") / results * 100.0,
                        );
                    }
                }
                None => println!("snapshot: none yet"),
            }
        }
    }
    let a = ExperimentAnalysis::load(&dir).expect("reading log dir");
    println!("{} trials, {} results", a.trials.len(), a.num_results());
    match a.best_trial(&metric, mode) {
        Some((id, v)) => {
            println!("best trial #{id}: {metric}={v:.5}");
            println!("config: {:?}", a.trials[&id].config);
        }
        None => println!("no results with metric {metric:?}"),
    }
    let curve = a.best_vs_budget(&metric, mode);
    if !curve.is_empty() {
        println!("\nbest-vs-budget ({} points, showing 10):", curve.len());
        let step = (curve.len() / 10).max(1);
        for (b, v) in curve.iter().step_by(step) {
            println!("  budget {b:>10.1}s  best {v:.5}");
        }
    }
}
