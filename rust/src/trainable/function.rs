//! The cooperative function-based user API (Figure 2(a)).
//!
//! The user writes an ordinary training loop taking a [`TuneHandle`]:
//!
//! ```
//! use tune::trainable::function::{FunctionTrainable, TuneHandle};
//! use tune::trainable::Trainable;
//! let f = |tune: TuneHandle| {
//!     let lr = tune.param_f64("lr", 0.01);
//!     let mut model = 0.0;
//!     for i in tune.start_iteration()..100 {
//!         model += lr; // one training epoch
//!         if tune.should_checkpoint() {
//!             tune.record_checkpoint(model.to_le_bytes().to_vec());
//!         }
//!         if !tune.report(i, &[("score", model)]) { return; }
//!     }
//! };
//! let mut t = FunctionTrainable::spawn(Default::default(), 0, std::sync::Arc::new(f));
//! assert!(t.step().unwrap().metrics["score"] > 0.0);
//! ```
//!
//! `report` *blocks* until the scheduler wants another iteration — the
//! cooperative control model: the framework decides between iterations
//! whether to continue, checkpoint, mutate, or stop, with minimal
//! changes to user code. The adapter below wraps the function in a
//! thread and exposes the class-based [`Trainable`] interface to the
//! executors ("Tune inserts adapters over the cooperative interface to
//! provide a facade of direct control to trial schedulers").

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::trial::Config;

use super::{StepOutput, Trainable};

/// What the driver sends to the user function.
enum Cmd {
    /// Run until the next `report`.
    Continue,
    /// Finish: `report` returns false, function should return.
    Stop,
}

/// What the user function sends to the driver.
enum Msg {
    Report { iteration: u64, metrics: BTreeMap<String, f64> },
    Done,
}

type TrainFn = Arc<dyn Fn(TuneHandle) + Send + Sync>;

/// Handle passed into the user's training function.
pub struct TuneHandle {
    params: Config,
    cmd_rx: Receiver<Cmd>,
    msg_tx: Sender<Msg>,
    shared: Arc<Shared>,
    start_iteration: u64,
}

#[derive(Default)]
struct Shared {
    /// Set by the driver when it wants a checkpoint at the next
    /// cooperative opportunity; cleared when one is recorded.
    want_checkpoint: Mutex<bool>,
    /// Last checkpoint blob recorded by the user function.
    last_checkpoint: Mutex<Option<Vec<u8>>>,
    /// Blob to restore from at (re)start.
    restore_from: Mutex<Option<Vec<u8>>>,
    /// Config updates applied between iterations (PBT).
    config_update: Mutex<Option<Config>>,
}

impl TuneHandle {
    /// Hyperparameters (`tune.params` in the paper's snippet).
    pub fn param_f64(&self, key: &str, default: f64) -> f64 {
        self.latest_config()
            .get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or(default)
    }

    /// String hyperparameter lookup with a default.
    pub fn param_str(&self, key: &str, default: &str) -> String {
        self.latest_config()
            .get(key)
            .and_then(|v| v.as_str().map(|s| s.to_string()))
            .unwrap_or_else(|| default.to_string())
    }

    fn latest_config(&self) -> Config {
        if let Some(c) = self.shared.config_update.lock().unwrap().clone() {
            c
        } else {
            self.params.clone()
        }
    }

    /// Iteration to resume from (0 on fresh start; the checkpointed
    /// iteration after a restore-restart).
    pub fn start_iteration(&self) -> u64 {
        self.start_iteration
    }

    /// Blob recorded by a previous incarnation, if restoring.
    pub fn get_checkpoint(&self) -> Option<Vec<u8>> {
        self.shared.restore_from.lock().unwrap().clone()
    }

    /// True when the framework wants a snapshot now (§4.1:
    /// `tune.should_checkpoint()`).
    pub fn should_checkpoint(&self) -> bool {
        *self.shared.want_checkpoint.lock().unwrap()
    }

    /// Hand the framework a snapshot (§4.1: `tune.record_checkpoint`).
    pub fn record_checkpoint(&self, blob: Vec<u8>) {
        *self.shared.last_checkpoint.lock().unwrap() = Some(blob);
        *self.shared.want_checkpoint.lock().unwrap() = false;
    }

    /// Report intermediate results; blocks until the framework requests
    /// the next iteration. Returns false when the trial should stop.
    pub fn report(&self, iteration: u64, metrics: &[(&str, f64)]) -> bool {
        let metrics = metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        if self.msg_tx.send(Msg::Report { iteration, metrics }).is_err() {
            return false;
        }
        matches!(self.cmd_rx.recv(), Ok(Cmd::Continue))
    }
}

/// Adapter: cooperative function -> class-based [`Trainable`].
pub struct FunctionTrainable {
    f: TrainFn,
    config: Config,
    #[allow(dead_code)]
    seed: u64,
    shared: Arc<Shared>,
    cmd_tx: Option<Sender<Cmd>>,
    msg_rx: Option<Receiver<Msg>>,
    thread: Option<JoinHandle<()>>,
    iteration: u64,
    finished: bool,
}

impl FunctionTrainable {
    /// Start the user function on its own thread, parked at its first
    /// `report` until the executor steps it.
    pub fn spawn(config: Config, seed: u64, f: TrainFn) -> Self {
        let mut t = FunctionTrainable {
            f,
            config,
            seed,
            shared: Arc::new(Shared::default()),
            cmd_tx: None,
            msg_rx: None,
            thread: None,
            iteration: 0,
            finished: false,
        };
        t.start_thread();
        t
    }

    fn start_thread(&mut self) {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (msg_tx, msg_rx) = mpsc::channel();
        let handle = TuneHandle {
            params: self.config.clone(),
            cmd_rx,
            msg_tx: msg_tx.clone(),
            shared: self.shared.clone(),
            start_iteration: self.iteration,
        };
        let f = self.f.clone();
        self.thread = Some(std::thread::spawn(move || {
            f(handle);
            let _ = msg_tx.send(Msg::Done);
        }));
        self.cmd_tx = Some(cmd_tx);
        self.msg_rx = Some(msg_rx);
        self.finished = false;
    }

    fn shutdown_thread(&mut self) {
        if let Some(tx) = self.cmd_tx.take() {
            let _ = tx.send(Cmd::Stop);
        }
        if let Some(rx) = self.msg_rx.take() {
            // Drain until the function acknowledges by returning.
            while let Ok(msg) = rx.recv() {
                if matches!(msg, Msg::Done) {
                    break;
                }
            }
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Trainable for FunctionTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        if self.finished {
            return Ok(StepOutput { metrics: BTreeMap::new(), done: true });
        }
        let rx = self.msg_rx.as_ref().ok_or("function thread not running")?;
        // The function is parked inside `report` (or hasn't reported yet
        // on a fresh start). First wait for its report, then it parks.
        match rx.recv() {
            Ok(Msg::Report { iteration, metrics }) => {
                self.iteration = iteration;
                // Ask for one more iteration so the next `step` finds a
                // fresh report; the *scheduler* decides what actually
                // happens via the runner, which calls stop()/save() etc.
                if let Some(tx) = &self.cmd_tx {
                    let _ = tx.send(Cmd::Continue);
                }
                Ok(StepOutput { metrics, done: false })
            }
            Ok(Msg::Done) | Err(_) => {
                self.finished = true;
                Ok(StepOutput { metrics: BTreeMap::new(), done: true })
            }
        }
    }

    fn save(&mut self) -> Vec<u8> {
        // Cooperative model: request a checkpoint; it becomes available
        // at the function's next should_checkpoint() poll. We return the
        // most recent recorded blob (Ray's function API semantics).
        *self.shared.want_checkpoint.lock().unwrap() = true;
        let blob = self.shared.last_checkpoint.lock().unwrap().clone();
        let mut out = self.iteration.to_le_bytes().to_vec();
        out.extend(blob.unwrap_or_default());
        out
    }

    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        if blob.len() < 8 {
            return Err("bad function checkpoint".into());
        }
        // Restart the function thread from the checkpointed iteration —
        // the actor-restart semantics of the real system.
        self.shutdown_thread();
        self.iteration = u64::from_le_bytes(blob[..8].try_into().unwrap());
        *self.shared.restore_from.lock().unwrap() = Some(blob[8..].to_vec());
        *self.shared.last_checkpoint.lock().unwrap() = Some(blob[8..].to_vec());
        self.start_thread();
        Ok(())
    }

    fn update_config(&mut self, config: &Config) {
        *self.shared.config_update.lock().unwrap() = Some(config.clone());
    }
}

impl Drop for FunctionTrainable {
    fn drop(&mut self) {
        // Don't hang on a parked user thread.
        if let Some(tx) = self.cmd_tx.take() {
            let _ = tx.send(Cmd::Stop);
        }
        if let Some(rx) = self.msg_rx.take() {
            loop {
                match rx.try_recv() {
                    Ok(Msg::Done) | Err(TryRecvError::Disconnected) => break,
                    Ok(_) => continue,
                    Err(TryRecvError::Empty) => break,
                }
            }
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::ParamValue;

    fn train_fn(tune: TuneHandle) {
        let lr = tune.param_f64("lr", 0.1);
        let mut model = match tune.get_checkpoint() {
            Some(b) if b.len() == 8 => f64::from_le_bytes(b.try_into().unwrap()),
            _ => 0.0,
        };
        let mut i = tune.start_iteration();
        loop {
            i += 1;
            model += lr;
            if tune.should_checkpoint() {
                tune.record_checkpoint(model.to_le_bytes().to_vec());
            }
            if !tune.report(i, &[("score", model)]) {
                return;
            }
        }
    }

    fn cfg(lr: f64) -> Config {
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(lr));
        c
    }

    #[test]
    fn reports_flow_through_step() {
        let mut t = FunctionTrainable::spawn(cfg(0.5), 0, Arc::new(train_fn));
        let a = t.step().unwrap();
        let b = t.step().unwrap();
        assert_eq!(a.metrics["score"], 0.5);
        assert_eq!(b.metrics["score"], 1.0);
    }

    #[test]
    fn checkpoint_and_restore_across_incarnations() {
        let mut t = FunctionTrainable::spawn(cfg(1.0), 0, Arc::new(train_fn));
        t.step().unwrap();
        t.save(); // arm want_checkpoint
        t.step().unwrap(); // function records at next poll
        let blob = t.save();
        drop(t);

        let mut t2 = FunctionTrainable::spawn(cfg(1.0), 0, Arc::new(train_fn));
        t2.restore(&blob).unwrap();
        let out = t2.step().unwrap();
        // Restored model had score >= 2.0, so next report is >= 3.0.
        assert!(out.metrics["score"] >= 3.0, "{:?}", out.metrics);
    }

    #[test]
    fn update_config_reaches_function() {
        let f = |tune: TuneHandle| {
            let mut i = 0;
            loop {
                i += 1;
                let lr = tune.param_f64("lr", 0.0);
                if !tune.report(i, &[("lr", lr)]) {
                    return;
                }
            }
        };
        let mut t = FunctionTrainable::spawn(cfg(0.1), 0, Arc::new(f));
        assert_eq!(t.step().unwrap().metrics["lr"], 0.1);
        t.update_config(&cfg(0.9));
        assert_eq!(t.step().unwrap().metrics["lr"], 0.9);
    }

    #[test]
    fn finite_function_signals_done() {
        let f = |tune: TuneHandle| {
            for i in 1..=3u64 {
                if !tune.report(i, &[("i", i as f64)]) {
                    return;
                }
            }
        };
        let mut t = FunctionTrainable::spawn(Config::new(), 0, Arc::new(f));
        for _ in 0..3 {
            assert!(!t.step().unwrap().done);
        }
        assert!(t.step().unwrap().done);
        assert!(t.step().unwrap().done); // idempotent after finish
    }

    #[test]
    fn drop_does_not_hang() {
        let t = FunctionTrainable::spawn(cfg(0.1), 0, Arc::new(train_fn));
        drop(t); // must not deadlock
    }
}
