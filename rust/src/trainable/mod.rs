//! The user API (§4.1 of the paper): trainables.
//!
//! The paper offers two integration styles and so do we:
//!
//! * **Class-based** ([`Trainable`], Figure 2(b)) — `step`/`save`/
//!   `restore` methods the trial schedulers call to incrementally train
//!   models. This is the native interface of the executors.
//! * **Function-based cooperative** ([`function::run_function`],
//!   Figure 2(a)) — the user writes a plain training loop calling
//!   `tune.report(..)` / `tune.should_checkpoint()` /
//!   `tune.record_checkpoint(..)`; an adapter ("Tune inserts adapters
//!   over the cooperative interface to provide a facade of direct
//!   control") turns it into a [`Trainable`].
//!
//! Everything a scheduler needs — intermediate results, snapshot,
//! restore, runtime hyperparameter mutation — flows through this narrow
//! waist, which is the paper's central design claim.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::trial::Config;

pub mod function;
pub mod jax_model;
pub mod synthetic;

/// Metrics from one training iteration.
#[derive(Clone, Debug, Default)]
pub struct StepOutput {
    /// Metric name -> value for this iteration.
    pub metrics: BTreeMap<String, f64>,
    /// The trainable itself declares it is finished (e.g. the
    /// cooperative function returned).
    pub done: bool,
}

impl StepOutput {
    /// Build a (not-done) output from metric pairs.
    pub fn of(pairs: &[(&str, f64)]) -> Self {
        StepOutput {
            metrics: pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            done: false,
        }
    }
}

/// The class-based user API (Figure 2(b)).
pub trait Trainable: Send {
    /// Run one training iteration and report metrics.
    fn step(&mut self) -> Result<StepOutput, String>;

    /// Snapshot the full training state as an opaque blob.
    fn save(&mut self) -> Vec<u8>;

    /// Restore from a blob produced by `save` (possibly by a *different*
    /// trial — PBT clones across the population).
    fn restore(&mut self, blob: &[u8]) -> Result<(), String>;

    /// Apply a mutated hyperparameter configuration at runtime
    /// ("alter hyperparameters in the middle of training", §4.1).
    fn update_config(&mut self, _config: &Config) {}

    /// Virtual seconds one `step` costs on the discrete-event executor.
    /// Irregular computations (§3) surface here: trainables may report
    /// config-dependent or time-varying costs.
    fn step_cost(&self) -> f64 {
        1.0
    }
}

/// Creates a trainable for a trial: (config, trial seed) -> Trainable.
pub type TrainableFactory = Arc<dyn Fn(&Config, u64) -> Box<dyn Trainable> + Send + Sync>;

/// Convenience for tests and examples.
pub fn factory<F>(f: F) -> TrainableFactory
where
    F: Fn(&Config, u64) -> Box<dyn Trainable> + Send + Sync + 'static,
{
    Arc::new(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter {
        n: u64,
    }
    impl Trainable for Counter {
        fn step(&mut self) -> Result<StepOutput, String> {
            self.n += 1;
            Ok(StepOutput::of(&[("n", self.n as f64)]))
        }
        fn save(&mut self) -> Vec<u8> {
            self.n.to_le_bytes().to_vec()
        }
        fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
            self.n = u64::from_le_bytes(blob.try_into().map_err(|_| "bad blob")?);
            Ok(())
        }
    }

    #[test]
    fn save_restore_roundtrip() {
        let mut c = Counter { n: 0 };
        c.step().unwrap();
        c.step().unwrap();
        let blob = c.save();
        let mut c2 = Counter { n: 0 };
        c2.restore(&blob).unwrap();
        assert_eq!(c2.step().unwrap().metrics["n"], 3.0);
    }

    #[test]
    fn factory_builds_boxed() {
        let f = factory(|_, _| Box::new(Counter { n: 0 }));
        let mut t = f(&Config::new(), 0);
        assert_eq!(t.step().unwrap().metrics["n"], 1.0);
    }
}
