//! Synthetic trainables: parametric learning curves whose observable
//! interface (iteration -> metric stream, config sensitivity, save/
//! restore, runtime mutation) matches a real training job at ~10^6x less
//! compute. The HyperBand / ASHA / PBT papers evaluate schedulers on
//! exactly this kind of simulated workload; DESIGN.md documents the
//! substitution (C1/C2).

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use crate::coordinator::trial::Config;
use crate::util::rng::Rng;

use super::{StepOutput, Trainable};

fn cfg_f64(config: &Config, key: &str, default: f64) -> f64 {
    config.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
}

/// Stationary learning curve:
///
///   quality q  = exp(-(log10 lr - log10 lr*)^2 / w) * m(momentum)
///   acc(t)     = q * (1 - exp(-t / tau)) + eps,  eps ~ N(0, noise)
///   loss(t)    = 1 - acc(t)
///
/// with lr* = 0.02. Better configs converge to higher ceilings; tau also
/// depends on the config so curves cross — exactly the regime where
/// early stopping on intermediate results can be fooled, which is what
/// separates median-stopping / ASHA / HyperBand from FIFO in C1.
pub struct CurveTrainable {
    t: u64,
    quality: f64,
    tau: f64,
    noise: f64,
    cost: f64,
    rng: Rng,
}

impl CurveTrainable {
    /// The learning rate with the highest quality ceiling.
    pub const OPT_LR: f64 = 0.02;

    /// Build from a config (`lr`, `momentum`) and a trial seed.
    pub fn new(config: &Config, seed: u64) -> Self {
        let lr = cfg_f64(config, "lr", 0.01);
        let momentum = cfg_f64(config, "momentum", 0.9);
        let dist = (lr.log10() - Self::OPT_LR.log10()).powi(2);
        let mq = 1.0 - 0.3 * (momentum - 0.9).abs();
        let quality = 0.97 * (-dist / 1.5).exp() * mq;
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        // Slow starters: worse configs converge slower => curves cross.
        let tau = 8.0 + 30.0 * (1.0 - quality) + rng.uniform(0.0, 4.0);
        // Irregular computations (§3): per-trial step cost varies ~4x.
        let cost = rng.uniform(0.5, 2.0);
        CurveTrainable { t: 0, quality, tau, noise: 0.01, cost, rng }
    }

    /// The accuracy ceiling this config converges to.
    pub fn asymptote(&self) -> f64 {
        self.quality
    }

    fn accuracy_at(&mut self, t: u64) -> f64 {
        let base = self.quality * (1.0 - (-(t as f64) / self.tau).exp());
        (base + self.rng.normal_scaled(0.0, self.noise)).clamp(0.0, 1.0)
    }
}

impl Trainable for CurveTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.t += 1;
        let acc = self.accuracy_at(self.t);
        Ok(StepOutput::of(&[("accuracy", acc), ("loss", 1.0 - acc)]))
    }

    fn save(&mut self) -> Vec<u8> {
        // Full state including the noise RNG, so restoring a checkpoint
        // replays the exact metric stream — the property crash-safe
        // resume (`--resume`) relies on for deterministic outcomes.
        let mut out = Vec::with_capacity(24);
        out.extend_from_slice(&self.t.to_le_bytes());
        out.extend_from_slice(&self.quality.to_le_bytes());
        out.extend_from_slice(&self.rng.state().to_le_bytes());
        out
    }

    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        if blob.len() != 24 {
            return Err(format!("bad curve checkpoint: {} bytes", blob.len()));
        }
        self.t = u64::from_le_bytes(blob[..8].try_into().unwrap());
        self.quality = f64::from_le_bytes(blob[8..16].try_into().unwrap());
        self.rng.set_state(u64::from_le_bytes(blob[16..].try_into().unwrap()));
        Ok(())
    }

    fn step_cost(&self) -> f64 {
        self.cost
    }
}

/// Non-stationary objective for PBT (C2): the optimal learning rate
/// decays over time,
///
///   lr*(t) = 0.1 * 10^(-t / half_life)
///
/// and the per-step gain is exp(-(log10 lr - log10 lr*(t))^2 / w).
/// The reported metric is cumulative score. A static config can only be
/// near-optimal for a short window; PBT's mid-training mutation/cloning
/// tracks the moving target — the paper's claim 3 in §4.2.
pub struct NonStationaryTrainable {
    t: u64,
    score: f64,
    lr: f64,
    half_life: f64,
    rng: Rng,
}

impl NonStationaryTrainable {
    /// Build from a config (`lr`, `half_life`) and a trial seed.
    pub fn new(config: &Config, seed: u64) -> Self {
        NonStationaryTrainable {
            t: 0,
            score: 0.0,
            lr: cfg_f64(config, "lr", 0.01),
            half_life: cfg_f64(config, "half_life", 40.0),
            rng: Rng::new(seed ^ 0xDECade),
        }
    }

    /// The moving optimum `lr*(t)` the objective rewards tracking.
    pub fn optimal_lr_at(t: u64, half_life: f64) -> f64 {
        0.1 * 10f64.powf(-(t as f64) / half_life)
    }

    fn gain(&mut self) -> f64 {
        let opt = Self::optimal_lr_at(self.t, self.half_life);
        let d = (self.lr.log10() - opt.log10()).powi(2);
        ((-d / 0.5).exp() + self.rng.normal_scaled(0.0, 0.005)).max(0.0)
    }
}

impl Trainable for NonStationaryTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.t += 1;
        let g = self.gain();
        self.score += g;
        Ok(StepOutput::of(&[
            ("score", self.score),
            ("gain", g),
            ("lr", self.lr),
        ]))
    }

    fn save(&mut self) -> Vec<u8> {
        // Includes the noise RNG state for replay-exact restores (see
        // CurveTrainable::save).
        let mut out = Vec::with_capacity(24);
        out.extend_from_slice(&self.t.to_le_bytes());
        out.extend_from_slice(&self.score.to_le_bytes());
        out.extend_from_slice(&self.rng.state().to_le_bytes());
        out
    }

    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        if blob.len() != 24 {
            return Err("bad checkpoint".into());
        }
        self.t = u64::from_le_bytes(blob[..8].try_into().unwrap());
        self.score = f64::from_le_bytes(blob[8..16].try_into().unwrap());
        self.rng.set_state(u64::from_le_bytes(blob[16..].try_into().unwrap()));
        Ok(())
    }

    /// PBT explore lands here: the new lr takes effect mid-training.
    fn update_config(&mut self, config: &Config) {
        self.lr = cfg_f64(config, "lr", self.lr);
    }
}

/// A learning curve that *diverges*: behaves like [`CurveTrainable`]
/// through iteration `nan_after` (config key), then reports `NaN` for
/// every metric — the classic exploded-loss failure mode §3 calls an
/// irregular computation. `nan_after` absent (or past the horizon)
/// means it never diverges; `nan_after = 0` means every result is NaN.
/// The trainable itself keeps stepping happily; it is the
/// *coordinator's* job to rank the NaN stream as strictly worst instead
/// of panicking (see `util::order`), which the NaN regression tests
/// drive through every scheduler and searcher.
pub struct DivergentTrainable {
    inner: CurveTrainable,
    t: u64,
    nan_after: f64,
}

impl DivergentTrainable {
    /// Build from a config (`lr`, `momentum`, `nan_after`) and a seed.
    pub fn new(config: &Config, seed: u64) -> Self {
        DivergentTrainable {
            inner: CurveTrainable::new(config, seed),
            t: 0,
            nan_after: cfg_f64(config, "nan_after", f64::INFINITY),
        }
    }

    /// Has this trainable started reporting NaN yet?
    pub fn diverged(&self) -> bool {
        self.t as f64 > self.nan_after
    }
}

impl Trainable for DivergentTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.t += 1;
        let mut out = self.inner.step()?;
        if self.diverged() {
            for v in out.metrics.values_mut() {
                *v = f64::NAN;
            }
        }
        Ok(out)
    }

    fn save(&mut self) -> Vec<u8> {
        // The divergence point is config-derived and `t` mirrors the
        // inner curve's step counter, so the inner blob is sufficient.
        self.inner.save()
    }

    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        self.inner.restore(blob)?;
        self.t = u64::from_le_bytes(blob[..8].try_into().map_err(|_| "bad blob")?);
        Ok(())
    }

    fn step_cost(&self) -> f64 {
        self.inner.step_cost()
    }
}

/// Fixed-length trivial trainable for overhead/scaling benches (C3):
/// every step costs `cost` virtual seconds and reports one metric.
pub struct ConstTrainable {
    t: u64,
    cost: f64,
}

impl ConstTrainable {
    /// Build from a config (`step_cost`) — the seed is unused.
    pub fn new(config: &Config, _seed: u64) -> Self {
        ConstTrainable { t: 0, cost: cfg_f64(config, "step_cost", 1.0) }
    }
}

impl Trainable for ConstTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.t += 1;
        Ok(StepOutput::of(&[("iters", self.t as f64)]))
    }
    fn save(&mut self) -> Vec<u8> {
        self.t.to_le_bytes().to_vec()
    }
    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        self.t = u64::from_le_bytes(blob.try_into().map_err(|_| "bad blob")?);
        Ok(())
    }
    fn step_cost(&self) -> f64 {
        self.cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::ParamValue;

    fn cfg(lr: f64) -> Config {
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(lr));
        c
    }

    #[test]
    fn good_lr_beats_bad_lr() {
        let mut good = CurveTrainable::new(&cfg(0.02), 1);
        let mut bad = CurveTrainable::new(&cfg(1e-4), 1);
        let mut g_acc = 0.0;
        let mut b_acc = 0.0;
        for _ in 0..200 {
            g_acc = good.step().unwrap().metrics["accuracy"];
            b_acc = bad.step().unwrap().metrics["accuracy"];
        }
        assert!(g_acc > b_acc + 0.2, "good={g_acc} bad={b_acc}");
    }

    #[test]
    fn curve_is_monotone_ish() {
        let mut t = CurveTrainable::new(&cfg(0.02), 2);
        let early = t.step().unwrap().metrics["accuracy"];
        for _ in 0..100 {
            t.step().unwrap();
        }
        let late = t.step().unwrap().metrics["accuracy"];
        assert!(late > early);
    }

    #[test]
    fn curve_checkpoint_resumes_time() {
        let mut a = CurveTrainable::new(&cfg(0.02), 3);
        for _ in 0..50 {
            a.step().unwrap();
        }
        let blob = a.save();
        let mut b = CurveTrainable::new(&cfg(0.02), 3);
        b.restore(&blob).unwrap();
        assert_eq!(b.t, 50);
    }

    #[test]
    fn curve_checkpoint_restore_is_replay_exact() {
        // A restored trainable must emit the same metric stream the
        // original would — noise included (the rng state travels in the
        // blob). Crash-safe resume depends on this.
        let mut a = CurveTrainable::new(&cfg(0.02), 3);
        for _ in 0..10 {
            a.step().unwrap();
        }
        let blob = a.save();
        let mut b = CurveTrainable::new(&cfg(0.02), 3);
        b.restore(&blob).unwrap();
        for _ in 0..20 {
            assert_eq!(
                a.step().unwrap().metrics["accuracy"],
                b.step().unwrap().metrics["accuracy"]
            );
        }
    }

    #[test]
    fn nonstationary_checkpoint_restore_is_replay_exact() {
        let mut a = NonStationaryTrainable::new(&cfg(0.05), 9);
        for _ in 0..7 {
            a.step().unwrap();
        }
        let blob = a.save();
        let mut b = NonStationaryTrainable::new(&cfg(0.05), 9);
        b.restore(&blob).unwrap();
        for _ in 0..20 {
            assert_eq!(a.step().unwrap().metrics["score"], b.step().unwrap().metrics["score"]);
        }
    }

    #[test]
    fn irregular_step_costs() {
        let a = CurveTrainable::new(&cfg(0.02), 1);
        let b = CurveTrainable::new(&cfg(0.02), 99);
        assert_ne!(a.step_cost(), b.step_cost());
        assert!(a.step_cost() >= 0.5 && a.step_cost() <= 2.0);
    }

    #[test]
    fn nonstationary_rewards_tracking() {
        // An adaptive lr (reset every 20 steps to the optimum) must beat
        // any static lr — the PBT premise.
        let mut adaptive = NonStationaryTrainable::new(&cfg(0.1), 4);
        let mut static_ = NonStationaryTrainable::new(&cfg(0.1), 4);
        for t in 0..120 {
            if t % 10 == 0 {
                let opt = NonStationaryTrainable::optimal_lr_at(t, 40.0);
                let mut c = cfg(opt);
                c.insert("half_life".into(), ParamValue::F64(40.0));
                adaptive.update_config(&c);
            }
            adaptive.step().unwrap();
            static_.step().unwrap();
        }
        assert!(adaptive.score > static_.score * 1.5,
                "adaptive={} static={}", adaptive.score, static_.score);
    }

    #[test]
    fn divergent_reports_nan_after_threshold() {
        let mut c = cfg(0.02);
        c.insert("nan_after".into(), ParamValue::I64(3));
        let mut t = DivergentTrainable::new(&c, 1);
        for _ in 0..3 {
            let out = t.step().unwrap();
            assert!(out.metrics["accuracy"].is_finite());
        }
        assert!(!t.diverged());
        let out = t.step().unwrap();
        assert!(out.metrics["accuracy"].is_nan());
        assert!(out.metrics["loss"].is_nan());
        assert!(t.diverged());
    }

    #[test]
    fn divergent_without_threshold_matches_curve() {
        let mut a = DivergentTrainable::new(&cfg(0.02), 5);
        let mut b = CurveTrainable::new(&cfg(0.02), 5);
        for _ in 0..20 {
            assert_eq!(
                a.step().unwrap().metrics["accuracy"],
                b.step().unwrap().metrics["accuracy"]
            );
        }
    }

    #[test]
    fn update_config_changes_lr_midstream() {
        let mut t = NonStationaryTrainable::new(&cfg(0.1), 5);
        t.step().unwrap();
        t.update_config(&cfg(0.001));
        assert_eq!(t.step().unwrap().metrics["lr"], 0.001);
    }
}
