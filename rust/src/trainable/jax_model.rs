//! The real workload: a [`Trainable`] backed by an AOT-compiled
//! JAX/Pallas model executed through the PJRT service. This is what the
//! end-to-end example tunes — the full three-layer stack on the trial
//! hot path, python nowhere in sight.
//!
//! Hyperparameters: `lr` and `momentum` are runtime scalars fed to the
//! executable each step (so PBT can mutate them mid-training);
//! `activation` / `model` select the compiled variant.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::trial::Config;
use crate::runtime::{PjrtService, SessionId};

use super::{StepOutput, Trainable};

static NEXT_SESSION: AtomicU64 = AtomicU64::new(1);

/// A trainable whose compute is an AOT-compiled JAX model behind the
/// PJRT service; only metrics and opaque state blobs cross the channel.
pub struct JaxTrainable {
    svc: PjrtService,
    session: SessionId,
    lr: f32,
    momentum: f32,
    /// PJRT train steps folded into one Tune iteration (report period).
    steps_per_iteration: u32,
    iteration: u64,
    open: bool,
}

/// Resolve a config to a compiled variant name: explicit `model` wins;
/// otherwise `<family>_<activation>`.
pub fn variant_for(config: &Config, default_family: &str) -> String {
    if let Some(m) = config.get("model").and_then(|v| v.as_str()) {
        return m.to_string();
    }
    let act = config
        .get("activation")
        .and_then(|v| v.as_str())
        .unwrap_or("relu");
    format!("{default_family}_{act}")
}

impl JaxTrainable {
    /// Open a session for the variant `config` resolves to.
    pub fn new(
        svc: PjrtService,
        config: &Config,
        seed: u64,
        default_family: &str,
        steps_per_iteration: u32,
    ) -> Result<Self, String> {
        let session = NEXT_SESSION.fetch_add(1, Ordering::Relaxed);
        let model = variant_for(config, default_family);
        svc.open(session, &model, seed).map_err(|e| format!("{e:#}"))?;
        let mut t = JaxTrainable {
            svc,
            session,
            lr: 0.01,
            momentum: 0.9,
            steps_per_iteration,
            iteration: 0,
            open: true,
        };
        t.update_config(config);
        Ok(t)
    }
}

impl Trainable for JaxTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        let (loss, extra) = self
            .svc
            .step(self.session, self.steps_per_iteration, self.lr, self.momentum)
            .map_err(|e| format!("{e:#}"))?;
        self.iteration += 1;
        let mut out = StepOutput::of(&[
            ("loss", loss),
            ("perplexity", loss.exp()),
            ("steps", (self.iteration * self.steps_per_iteration as u64) as f64),
        ]);
        if let Some(acc) = extra.first() {
            out.metrics.insert("accuracy".into(), *acc);
        }
        Ok(out)
    }

    fn save(&mut self) -> Vec<u8> {
        match self.svc.save(self.session) {
            Ok(mut blob) => {
                let mut out = self.iteration.to_le_bytes().to_vec();
                out.append(&mut blob);
                out
            }
            Err(_) => Vec::new(),
        }
    }

    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        if blob.len() < 8 {
            return Err("short jax checkpoint".into());
        }
        self.iteration = u64::from_le_bytes(blob[..8].try_into().unwrap());
        self.svc
            .restore(self.session, blob[8..].to_vec())
            .map_err(|e| format!("{e:#}"))
    }

    fn update_config(&mut self, config: &Config) {
        if let Some(lr) = config.get("lr").and_then(|v| v.as_f64()) {
            self.lr = lr as f32;
        }
        if let Some(mu) = config.get("momentum").and_then(|v| v.as_f64()) {
            self.momentum = mu as f32;
        }
    }

    /// Wall time dominates in Threads mode; for Sim mode estimate one
    /// iteration as one virtual second.
    fn step_cost(&self) -> f64 {
        1.0
    }
}

impl Drop for JaxTrainable {
    fn drop(&mut self) {
        if self.open {
            self.svc.close(self.session);
        }
    }
}

/// Factory for `run_experiments`: trials share the PJRT service.
pub fn jax_factory(
    svc: PjrtService,
    default_family: &'static str,
    steps_per_iteration: u32,
) -> super::TrainableFactory {
    super::factory(move |config, seed| {
        match JaxTrainable::new(svc.clone(), config, seed, default_family, steps_per_iteration) {
            Ok(t) => Box::new(t),
            Err(e) => Box::new(BrokenTrainable { error: e }),
        }
    })
}

/// Surfaces factory errors through the Trainable interface (the runner
/// handles them as trial errors rather than panicking the executor).
struct BrokenTrainable {
    error: String,
}

impl Trainable for BrokenTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        Err(self.error.clone())
    }
    fn save(&mut self) -> Vec<u8> {
        Vec::new()
    }
    fn restore(&mut self, _blob: &[u8]) -> Result<(), String> {
        Err(self.error.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::ParamValue;
    use crate::runtime::Manifest;

    fn svc() -> Option<PjrtService> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(PjrtService::spawn(dir).unwrap())
    }

    fn cfg(lr: f64, act: &str) -> Config {
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(lr));
        c.insert("momentum".into(), ParamValue::F64(0.9));
        c.insert("activation".into(), ParamValue::Str(act.into()));
        c
    }

    #[test]
    fn variant_resolution() {
        assert_eq!(variant_for(&cfg(0.1, "tanh"), "mlp"), "mlp_tanh");
        let mut c = Config::new();
        c.insert("model".into(), ParamValue::Str("tlm_gelu".into()));
        assert_eq!(variant_for(&c, "mlp"), "tlm_gelu");
    }

    #[test]
    fn jax_trainable_learns_and_checkpoints() {
        let Some(svc) = svc() else { return };
        let mut t = JaxTrainable::new(svc.clone(), &cfg(0.1, "relu"), 1, "mlp", 5).unwrap();
        let first = t.step().unwrap().metrics["loss"];
        for _ in 0..5 {
            t.step().unwrap();
        }
        let blob = t.save();
        assert!(!blob.is_empty());
        let last = t.step().unwrap().metrics["loss"];
        assert!(last < first, "{first} -> {last}");

        // Clone into a *fresh* trainable (PBT exploit path).
        let mut t2 = JaxTrainable::new(svc.clone(), &cfg(0.1, "relu"), 2, "mlp", 5).unwrap();
        t2.restore(&blob).unwrap();
        let resumed = t2.step().unwrap().metrics["loss"];
        assert!(resumed < first, "restored loss {resumed} vs fresh {first}");
        svc.shutdown();
    }

    #[test]
    fn factory_propagates_bad_variant_as_step_error() {
        let Some(svc) = svc() else { return };
        let f = jax_factory(svc.clone(), "mlp", 1);
        let mut c = Config::new();
        c.insert("model".into(), ParamValue::Str("no_such_model".into()));
        let mut t = f(&c, 0);
        assert!(t.step().is_err());
        svc.shutdown();
    }
}
