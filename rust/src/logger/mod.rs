//! Monitoring & persistence (§3: "monitoring and visualization of trial
//! progress and outcomes"): a logger interface the runner fans results
//! out to, with console, JSONL and in-memory implementations, plus the
//! offline [`analysis`] module that reads the logs back.

use std::collections::BTreeMap;

use crate::coordinator::trial::{ResultRow, Trial, TrialId};
use crate::util::intern::MetricSchema;

pub mod analysis;
pub mod jsonl;
pub mod progress;

pub use analysis::ExperimentAnalysis;
pub use jsonl::JsonlLogger;
pub use progress::ProgressReporter;

/// Receives every intermediate result and lifecycle transition. Result
/// rows carry interned metric ids; the experiment's [`MetricSchema`] is
/// passed alongside so loggers that need names (JSONL, console) resolve
/// them without per-row string allocation.
pub trait ResultLogger: Send {
    /// One intermediate result arrived for `trial`.
    fn on_result(&mut self, schema: &MetricSchema, trial: &Trial, row: &ResultRow);
    /// A crash-resume *replayed* result: the iteration was already
    /// processed (and reported) before the crash and is re-executing
    /// only to rebuild state. Default: ignored, so live reporters do
    /// not double-report; durable logs override this to re-write the
    /// pruned rows (see `JsonlLogger`).
    fn on_replayed_result(&mut self, _schema: &MetricSchema, _trial: &Trial, _row: &ResultRow) {}
    /// `trial` reached a terminal status.
    fn on_trial_end(&mut self, _trial: &Trial) {}
    /// The whole experiment finished.
    fn on_experiment_end(&mut self, _trials: &BTreeMap<TrialId, Trial>) {}
}

/// In-memory recorder used by tests and the analysis pipeline.
#[derive(Default)]
pub struct MemoryLogger {
    /// Every (trial, result) pair observed, in arrival order.
    pub rows: Vec<(TrialId, ResultRow)>,
    /// Trials that ended, in completion order.
    pub ended: Vec<TrialId>,
}

impl MemoryLogger {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }
}

impl ResultLogger for MemoryLogger {
    fn on_result(&mut self, _schema: &MetricSchema, trial: &Trial, row: &ResultRow) {
        self.rows.push((trial.id, row.clone()));
    }
    fn on_trial_end(&mut self, trial: &Trial) {
        self.ended.push(trial.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::Config;
    use crate::ray::Resources;

    #[test]
    fn memory_logger_records() {
        let mut schema = MetricSchema::new();
        let loss = schema.intern("loss");
        let mut l = MemoryLogger::new();
        let t = Trial::new(1, Config::new(), Resources::cpu(1.0), 0);
        l.on_result(&schema, &t, &ResultRow::new(1, 1.0).with(loss, 0.5));
        l.on_trial_end(&t);
        assert_eq!(l.rows.len(), 1);
        assert_eq!(l.rows[0].1.get(loss), Some(0.5));
        assert_eq!(l.ended, vec![1]);
    }
}
