//! Offline experiment analysis: read the JSONL logs back, find best
//! trials/configs, and extract best-metric-vs-budget curves — the
//! "performance analysis" role Vizier/Tune expose to users, and what
//! the benches use to compare schedulers (C1/C2).
//!
//! The loader is deliberately crash-tolerant: a half-written final line
//! (the process died mid-`write`) is skipped, and a missing
//! `experiment.json` summary is never required — only the per-trial
//! `trial_*.jsonl` files are read.
//!
//! # Example
//!
//! ```
//! use tune::coordinator::trial::Mode;
//! use tune::logger::ExperimentAnalysis;
//!
//! let dir = std::env::temp_dir().join(format!("tune_doc_analysis_{}", std::process::id()));
//! std::fs::create_dir_all(&dir).unwrap();
//! std::fs::write(
//!     dir.join("trial_0000.jsonl"),
//!     "{\"trial\":0,\"config\":{\"lr\":0.1},\"seed\":1}\n\
//!      {\"trial\":0,\"iteration\":1,\"time_total_s\":1.0,\"loss\":0.5}\n",
//! )
//! .unwrap();
//!
//! let a = ExperimentAnalysis::load(&dir).unwrap();
//! assert_eq!(a.num_results(), 1);
//! assert_eq!(a.best_trial("loss", Mode::Min), Some((0, 0.5)));
//! std::fs::remove_dir_all(&dir).unwrap();
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::coordinator::trial::Mode;
use crate::util::json::{parse, Json};

/// One trial's history as reconstructed from its JSONL log.
#[derive(Clone, Debug)]
pub struct TrialRecord {
    /// Trial id.
    pub trial: u64,
    /// Config rendered as strings (the JSONL header form).
    pub config: BTreeMap<String, String>,
    /// Result rows as (iter, time, metrics).
    pub rows: Vec<(u64, f64, BTreeMap<String, f64>)>,
    /// Terminal status string, if the end line was written.
    pub end_status: Option<String>,
    /// Best metric from the end line, if present.
    pub best_metric: Option<f64>,
}

/// Offline view over a whole experiment's JSONL logs.
#[derive(Clone, Debug, Default)]
pub struct ExperimentAnalysis {
    /// Reconstructed trials by id.
    pub trials: BTreeMap<u64, TrialRecord>,
}

impl ExperimentAnalysis {
    /// Load every `trial_*.jsonl` under `dir`.
    pub fn load(dir: &Path) -> std::io::Result<Self> {
        let mut out = ExperimentAnalysis::default();
        let mut entries: Vec<_> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map_or(false, |n| n.starts_with("trial_") && n.ends_with(".jsonl"))
            })
            .collect();
        entries.sort();
        for path in entries {
            let text = std::fs::read_to_string(&path)?;
            if let Some(rec) = Self::parse_trial(&text) {
                out.trials.insert(rec.trial, rec);
            }
        }
        Ok(out)
    }

    fn parse_trial(text: &str) -> Option<TrialRecord> {
        let mut rec: Option<TrialRecord> = None;
        for line in text.lines() {
            let Ok(v) = parse(line) else { continue };
            if let Some(cfg) = v.get("config") {
                // Header line.
                let config = cfg
                    .as_obj()?
                    .iter()
                    .map(|(k, jv)| {
                        let s = match jv {
                            Json::Str(s) => s.clone(),
                            Json::Num(n) => format!("{n}"),
                            Json::Bool(b) => format!("{b}"),
                            _ => String::new(),
                        };
                        (k.clone(), s)
                    })
                    .collect();
                rec = Some(TrialRecord {
                    trial: v.get("trial")?.as_u64()?,
                    config,
                    rows: Vec::new(),
                    end_status: None,
                    best_metric: None,
                });
            } else if let Some(end) = v.get("end") {
                if let Some(r) = rec.as_mut() {
                    r.end_status = end.as_str().map(|s| s.to_string());
                    r.best_metric = v.get("best_metric").and_then(|m| m.as_f64());
                }
            } else if let (Some(iter), Some(r)) = (v.get("iteration"), rec.as_mut()) {
                let iter = iter.as_u64()?;
                let time = v.get("time_total_s").and_then(|t| t.as_f64()).unwrap_or(0.0);
                let metrics = v
                    .as_obj()?
                    .iter()
                    .filter(|(k, _)| {
                        !matches!(k.as_str(), "trial" | "iteration" | "time_total_s")
                    })
                    .filter_map(|(k, jv)| jv.as_f64().map(|f| (k.clone(), f)))
                    .collect();
                r.rows.push((iter, time, metrics));
            }
        }
        rec
    }

    /// Best (trial id, metric value) under `mode`. NaN metric values
    /// (serialized as `null`, re-read as absent) never win; the outer
    /// comparison is the NaN-proof total order as belt and braces.
    pub fn best_trial(&self, metric: &str, mode: Mode) -> Option<(u64, f64)> {
        self.trials
            .values()
            .filter_map(|t| {
                t.rows
                    .iter()
                    .filter_map(|(_, _, m)| m.get(metric).copied())
                    .filter(|v| !v.is_nan())
                    .fold(None, |acc: Option<f64>, v| {
                        Some(acc.map_or(v, |a| if mode.better(v, a) { v } else { a }))
                    })
                    .map(|v| (t.trial, v))
            })
            .max_by(|a, b| crate::util::order::asc(mode.ascending(a.1), mode.ascending(b.1)))
    }

    /// Experiment-level best-metric-so-far vs cumulative budget
    /// (total virtual/wall seconds consumed across all trials).
    pub fn best_vs_budget(&self, metric: &str, mode: Mode) -> Vec<(f64, f64)> {
        // Merge all rows by per-trial time deltas to get global budget.
        let mut events: Vec<(f64, f64)> = Vec::new(); // (delta budget, value)
        for t in self.trials.values() {
            let mut prev = 0.0;
            for (_, time, m) in &t.rows {
                if let Some(v) = m.get(metric) {
                    events.push(((time - prev).max(0.0), *v));
                }
                prev = *time;
            }
        }
        // Order events by per-trial time is lost; approximate by
        // original insertion (trial-major) — callers that need exact
        // interleaving use the runner's in-memory best_curve instead.
        let mut budget = 0.0;
        let mut best = mode.worst();
        let mut curve = Vec::with_capacity(events.len());
        for (dt, v) in events {
            budget += dt;
            if mode.better(v, best) {
                best = v;
            }
            curve.push((budget, best));
        }
        curve
    }

    /// Total result rows across all trials.
    pub fn num_results(&self) -> usize {
        self.trials.values().map(|t| t.rows.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::{Config, ParamValue, ResultRow, Trial};
    use crate::logger::{JsonlLogger, ResultLogger};
    use crate::ray::Resources;

    #[test]
    fn roundtrip_through_jsonl() {
        let dir = std::env::temp_dir().join(format!("tune_analysis_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut schema = crate::util::intern::MetricSchema::new();
        let loss_id = schema.intern("loss");
        let mut l = JsonlLogger::new(dir.clone()).unwrap();
        for id in 0..3u64 {
            let mut c = Config::new();
            c.insert("lr".into(), ParamValue::F64(0.1 * (id + 1) as f64));
            let mut t = Trial::new(id, c, Resources::cpu(1.0), id);
            for it in 1..=4 {
                let loss = 1.0 / (it as f64) + id as f64; // trial 0 best
                let row = ResultRow::new(it, it as f64).with(loss_id, loss);
                t.record(row.clone(), loss_id, Mode::Min);
                l.on_result(&schema, &t, &row);
            }
            l.on_trial_end(&t);
        }
        let a = ExperimentAnalysis::load(&dir).unwrap();
        assert_eq!(a.trials.len(), 3);
        assert_eq!(a.num_results(), 12);
        let (best_id, best_v) = a.best_trial("loss", Mode::Min).unwrap();
        assert_eq!(best_id, 0);
        assert!((best_v - 0.25).abs() < 1e-9);
        let curve = a.best_vs_budget("loss", Mode::Min);
        assert_eq!(curve.len(), 12);
        // Monotone non-increasing best for Min mode.
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
            assert!(w[1].0 >= w[0].0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tolerates_truncated_final_line() {
        // Regression (crash-mid-write): a process killed while flushing
        // leaves a partial last line; analysis must keep every complete
        // row and ignore the fragment.
        let dir = std::env::temp_dir().join(format!("tune_analysis_trunc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("trial_0000.jsonl"),
            "{\"trial\":0,\"config\":{\"lr\":0.1},\"seed\":1}\n\
             {\"trial\":0,\"iteration\":1,\"time_total_s\":1.0,\"loss\":0.5}\n\
             {\"trial\":0,\"iteration\":2,\"time_total_s\":2.0,\"lo",
        )
        .unwrap();
        let a = ExperimentAnalysis::load(&dir).unwrap();
        assert_eq!(a.trials.len(), 1);
        assert_eq!(a.num_results(), 1); // the fragment is dropped
        assert_eq!(a.best_trial("loss", Mode::Min), Some((0, 0.5)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tolerates_missing_experiment_summary() {
        // Regression (crash before on_experiment_end): no experiment.json
        // exists, only trial logs — load must still succeed.
        let dir = std::env::temp_dir().join(format!("tune_analysis_nosum_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut schema = crate::util::intern::MetricSchema::new();
        let loss_id = schema.intern("loss");
        let mut l = JsonlLogger::new(dir.clone()).unwrap();
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(0.2));
        let t = Trial::new(4, c, Resources::cpu(1.0), 0);
        l.on_result(&schema, &t, &ResultRow::new(1, 1.0).with(loss_id, 0.9));
        drop(l); // crash: neither on_trial_end nor on_experiment_end ran
        assert!(!dir.join("experiment.json").exists());
        let a = ExperimentAnalysis::load(&dir).unwrap();
        assert_eq!(a.trials.len(), 1);
        assert_eq!(a.trials[&4].rows.len(), 1);
        assert!(a.trials[&4].end_status.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
