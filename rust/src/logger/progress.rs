//! Console progress reporter: the periodic status table Tune prints
//! ("the progress of trials is periodically reported in the console",
//! §4.3). Throttled by result count so sim-mode experiments with
//! millions of virtual seconds don't flood the terminal.

use std::collections::BTreeMap;

use crate::coordinator::trial::{config_str, ResultRow, Trial, TrialId, TrialStatus};
use crate::util::intern::MetricSchema;

use super::ResultLogger;

/// Console status-table reporter, throttled by result count.
pub struct ProgressReporter {
    /// Print every N results (0 = silent until the end).
    pub every: u64,
    metric: String,
    seen: u64,
    /// trial -> (status, iteration, last metric)
    table: BTreeMap<TrialId, (TrialStatus, u64, Option<f64>, String)>,
}

impl ProgressReporter {
    /// New reporter tracking `metric`, printing every `every` results.
    pub fn new(metric: &str, every: u64) -> Self {
        ProgressReporter { every, metric: metric.into(), seen: 0, table: BTreeMap::new() }
    }

    fn print_table(&self) {
        let counts = |s: TrialStatus| self.table.values().filter(|(st, ..)| *st == s).count();
        println!(
            "== status: {} RUNNING | {} PENDING | {} PAUSED | {} terminal ==",
            counts(TrialStatus::Running),
            counts(TrialStatus::Pending),
            counts(TrialStatus::Paused),
            self.table
                .values()
                .filter(|(st, ..)| st.is_terminal())
                .count(),
        );
        for (id, (status, iter, metric, cfg)) in self.table.iter().take(12) {
            println!(
                "  trial {id:>4} {:<10} iter {iter:>6} {}={} [{}]",
                format!("{status:?}"),
                self.metric,
                metric.map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into()),
                cfg
            );
        }
        if self.table.len() > 12 {
            println!("  ... {} more trials", self.table.len() - 12);
        }
    }
}

impl ResultLogger for ProgressReporter {
    fn on_result(&mut self, schema: &MetricSchema, trial: &Trial, row: &ResultRow) {
        self.table.insert(
            trial.id,
            (
                trial.status,
                row.iteration,
                row.metric(schema, &self.metric),
                config_str(&trial.config),
            ),
        );
        self.seen += 1;
        if self.every > 0 && self.seen % self.every == 0 {
            self.print_table();
        }
    }

    fn on_trial_end(&mut self, trial: &Trial) {
        if let Some(e) = self.table.get_mut(&trial.id) {
            e.0 = trial.status;
        }
    }

    fn on_experiment_end(&mut self, trials: &BTreeMap<TrialId, Trial>) {
        for t in trials.values() {
            self.table.insert(
                t.id,
                (t.status, t.iteration, t.best_metric, config_str(&t.config)),
            );
        }
        self.print_table();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::Config;
    use crate::ray::Resources;

    #[test]
    fn tracks_status_counts() {
        let mut schema = MetricSchema::new();
        let loss = schema.intern("loss");
        let mut p = ProgressReporter::new("loss", 0);
        let mut t = Trial::new(1, Config::new(), Resources::cpu(1.0), 0);
        t.status = TrialStatus::Running;
        p.on_result(&schema, &t, &ResultRow::new(1, 1.0).with(loss, 0.3));
        assert_eq!(p.table[&1].0, TrialStatus::Running);
        assert_eq!(p.table[&1].2, Some(0.3));
        t.status = TrialStatus::Completed;
        p.on_trial_end(&t);
        assert_eq!(p.table[&1].0, TrialStatus::Completed);
    }
}
