//! JSONL result logs — one file per trial plus an experiment summary,
//! the moral equivalent of Tune's result.json/TensorBoard integration.
//! `ExperimentAnalysis` (and the `analyze` CLI subcommand) reads these
//! back.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::PathBuf;

use crate::coordinator::trial::{config_str, ParamValue, ResultRow, Trial, TrialId};
use crate::util::json::Json;

use super::ResultLogger;

/// Writes one `trial_NNNN.jsonl` per trial plus `experiment.json`.
pub struct JsonlLogger {
    dir: PathBuf,
    writers: BTreeMap<TrialId, BufWriter<File>>,
    /// Resume mode: append to existing trial logs (headers already
    /// written before the crash) instead of truncating them.
    append: bool,
}

impl JsonlLogger {
    /// Create (and mkdir -p) a logger rooted at `dir`.
    pub fn new(dir: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(JsonlLogger { dir, writers: BTreeMap::new(), append: false })
    }

    /// Logger for a resumed experiment: existing `trial_*.jsonl` files
    /// are appended to (their header lines survive from the previous
    /// run); logs for trials first seen after the resume are created
    /// normally. The runner prunes stale rows before attaching this.
    pub fn resume(dir: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(JsonlLogger { dir, writers: BTreeMap::new(), append: true })
    }

    /// The directory logs are written under.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    fn config_json(trial: &Trial) -> Json {
        Json::Obj(
            trial
                .config
                .iter()
                .map(|(k, v)| {
                    let jv = match v {
                        ParamValue::F64(f) => Json::Num(*f),
                        ParamValue::I64(i) => Json::Num(*i as f64),
                        ParamValue::Str(s) => Json::Str(s.clone()),
                        ParamValue::Bool(b) => Json::Bool(*b),
                    };
                    (k.clone(), jv)
                })
                .collect(),
        )
    }

    fn row_json(trial: &Trial, row: &ResultRow) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("trial".into(), Json::Num(trial.id as f64));
        obj.insert("iteration".into(), Json::Num(row.iteration as f64));
        obj.insert("time_total_s".into(), Json::Num(row.time_total_s));
        for (k, v) in &row.metrics {
            obj.insert(k.clone(), Json::Num(*v));
        }
        Json::Obj(obj)
    }
}

impl ResultLogger for JsonlLogger {
    fn on_result(&mut self, trial: &Trial, row: &ResultRow) {
        let dir = self.dir.clone();
        let append = self.append;
        let w = self.writers.entry(trial.id).or_insert_with(|| {
            let path = dir.join(format!("trial_{:04}.jsonl", trial.id));
            // Resume mode reopens a surviving log in append position (its
            // header is already on disk); everything else starts fresh.
            let existing = append
                && std::fs::metadata(&path).map(|m| m.len() > 0).unwrap_or(false);
            let file = if existing {
                OpenOptions::new().append(true).open(&path)
            } else {
                File::create(&path)
            };
            let mut w = BufWriter::new(file.expect("create trial log"));
            if !existing {
                // First line: the trial header (config, seed). The seed
                // is a full-range u64 (forked from the experiment RNG),
                // so it is hex-encoded — Json::Num is an f64 and would
                // round it.
                let header = Json::obj(vec![
                    ("trial", Json::Num(trial.id as f64)),
                    ("config", Self::config_json(trial)),
                    ("config_str", Json::Str(config_str(&trial.config))),
                    ("seed", crate::util::json::u64_to_json(trial.seed)),
                ]);
                writeln!(w, "{}", header.to_string()).ok();
            }
            w
        });
        writeln!(w, "{}", Self::row_json(trial, row).to_string()).ok();
    }

    /// Replayed rows are logged normally: the resume path pruned this
    /// trial's log back to the rollback point, so re-writing them keeps
    /// the on-disk history complete and duplicate-free.
    fn on_replayed_result(&mut self, trial: &Trial, row: &ResultRow) {
        self.on_result(trial, row);
    }

    fn on_trial_end(&mut self, trial: &Trial) {
        if let Some(mut w) = self.writers.remove(&trial.id) {
            let end = Json::obj(vec![
                ("trial", Json::Num(trial.id as f64)),
                ("end", Json::Str(format!("{:?}", trial.status))),
                ("iterations", Json::Num(trial.iteration as f64)),
                ("best_metric", trial.best_metric.map(Json::Num).unwrap_or(Json::Null)),
            ]);
            writeln!(w, "{}", end.to_string()).ok();
            w.flush().ok();
        }
    }

    fn on_experiment_end(&mut self, trials: &BTreeMap<TrialId, Trial>) {
        for w in self.writers.values_mut() {
            w.flush().ok();
        }
        let summary = Json::Arr(
            trials
                .values()
                .map(|t| {
                    Json::obj(vec![
                        ("trial", Json::Num(t.id as f64)),
                        ("status", Json::Str(format!("{:?}", t.status))),
                        ("iterations", Json::Num(t.iteration as f64)),
                        ("best_metric", t.best_metric.map(Json::Num).unwrap_or(Json::Null)),
                        ("config", Self::config_json(t)),
                        ("mutations", Json::Num(t.mutations as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write(self.dir.join("experiment.json"), summary.to_string()).ok();
    }
}

impl Drop for JsonlLogger {
    /// Flush everything buffered: rows logged before a panic or an
    /// abandoned run must still reach disk (`BufWriter`'s own drop
    /// flushes too, but silently — this makes the guarantee explicit
    /// and keeps it even if the buffering strategy changes).
    fn drop(&mut self) {
        for w in self.writers.values_mut() {
            w.flush().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::{Config, TrialStatus};
    use crate::ray::Resources;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tune_jsonl_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn writes_header_rows_and_summary() {
        let dir = tmpdir("basic");
        let mut l = JsonlLogger::new(dir.clone()).unwrap();
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(0.1));
        let mut t = Trial::new(3, c, Resources::cpu(1.0), 7);
        l.on_result(&t, &ResultRow::new(1, 0.5).with("loss", 1.0));
        l.on_result(&t, &ResultRow::new(2, 1.0).with("loss", 0.5));
        t.status = TrialStatus::Completed;
        t.iteration = 2;
        t.best_metric = Some(0.5);
        l.on_trial_end(&t);
        let mut trials = BTreeMap::new();
        trials.insert(t.id, t);
        l.on_experiment_end(&trials);

        let log = std::fs::read_to_string(dir.join("trial_0003.jsonl")).unwrap();
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines.len(), 4); // header + 2 rows + end
        let header = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(header.get("config.lr").unwrap().as_f64(), Some(0.1));
        let summary =
            crate::util::json::parse(&std::fs::read_to_string(dir.join("experiment.json")).unwrap())
                .unwrap();
        assert_eq!(summary.as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flushes_on_drop_without_trial_end() {
        // Regression: rows from a crashed/abandoned run must reach disk
        // even though on_trial_end/on_experiment_end never ran.
        let dir = tmpdir("drop");
        {
            let mut l = JsonlLogger::new(dir.clone()).unwrap();
            let mut c = Config::new();
            c.insert("lr".into(), ParamValue::F64(0.1));
            let t = Trial::new(1, c, Resources::cpu(1.0), 0);
            l.on_result(&t, &ResultRow::new(1, 0.5).with("loss", 1.0));
        } // dropped here, mid-experiment
        let log = std::fs::read_to_string(dir.join("trial_0001.jsonl")).unwrap();
        assert_eq!(log.lines().count(), 2); // header + 1 row
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_appends_without_duplicate_header() {
        let dir = tmpdir("resume");
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(0.1));
        let t = Trial::new(2, c, Resources::cpu(1.0), 0);
        {
            let mut l = JsonlLogger::new(dir.clone()).unwrap();
            l.on_result(&t, &ResultRow::new(1, 0.5).with("loss", 1.0));
        }
        {
            let mut l = JsonlLogger::resume(dir.clone()).unwrap();
            l.on_result(&t, &ResultRow::new(2, 1.0).with("loss", 0.8));
        }
        let log = std::fs::read_to_string(dir.join("trial_0002.jsonl")).unwrap();
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines.len(), 3, "{log}"); // one header + two rows
        assert!(lines[0].contains("config"));
        assert!(lines[1].contains("\"iteration\":1"));
        assert!(lines[2].contains("\"iteration\":2"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
