//! JSONL result logs — one file per trial plus an experiment summary,
//! the moral equivalent of Tune's result.json/TensorBoard integration.
//! `ExperimentAnalysis` (and the `analyze` CLI subcommand) reads these
//! back.
//!
//! Perf: the per-result path streams each line into one reusable
//! `String` buffer with the `util::json` streaming writers — no
//! intermediate `Json::Obj`, no `BTreeMap`, no per-line `to_string()`
//! allocation. Metric names come from the experiment's interned
//! [`MetricSchema`], borrowed, never cloned.
//!
//! Robustness: a trial log that cannot be created (the directory
//! vanished, permissions changed under a long-running `tune serve`)
//! degrades to a once-per-trial warning and dropped rows for that trial
//! — it must never panic the shared hub.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::PathBuf;

use crate::coordinator::trial::{Config, ParamValue, ResultRow, Trial, TrialId};
use crate::util::intern::MetricSchema;
use crate::util::json::{write_json_f64, write_json_str, Json};

use super::ResultLogger;

/// Writes one `trial_NNNN.jsonl` per trial plus `experiment.json`.
pub struct JsonlLogger {
    dir: PathBuf,
    /// `None` marks a trial whose log could not be created: the failure
    /// was warned about once and its rows are dropped.
    writers: BTreeMap<TrialId, Option<BufWriter<File>>>,
    /// Resume mode: append to existing trial logs (headers already
    /// written before the crash) instead of truncating them.
    append: bool,
    /// Reusable line buffer (the streaming encoder's only allocation,
    /// amortized to zero once it reaches steady-state capacity).
    buf: String,
}

impl JsonlLogger {
    /// Create (and mkdir -p) a logger rooted at `dir`.
    pub fn new(dir: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(JsonlLogger { dir, writers: BTreeMap::new(), append: false, buf: String::new() })
    }

    /// Logger for a resumed experiment: existing `trial_*.jsonl` files
    /// are appended to (their header lines survive from the previous
    /// run); logs for trials first seen after the resume are created
    /// normally. The runner prunes stale rows before attaching this.
    pub fn resume(dir: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(JsonlLogger { dir, writers: BTreeMap::new(), append: true, buf: String::new() })
    }

    /// The directory logs are written under.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Stream a config object (`{"lr":0.1,"act":"relu"}`) into `out` —
    /// keys and string values are borrowed, never cloned.
    fn write_config(config: &Config, out: &mut String) {
        out.push('{');
        for (i, (k, v)) in config.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write_json_str(k, out);
            out.push(':');
            match v {
                ParamValue::F64(f) => write_json_f64(*f, out),
                ParamValue::I64(n) => {
                    use std::fmt::Write as _;
                    let _ = write!(out, "{n}");
                }
                ParamValue::Str(s) => write_json_str(s, out),
                ParamValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            }
        }
        out.push('}');
    }

    /// Stream the per-trial header line (config, seed) into `out`.
    fn write_header(trial: &Trial, out: &mut String) {
        use std::fmt::Write as _;
        out.push_str("{\"trial\":");
        let _ = write!(out, "{}", trial.id);
        out.push_str(",\"config\":");
        Self::write_config(&trial.config, out);
        out.push_str(",\"config_str\":");
        // config_str allocates, but this runs once per trial, not per
        // result; the escaped write still borrows it.
        let cfg = crate::coordinator::trial::config_str(&trial.config);
        write_json_str(&cfg, out);
        // The seed is a full-range u64 (forked from the experiment
        // RNG), so it is hex-encoded — a JSON number is an f64 and
        // would round it.
        let _ = write!(out, ",\"seed\":\"{:016x}\"}}", trial.seed);
        out.push('\n');
    }

    /// Stream one result line into `out`.
    fn write_row(schema: &MetricSchema, trial: &Trial, row: &ResultRow, out: &mut String) {
        use std::fmt::Write as _;
        out.push_str("{\"trial\":");
        let _ = write!(out, "{}", trial.id);
        out.push_str(",\"iteration\":");
        let _ = write!(out, "{}", row.iteration);
        out.push_str(",\"time_total_s\":");
        write_json_f64(row.time_total_s, out);
        for (id, v) in &row.metrics {
            if let Some(name) = schema.name(*id) {
                out.push(',');
                write_json_str(name, out);
                out.push(':');
                write_json_f64(*v, out);
            }
        }
        out.push_str("}\n");
    }

    /// Open one trial's log and write its header (cold path: once per
    /// trial, so the header's local buffer allocation is fine). `None`
    /// when the file cannot be created — warned once, rows dropped.
    fn open_writer(dir: &std::path::Path, append: bool, trial: &Trial) -> Option<BufWriter<File>> {
        // lint:allow(durability): trial logs are append-only JSONL streams — torn
        // tails are expected and skipped by the resume scanner; routing them
        // through write_atomic would mean rewriting the whole log per row.
        let path = dir.join(format!("trial_{:04}.jsonl", trial.id));
        // Resume mode reopens a surviving log in append position (its
        // header is already on disk); everything else starts fresh.
        let existing = append && std::fs::metadata(&path).map(|m| m.len() > 0).unwrap_or(false);
        let file = if existing {
            OpenOptions::new().append(true).open(&path)
        } else {
            File::create(&path)
        };
        match file {
            Ok(f) => {
                let mut w = BufWriter::new(f);
                if !existing {
                    let mut header = String::new();
                    Self::write_header(trial, &mut header);
                    w.write_all(header.as_bytes()).ok();
                }
                Some(w)
            }
            Err(e) => {
                // Degrade, never panic: one unwritable log dir under
                // `tune serve` must not take the hub down.
                eprintln!(
                    "jsonl: cannot create log for trial {} at {path:?}: {e}; \
                     dropping its rows",
                    trial.id
                );
                None
            }
        }
    }
}

impl ResultLogger for JsonlLogger {
    fn on_result(&mut self, schema: &MetricSchema, trial: &Trial, row: &ResultRow) {
        // Encode into the reusable buffer, then resolve the writer with
        // ONE map lookup — `buf`/`dir` and `writers` are disjoint
        // fields, so the shared borrows coexist with the entry.
        self.buf.clear();
        Self::write_row(schema, trial, row, &mut self.buf);
        let (dir, append, buf) = (&self.dir, self.append, &self.buf);
        if let Some(w) = self
            .writers
            .entry(trial.id)
            .or_insert_with(|| Self::open_writer(dir, append, trial))
            .as_mut()
        {
            w.write_all(buf.as_bytes()).ok();
        }
    }

    /// Replayed rows are logged normally: the resume path pruned this
    /// trial's log back to the rollback point, so re-writing them keeps
    /// the on-disk history complete and duplicate-free.
    fn on_replayed_result(&mut self, schema: &MetricSchema, trial: &Trial, row: &ResultRow) {
        self.on_result(schema, trial, row);
    }

    fn on_trial_end(&mut self, trial: &Trial) {
        if let Some(Some(mut w)) = self.writers.remove(&trial.id) {
            let end = Json::obj(vec![
                ("trial", Json::Num(trial.id as f64)),
                ("end", Json::Str(format!("{:?}", trial.status))),
                ("iterations", Json::Num(trial.iteration as f64)),
                ("best_metric", trial.best_metric.map(Json::Num).unwrap_or(Json::Null)),
            ]);
            self.buf.clear();
            end.write_to(&mut self.buf);
            self.buf.push('\n');
            w.write_all(self.buf.as_bytes()).ok();
            w.flush().ok();
        }
    }

    fn on_experiment_end(&mut self, trials: &BTreeMap<TrialId, Trial>) {
        for w in self.writers.values_mut().flatten() {
            w.flush().ok();
        }
        // Cold path, but streamed anyway: configs are borrowed into the
        // buffer instead of cloned into a Json tree.
        use std::fmt::Write as _;
        let mut out = String::new();
        out.push('[');
        for (i, t) in trials.values().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("{\"trial\":");
            let _ = write!(out, "{}", t.id);
            let _ = write!(out, ",\"status\":\"{:?}\"", t.status);
            let _ = write!(out, ",\"iterations\":{}", t.iteration);
            out.push_str(",\"best_metric\":");
            match t.best_metric {
                Some(m) => write_json_f64(m, &mut out),
                None => out.push_str("null"),
            }
            out.push_str(",\"config\":");
            Self::write_config(&t.config, &mut out);
            let _ = write!(out, ",\"mutations\":{}}}", t.mutations);
        }
        out.push(']');
        // The end-of-run summary is a real recovery artifact: write it
        // atomically so a crash mid-write can never leave a torn
        // experiment.json next to intact trial logs.
        crate::coordinator::persist::write_atomic(&self.dir.join("experiment.json"), &out).ok();
    }
}

impl Drop for JsonlLogger {
    /// Flush everything buffered: rows logged before a panic or an
    /// abandoned run must still reach disk (`BufWriter`'s own drop
    /// flushes too, but silently — this makes the guarantee explicit
    /// and keeps it even if the buffering strategy changes).
    fn drop(&mut self) {
        for w in self.writers.values_mut().flatten() {
            w.flush().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::{Config, TrialStatus};
    use crate::ray::Resources;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tune_jsonl_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn loss_schema() -> (MetricSchema, u32) {
        let mut s = MetricSchema::new();
        let id = s.intern("loss");
        (s, id)
    }

    #[test]
    fn writes_header_rows_and_summary() {
        let dir = tmpdir("basic");
        let (schema, loss) = loss_schema();
        let mut l = JsonlLogger::new(dir.clone()).unwrap();
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(0.1));
        let mut t = Trial::new(3, c, Resources::cpu(1.0), 7);
        l.on_result(&schema, &t, &ResultRow::new(1, 0.5).with(loss, 1.0));
        l.on_result(&schema, &t, &ResultRow::new(2, 1.0).with(loss, 0.5));
        t.status = TrialStatus::Completed;
        t.iteration = 2;
        t.best_metric = Some(0.5);
        l.on_trial_end(&t);
        let mut trials = BTreeMap::new();
        trials.insert(t.id, t);
        l.on_experiment_end(&trials);

        let log = std::fs::read_to_string(dir.join("trial_0003.jsonl")).unwrap();
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines.len(), 4); // header + 2 rows + end
        let header = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(header.get("config.lr").unwrap().as_f64(), Some(0.1));
        assert_eq!(header.get("seed").unwrap().as_str(), Some("0000000000000007"));
        let row = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(row.get("loss").unwrap().as_f64(), Some(1.0));
        assert_eq!(row.get("iteration").unwrap().as_u64(), Some(1));
        let summary =
            crate::util::json::parse(&std::fs::read_to_string(dir.join("experiment.json")).unwrap())
                .unwrap();
        let arr = summary.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("config.lr").unwrap().as_f64(), Some(0.1));
        assert_eq!(arr[0].get("status").unwrap().as_str(), Some("Completed"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streamed_lines_match_parser_roundtrip() {
        // Escaped config strings and non-finite metrics must survive the
        // streaming encoder exactly like the old tree encoder.
        let dir = tmpdir("escape");
        let (mut schema, loss) = loss_schema();
        let nan = schema.intern("weird\"metric");
        let mut c = Config::new();
        c.insert("act\n".into(), ParamValue::Str("re\"lu".into()));
        c.insert("layers".into(), ParamValue::I64(-3));
        c.insert("debug".into(), ParamValue::Bool(true));
        let t = Trial::new(1, c, Resources::cpu(1.0), u64::MAX);
        let mut l = JsonlLogger::new(dir.clone()).unwrap();
        let row = ResultRow::new(1, 0.5).with(loss, 0.25).with(nan, f64::NAN);
        l.on_result(&schema, &t, &row);
        drop(l);
        let log = std::fs::read_to_string(dir.join("trial_0001.jsonl")).unwrap();
        let header = crate::util::json::parse(log.lines().next().unwrap()).unwrap();
        assert_eq!(header.get("config.act\n").unwrap().as_str(), Some("re\"lu"));
        assert_eq!(header.get("config.layers").unwrap().as_f64(), Some(-3.0));
        assert_eq!(header.get("config.debug").unwrap().as_bool(), Some(true));
        assert_eq!(header.get("seed").unwrap().as_str(), Some("ffffffffffffffff"));
        let parsed = crate::util::json::parse(log.lines().nth(1).unwrap()).unwrap();
        assert_eq!(parsed.get("loss").unwrap().as_f64(), Some(0.25));
        // NaN serializes as null, exactly like Json::Num did.
        assert_eq!(parsed.get("weird\"metric"), Some(&Json::Null));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unwritable_dir_degrades_to_dropped_rows_not_panic() {
        // Regression for `tune serve`: the log directory vanishing mid-
        // run (or being unwritable) must drop that trial's rows with a
        // warning — one sick experiment cannot panic the shared hub.
        let dir = tmpdir("gone");
        let (schema, loss) = loss_schema();
        let mut l = JsonlLogger::new(dir.clone()).unwrap();
        std::fs::remove_dir_all(&dir).unwrap(); // yank the dir away
        let t = Trial::new(1, Config::new(), Resources::cpu(1.0), 0);
        l.on_result(&schema, &t, &ResultRow::new(1, 0.5).with(loss, 1.0));
        l.on_result(&schema, &t, &ResultRow::new(2, 1.0).with(loss, 0.9));
        l.on_trial_end(&t); // no writer: quietly skipped
        assert!(!dir.join("trial_0001.jsonl").exists());
        // A later trial whose log CAN be created still logs normally.
        std::fs::create_dir_all(&dir).unwrap();
        let t2 = Trial::new(2, Config::new(), Resources::cpu(1.0), 0);
        l.on_result(&schema, &t2, &ResultRow::new(1, 0.5).with(loss, 0.7));
        drop(l);
        assert!(dir.join("trial_0002.jsonl").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flushes_on_drop_without_trial_end() {
        // Regression: rows from a crashed/abandoned run must reach disk
        // even though on_trial_end/on_experiment_end never ran.
        let dir = tmpdir("drop");
        let (schema, loss) = loss_schema();
        {
            let mut l = JsonlLogger::new(dir.clone()).unwrap();
            let mut c = Config::new();
            c.insert("lr".into(), ParamValue::F64(0.1));
            let t = Trial::new(1, c, Resources::cpu(1.0), 0);
            l.on_result(&schema, &t, &ResultRow::new(1, 0.5).with(loss, 1.0));
        } // dropped here, mid-experiment
        let log = std::fs::read_to_string(dir.join("trial_0001.jsonl")).unwrap();
        assert_eq!(log.lines().count(), 2); // header + 1 row
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_appends_without_duplicate_header() {
        let dir = tmpdir("resume");
        let (schema, loss) = loss_schema();
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(0.1));
        let t = Trial::new(2, c, Resources::cpu(1.0), 0);
        {
            let mut l = JsonlLogger::new(dir.clone()).unwrap();
            l.on_result(&schema, &t, &ResultRow::new(1, 0.5).with(loss, 1.0));
        }
        {
            let mut l = JsonlLogger::resume(dir.clone()).unwrap();
            l.on_result(&schema, &t, &ResultRow::new(2, 1.0).with(loss, 0.8));
        }
        let log = std::fs::read_to_string(dir.join("trial_0002.jsonl")).unwrap();
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines.len(), 3, "{log}"); // one header + two rows
        assert!(lines[0].contains("config"));
        assert!(lines[1].contains("\"iteration\":1"));
        assert!(lines[2].contains("\"iteration\":2"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
