//! The experiment hub: N experiments multiplexed over ONE shared
//! bounded worker pool.
//!
//! The paper positions Tune as a *platform*: many users' training
//! scripts and many search algorithms share the system simultaneously.
//! `run_experiments` gives one experiment a private executor; the
//! [`ExperimentHub`] is the serving layer above it — a long-running
//! coordinator that admits experiments dynamically, schedules all of
//! their trials onto one [`SharedPool`], and keeps them isolated:
//!
//! * **Fair-share admission** — live-trial slots are split across
//!   active experiments by weighted share (weight / total weight of a
//!   configurable global live-trial budget), remainder rotating
//!   round-robin so no experiment is starved; every active experiment
//!   is always guaranteed at least one slot, which is also what makes
//!   fault-recovery relaunches deadlock-free under exhausted quotas.
//! * **Isolation** — each experiment keeps its own `TrialRunner` (trial
//!   table, RNG streams, scheduler/search state, fault injector, simulated
//!   cluster), its own trial-id namespace and wall clock on the pool,
//!   and its own durable directory; completion events are routed back
//!   to the owning experiment only. Results are identical to running
//!   the same experiment alone with the same seed.
//! * **Containment** — a trial reporting `NaN` ranks worst instead of
//!   panicking a scheduler (see [`crate::util::order`]), and a
//!   panicking trainable becomes a normal step failure, so one sick
//!   experiment cannot take down its neighbors.
//!
//! `tune serve` wraps this in a file-based control plane: spec files
//! dropped into `<dir>/queue/` become live experiments, `tune status`
//! reads the published status file, `tune stop` ends the server.

use std::time::{Duration, Instant};

use crate::logger::JsonlLogger;
use crate::ray::{AutoscalePolicy, Cluster, Resources};
use crate::trainable::TrainableFactory;
use crate::util::json::Json;

use super::executor::{ExpId, PoolPoll, SharedPool, SharedPoolClient};
use super::experiment::{manifest_json, ExecMode, ExperimentSpec, SchedulerKind, SearchKind};
use super::persist::ExperimentDir;
use super::runner::{ExperimentResult, TrialRunner};
use super::spec::SearchSpace;
use super::trial::Mode;

/// One experiment handed to [`ExperimentHub::submit`].
pub struct Submission {
    /// The experiment parameters (name, metric, samples, seed, ...).
    pub spec: ExperimentSpec,
    /// Hyperparameter search space.
    pub space: SearchSpace,
    /// Trial scheduler selection.
    pub scheduler: SchedulerKind,
    /// Search algorithm selection.
    pub search: SearchKind,
    /// Builds this experiment's trainables (per-experiment: different
    /// experiments can run different workloads on the same pool).
    pub factory: TrainableFactory,
    /// Simulated cluster the experiment's trials lease resources from
    /// (per-experiment, like every other piece of runner state).
    pub cluster: Cluster,
    /// Elastic autoscaling of the experiment's cluster (None = fixed).
    pub autoscale: Option<AutoscalePolicy>,
    /// Fair-share weight (min 1): slots are split proportionally.
    pub weight: u64,
    /// Durable experiment directory (JSONL logs, checkpoint spill,
    /// periodic snapshots), if wanted.
    pub experiment_dir: Option<std::path::PathBuf>,
    /// Snapshot cadence in processed results when `experiment_dir` is
    /// set (0 = final snapshot only).
    pub snapshot_every: u64,
}

impl Submission {
    /// A submission with defaults for everything but the four
    /// experiment-defining pieces: 1-node/8-cpu cluster, weight 1, no
    /// durable directory.
    pub fn new(
        spec: ExperimentSpec,
        space: SearchSpace,
        scheduler: SchedulerKind,
        search: SearchKind,
        factory: TrainableFactory,
    ) -> Self {
        Submission {
            spec,
            space,
            scheduler,
            search,
            factory,
            cluster: Cluster::uniform(1, Resources::cpu(8.0)),
            autoscale: None,
            weight: 1,
            experiment_dir: None,
            snapshot_every: 50,
        }
    }
}

/// Lifecycle of a hub-managed experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExperimentState {
    /// Still holding (or eligible for) live trials.
    Running,
    /// Finalized; its [`ExperimentResult`] is available.
    Finished,
}

struct HubSlot {
    name: String,
    exp: ExpId,
    weight: u64,
    runner: TrialRunner,
    done: bool,
    result: Option<ExperimentResult>,
}

/// A long-running multi-experiment coordinator: every submitted
/// experiment's trials run concurrently over one shared bounded
/// [`SharedPool`], with weighted fair-share admission and full
/// per-experiment isolation (see the module docs).
///
/// ```
/// use tune::coordinator::hub::{ExperimentHub, Submission};
/// use tune::coordinator::spec::SpaceBuilder;
/// use tune::coordinator::{ExperimentSpec, Mode, SchedulerKind, SearchKind};
/// use tune::trainable::{factory, synthetic::CurveTrainable};
///
/// let mut hub = ExperimentHub::new(2, 8);
/// for seed in 0..3u64 {
///     let mut spec = ExperimentSpec::named(&format!("exp-{seed}"));
///     spec.metric = "accuracy".into();
///     spec.mode = Mode::Max;
///     spec.num_samples = 4;
///     spec.max_iterations_per_trial = 5;
///     spec.seed = seed;
///     let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
///     hub.submit(Submission::new(
///         spec, space, SchedulerKind::Fifo, SearchKind::Random,
///         factory(|c, s| Box::new(CurveTrainable::new(c, s))),
///     )).expect("submit");
/// }
/// let results = hub.run_all();
/// assert_eq!(results.len(), 3);
/// assert!(results.iter().all(|(_, r)| r.trials.len() == 4));
/// ```
pub struct ExperimentHub {
    // Declared before `fleet`: slots (and with them the runners' pool
    // handles) drop first, so the owned pool's Drop can join its
    // workers.
    experiments: Vec<HubSlot>,
    /// Shard-scoped pool view: every experiment this hub admits is
    /// registered through (and pumped from) this client.
    pool: SharedPoolClient,
    /// The worker fleet itself when this hub stands alone
    /// (`new`/`with_capacities`); `None` when the hub is one shard of a
    /// [`crate::net::ShardedHub`], which owns the fleet for all shards.
    /// Never read — held purely so the sole-owner fleet drops (and
    /// joins its workers) after the slots above.
    #[allow(dead_code)]
    fleet: Option<SharedPool>,
    /// Global live-trial budget split across active experiments
    /// (0 = no global cap; per-experiment caps and clusters still bind).
    max_live: usize,
    /// Rotates the fair-share remainder (and advances on completions)
    /// so leftover slots spread evenly over time.
    rr_cursor: usize,
    occ_sum: f64,
    occ_samples: u64,
}

impl ExperimentHub {
    /// A hub over a fresh pool of `workers` threads, splitting at most
    /// `max_live` concurrently-running trials across its experiments
    /// (0 = unbounded: each experiment is limited only by its own
    /// `max_concurrent` and cluster capacity).
    pub fn new(workers: usize, max_live: usize) -> Self {
        Self::over(SharedPool::new(workers), max_live)
    }

    /// A hub whose shared pool carries per-worker capacity vectors: the
    /// fleet admits live trainables by vector fit, and fair share is
    /// additionally dealt as *resource-weighted* shares of the total
    /// capacity (each experiment's running demands must fit inside its
    /// weighted slice of the fleet, with one running trial always
    /// allowed).
    pub fn with_capacities(caps: Vec<Resources>, max_live: usize) -> Self {
        Self::over(SharedPool::with_capacities(caps), max_live)
    }

    fn over(pool: SharedPool, max_live: usize) -> Self {
        let client = pool.client(1.0);
        ExperimentHub {
            experiments: Vec::new(),
            pool: client,
            fleet: Some(pool),
            max_live,
            rr_cursor: 0,
            occ_sum: 0.0,
            occ_samples: 0,
        }
    }

    /// A hub over a borrowed slice of a shared fleet: one shard of a
    /// sharded control plane. The caller (the fleet owner) is
    /// responsible for outliving this hub — the client's handles send
    /// into the owner's pool, and a dropped pool silently drops late
    /// step requests (same contract as a halted trial).
    pub(crate) fn over_client(pool: SharedPoolClient, max_live: usize) -> Self {
        ExperimentHub {
            experiments: Vec::new(),
            pool,
            fleet: None,
            max_live,
            rr_cursor: 0,
            occ_sum: 0.0,
            occ_samples: 0,
        }
    }

    /// Number of pool worker threads serving all experiments.
    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    /// Admit an experiment; it starts running immediately (its first
    /// admission pass happens inside this call). Returns the hub-level
    /// experiment id. Errors (an unwritable durable directory, a failed
    /// manifest write) reject only this submission — a long-running
    /// server must never die because one user's experiment could not be
    /// set up.
    pub fn submit(&mut self, sub: Submission) -> Result<ExpId, String> {
        // Validate the durable directory before allocating anything.
        let durable = match sub.experiment_dir {
            Some(root) => {
                let dir = ExperimentDir::new(root.clone())
                    .map_err(|e| format!("creating experiment dir {root:?}: {e}"))?;
                // Hub submissions always start fresh (resume goes
                // through `tune run --resume`); clear any stale durable
                // state so a later resume cannot restore an abandoned
                // run.
                dir.reset()
                    .map_err(|e| format!("clearing stale state in {root:?}: {e}"))?;
                Some((root, dir))
            }
            None => None,
        };
        let handle = self.pool.handle(sub.factory);
        let exp = handle.exp_id();
        let scheduler = sub.scheduler.build(sub.spec.seed);
        let search = sub.search.build(sub.space, sub.spec.num_samples);
        let mut runner =
            TrialRunner::new(sub.spec, scheduler, search, Box::new(handle), sub.cluster);
        if let Some(policy) = sub.autoscale {
            runner.set_autoscaler(policy);
        }
        if let Some((root, dir)) = durable {
            let manifest = manifest_json(
                &runner.spec,
                &sub.scheduler,
                &sub.search,
                ExecMode::Pool { workers: self.pool.num_workers() },
                sub.snapshot_every,
            );
            dir.write_manifest(&manifest)
                .map_err(|e| format!("writing manifest in {root:?}: {e}"))?;
            let logger = JsonlLogger::new(root.clone())
                .map_err(|e| format!("creating logger in {root:?}: {e}"))?;
            runner.add_logger(Box::new(logger));
            runner.enable_persistence(dir, sub.snapshot_every);
        }
        self.experiments.push(HubSlot {
            name: runner.spec.name.clone(),
            exp,
            // Clamped on both ends: the share math multiplies weights
            // by the live-trial budget, so an absurd user-supplied
            // weight must not be able to overflow it.
            weight: sub.weight.clamp(1, 1_000_000),
            runner,
            done: false,
            result: None,
        });
        self.recompute_shares();
        let idx = self.experiments.len() - 1;
        self.pump_one(idx);
        Ok(exp)
    }

    /// Experiments still running.
    pub fn active_count(&self) -> usize {
        self.experiments.iter().filter(|s| !s.done).count()
    }

    /// State of one experiment, by the id `submit` returned.
    pub fn state(&self, exp: ExpId) -> Option<ExperimentState> {
        self.index_of(exp).map(|i| {
            if self.experiments[i].done {
                ExperimentState::Finished
            } else {
                ExperimentState::Running
            }
        })
    }

    /// Result of a finished experiment (None while it still runs).
    pub fn result(&self, exp: ExpId) -> Option<&ExperimentResult> {
        self.index_of(exp).and_then(|i| self.experiments[i].result.as_ref())
    }

    /// Mean live-trial occupancy across experiments, sampled at every
    /// processed completion event (the `hub_throughput` bench reports
    /// this as steady-state pool utilization).
    pub fn mean_occupancy(&self) -> f64 {
        if self.occ_samples == 0 {
            0.0
        } else {
            self.occ_sum / self.occ_samples as f64
        }
    }

    fn index_of(&self, exp: ExpId) -> Option<usize> {
        self.experiments.iter().position(|s| s.exp == exp)
    }

    /// Weighted fair share over live-trial slots: every *active*
    /// experiment gets `max_live * weight / total_weight` slots
    /// (integer), the remainder rotates round-robin, and everyone gets
    /// at least one — an experiment whose quota is exhausted can still
    /// relaunch a fault-recovered trial, so recovery can never deadlock
    /// behind admission.
    fn recompute_shares(&mut self) {
        let active: Vec<usize> = (0..self.experiments.len())
            .filter(|i| !self.experiments[*i].done)
            .collect();
        if active.is_empty() {
            return;
        }
        // Resource-weighted shares (the vector generalization of slot
        // quotas): on a capacitated pool every active experiment gets a
        // `weight / total_weight` slice of the fleet's total capacity.
        // The runner enforces "running demands fit inside the slice",
        // with one running trial always allowed — the same ≥1 guarantee
        // the slot floor provides, so fault recovery cannot deadlock.
        let capacity = self.pool.total_capacity();
        let total_w_f: f64 = active.iter().map(|&i| self.experiments[i].weight as f64).sum();
        for &i in &active {
            let share = capacity
                .as_ref()
                .map(|cap| cap.scaled(self.experiments[i].weight as f64 / total_w_f));
            self.experiments[i].runner.set_resource_share(share);
        }
        if self.max_live == 0 {
            for &i in &active {
                self.experiments[i].runner.set_slot_limit(0);
            }
            return;
        }
        // u128 products: weights are clamped to 1e6 but max_live is
        // caller-controlled, so keep the arithmetic overflow-proof.
        let total_w: u128 =
            active.iter().map(|&i| self.experiments[i].weight as u128).sum();
        let mut shares: Vec<usize> = active
            .iter()
            .map(|&i| {
                (self.max_live as u128 * self.experiments[i].weight as u128 / total_w) as usize
            })
            .collect();
        let used: usize = shares.iter().sum();
        let remainder = self.max_live.saturating_sub(used);
        let n = active.len();
        for k in 0..remainder.min(n) {
            shares[(self.rr_cursor + k) % n] += 1;
        }
        self.rr_cursor = self.rr_cursor.wrapping_add(1);
        for (slot_idx, &i) in active.iter().enumerate() {
            self.experiments[i].runner.set_slot_limit(shares[slot_idx].max(1));
        }
    }

    /// Admission-pump one experiment; finalize it when it reports no
    /// further progress. Returns true while it stays active.
    fn pump_one(&mut self, i: usize) -> bool {
        if self.experiments[i].done {
            return false;
        }
        if self.experiments[i].runner.hub_pump() {
            return true;
        }
        let result = self.experiments[i].runner.finalize();
        let slot = &mut self.experiments[i];
        slot.result = Some(result);
        slot.done = true;
        self.recompute_shares();
        false
    }

    /// Admission pass over every active experiment (slots freed by a
    /// completion are re-dealt here).
    fn pump_all(&mut self) {
        for i in 0..self.experiments.len() {
            self.pump_one(i);
        }
    }

    fn sample_occupancy(&mut self) {
        let live: usize = self
            .experiments
            .iter()
            .filter(|s| !s.done)
            .map(|s| s.runner.num_running())
            .sum();
        self.occ_sum += live as f64;
        self.occ_samples += 1;
    }

    /// Drive every experiment for up to `budget` wall time, returning
    /// whether any experiment is still active. `tune serve` calls this
    /// in a loop, interleaving control-plane work (queue ingestion,
    /// status publication) between slices.
    pub fn run_for(&mut self, budget: Duration) -> bool {
        // lint:allow(clock): run_for slices real wall time by contract with the serve loop
        let deadline = Instant::now() + budget;
        self.pump_all();
        loop {
            if self.active_count() == 0 {
                return false;
            }
            // lint:allow(clock): same wall-clock deadline loop as above
            let now = Instant::now();
            if now >= deadline {
                return true;
            }
            match self.pool.poll(deadline - now) {
                PoolPoll::Event(exp, ev) => {
                    let Some(i) = self.index_of(exp) else { continue };
                    if self.experiments[i].done {
                        continue; // stale event for a finalized experiment
                    }
                    self.experiments[i].runner.hub_handle_event(ev);
                    self.sample_occupancy();
                    if !self.pump_one(i) {
                        // Freed slots: re-deal them to the others now.
                        self.pump_all();
                    }
                }
                PoolPoll::Idle => {
                    // Nothing in flight anywhere. Every active
                    // experiment either issues fresh work in this pass
                    // (making the next poll productive), stays alive
                    // waiting out a node restart, or finalizes.
                    self.pump_all();
                    if self.active_count() > 0 {
                        // Survivors may be fault-stalled (no in-flight
                        // work until a dead node restarts): tick gently
                        // instead of burning a core on the idle loop.
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                PoolPoll::Timeout => return true,
            }
        }
    }

    /// Drive every submitted experiment to completion and return
    /// `(name, result)` pairs in submission order.
    pub fn run_all(&mut self) -> Vec<(String, ExperimentResult)> {
        while self.run_for(Duration::from_millis(250)) {}
        self.take_results()
    }

    /// Drain finished experiments out of the hub, in submission order.
    /// Call after [`Self::run_all`] (or once `active_count` is 0).
    pub fn take_results(&mut self) -> Vec<(String, ExperimentResult)> {
        self.experiments
            .drain(..)
            .filter_map(|s| s.result.map(|r| (s.name, r)))
            .collect()
    }

    /// Machine-readable status (what `tune serve` publishes and `tune
    /// status` prints): per experiment, its state, trial counters and
    /// best metric so far.
    pub fn status_json(&self) -> Json {
        let experiments = self
            .experiments
            .iter()
            .map(|s| {
                let util = s.runner.utilization();
                let (trials, running, best) = match &s.result {
                    Some(r) => (r.trials.len(), 0, r.best_metric()),
                    None => {
                        let trials = s.runner.trials();
                        let best = trials
                            .values()
                            .filter_map(|t| t.best_metric)
                            .max_by(|a, b| {
                                crate::util::order::asc(
                                    s.runner.spec.mode.ascending(*a),
                                    s.runner.spec.mode.ascending(*b),
                                )
                            });
                        (trials.len(), s.runner.num_running(), best)
                    }
                };
                Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    (
                        "state",
                        Json::Str(if s.done { "finished" } else { "running" }.into()),
                    ),
                    ("weight", Json::Num(s.weight as f64)),
                    ("trials", Json::Num(trials as f64)),
                    ("running", Json::Num(running as f64)),
                    ("metric", Json::Str(s.runner.spec.metric.clone())),
                    (
                        "mode",
                        Json::Str(
                            if s.runner.spec.mode == Mode::Max { "max" } else { "min" }.into(),
                        ),
                    ),
                    ("best_metric", best.map(Json::Num).unwrap_or(Json::Null)),
                    // Cluster utilization (SchedulerCtx exposes the same
                    // snapshot to schedulers; `tune status` prints it).
                    ("util_cpu", Json::Num(util.cpu_frac())),
                    ("util_gpu", Json::Num(util.gpu_frac())),
                    ("nodes_alive", Json::Num(util.nodes_alive as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("workers", Json::Num(self.pool.num_workers() as f64)),
            ("max_live", Json::Num(self.max_live as f64)),
            ("active", Json::Num(self.active_count() as f64)),
            ("experiments", Json::Arr(experiments)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;
    use crate::trainable::factory;
    use crate::trainable::synthetic::CurveTrainable;

    fn curve_submission(name: &str, seed: u64, samples: usize, iters: u64) -> Submission {
        let mut spec = ExperimentSpec::named(name);
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.num_samples = samples;
        spec.max_iterations_per_trial = iters;
        spec.seed = seed;
        let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
        Submission::new(
            spec,
            space,
            SchedulerKind::Fifo,
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        )
    }

    #[test]
    fn hub_runs_one_experiment_to_completion() {
        let mut hub = ExperimentHub::new(2, 0);
        let id = hub.submit(curve_submission("solo", 7, 5, 8)).unwrap();
        let results = hub.run_all();
        assert_eq!(results.len(), 1);
        let (name, res) = &results[0];
        assert_eq!(name, "solo");
        assert_eq!(res.trials.len(), 5);
        assert_eq!(res.count(crate::coordinator::trial::TrialStatus::Completed), 5);
        assert!(res.best.is_some());
        let _ = id;
    }

    #[test]
    fn hub_runs_many_experiments_concurrently() {
        let mut hub = ExperimentHub::new(4, 8);
        for i in 0..3u64 {
            hub.submit(curve_submission(&format!("e{i}"), i, 4, 6)).unwrap();
        }
        assert_eq!(hub.active_count(), 3);
        let results = hub.run_all();
        assert_eq!(results.len(), 3);
        for (_, r) in &results {
            assert_eq!(r.trials.len(), 4);
            assert!(r.best_metric().is_some());
        }
    }

    #[test]
    fn fair_share_guarantees_a_slot_each() {
        // 3 experiments, global budget of 2 slots: the max(1, ..) floor
        // must still hand every active experiment one slot.
        let mut hub = ExperimentHub::new(2, 2);
        for i in 0..3u64 {
            hub.submit(curve_submission(&format!("tiny{i}"), i, 2, 4)).unwrap();
        }
        let results = hub.run_all();
        assert_eq!(results.len(), 3);
        for (_, r) in &results {
            assert_eq!(r.trials.len(), 2);
        }
    }

    #[test]
    fn capacitated_hub_deals_resource_weighted_shares() {
        // Fleet: 2 workers x 2 cpus = 4 cpus total. Weights 3:1 give
        // the experiments cpu shares of 3.0 and 1.0; the lighter one
        // still always gets its guaranteed single running trial. Both
        // must complete despite the 1-cpu-per-trial demands contending
        // for the fleet.
        let mut hub = ExperimentHub::with_capacities(
            vec![Resources::cpu(2.0), Resources::cpu(2.0)],
            8,
        );
        let mut heavy = curve_submission("heavy", 1, 4, 5);
        heavy.weight = 3;
        hub.submit(heavy).unwrap();
        let mut light = curve_submission("light", 2, 4, 5);
        light.weight = 1;
        hub.submit(light).unwrap();
        let results = hub.run_all();
        assert_eq!(results.len(), 2);
        for (name, r) in &results {
            assert_eq!(r.trials.len(), 4, "{name}");
            assert_eq!(
                r.count(crate::coordinator::trial::TrialStatus::Completed),
                4,
                "{name}"
            );
        }
    }

    #[test]
    fn state_and_result_accessors_track_lifecycle() {
        let mut hub = ExperimentHub::new(2, 0);
        let id = hub.submit(curve_submission("acc", 1, 2, 3)).unwrap();
        // Freshly submitted: running (tiny experiments may already have
        // live trials but cannot have finalized — events need polling).
        assert_eq!(hub.state(id), Some(ExperimentState::Running));
        assert!(hub.result(id).is_none());
        while hub.run_for(Duration::from_millis(100)) {}
        assert_eq!(hub.state(id), Some(ExperimentState::Finished));
        assert!(hub.result(id).is_some());
        let status = hub.status_json();
        assert_eq!(status.get("active").unwrap().as_u64(), Some(0));
        let exps = status.get("experiments").unwrap().as_arr().unwrap();
        assert_eq!(exps.len(), 1);
        assert_eq!(exps[0].get("state").unwrap().as_str(), Some("finished"));
    }
}
