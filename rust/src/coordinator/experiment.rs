//! Experiment specification and the `run_experiments` facade (§4.3):
//! "the user must specify their model training function or class, an
//! initial set of trials, and a trial scheduler."

use crate::logger::{JsonlLogger, ProgressReporter};
use crate::ray::{AutoscalePolicy, Cluster, FaultPlan, Resources};
use crate::trainable::TrainableFactory;
use crate::util::json::Json;

use super::executor::{Executor, PoolExecutor, SimExecutor, ThreadExecutor};
use super::persist::{u64_from_json, u64_to_json, ExperimentDir, FORMAT_VERSION};
use super::runner::{ExperimentResult, TrialRunner};
use super::schedulers::{
    AshaScheduler, FifoScheduler, HyperBandScheduler, MedianStoppingRule, PbtScheduler,
    TrialScheduler,
};
use super::search::{EvolutionSearch, GridSearch, RandomSearch, SearchAlgorithm, TpeSearch};
use super::spec::SearchSpace;
use super::trial::Mode;

/// Everything that defines an experiment run.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// Experiment name (log directories, progress output).
    pub name: String,
    /// Metric trials report and schedulers optimize.
    pub metric: String,
    /// Whether larger or smaller metric values are better.
    pub mode: Mode,
    /// Number of stochastic samples (grid dims multiply inside the
    /// search algorithm).
    pub num_samples: usize,
    /// Resource demand each trial leases from the cluster.
    pub resources_per_trial: Resources,
    /// Per-trial stopping: max training iterations.
    pub max_iterations_per_trial: u64,
    /// Per-trial stopping: terminate once the metric is at least (Max) /
    /// at most (Min) this value.
    pub metric_target: Option<f64>,
    /// Experiment-wide (virtual or wall) seconds budget.
    pub max_experiment_time_s: f64,
    /// 0 = bounded by cluster resources only.
    pub max_concurrent: usize,
    /// Failures tolerated per trial before it is marked Errored.
    pub max_failures: u32,
    /// Checkpoint every N iterations (0 = only when schedulers ask).
    pub checkpoint_freq: u64,
    /// Snapshot final state of completed trials.
    pub checkpoint_at_end: bool,
    /// Deterministic fault injection plan (none by default).
    pub fault_plan: FaultPlan,
    /// Root seed: search sampling, trial seeds and fault injection all
    /// derive from it, so runs replay bit-identically.
    pub seed: u64,
    /// Hardware-aware scheduling: learn per-(workload, shape)
    /// throughput profiles online and, once warm, rank placements by
    /// predicted steps/sec over opportunity cost (and autoscale
    /// templates by throughput per dollar). Off by default — with the
    /// flag off the run is byte-identical to the pre-hardware-aware
    /// runner.
    pub hw_aware: bool,
    /// Hard virtual-dollar cap: the run stops (or, if already spent,
    /// refuses to launch) once accrued node-hours x price reach this.
    /// `None` = uncapped. Meaningful only when nodes carry a nonzero
    /// `price_per_hour`.
    pub budget_max_cost: Option<f64>,
}

impl ExperimentSpec {
    /// A spec with workable defaults for `name` (metric "loss", Min
    /// mode, one sample, 1 CPU per trial, 100 iterations).
    pub fn named(name: &str) -> Self {
        ExperimentSpec {
            name: name.to_string(),
            metric: "loss".into(),
            mode: Mode::Min,
            num_samples: 1,
            resources_per_trial: Resources::cpu(1.0),
            max_iterations_per_trial: 100,
            metric_target: None,
            max_experiment_time_s: f64::INFINITY,
            max_concurrent: 0,
            max_failures: 3,
            checkpoint_freq: 0,
            checkpoint_at_end: false,
            fault_plan: FaultPlan::none(),
            seed: 0,
            hw_aware: false,
            budget_max_cost: None,
        }
    }
}

/// Scheduler selection (string-friendly for the CLI).
#[derive(Clone, Debug)]
#[allow(missing_docs)] // parameter fields are documented on the schedulers themselves
pub enum SchedulerKind {
    /// Run every trial to its stopping criterion (the trivial baseline).
    Fifo,
    /// Asynchronous HyperBand (Li et al. 2018).
    Asha { grace_period: u64, reduction_factor: f64, max_t: u64 },
    /// Synchronous HyperBand with rung barriers (Li et al. 2016).
    HyperBand { max_t: u64, eta: f64 },
    /// Median stopping rule (Golovin et al. 2017).
    MedianStopping { grace_period: u64, min_samples: usize },
    /// Population-Based Training (Jaderberg et al. 2017).
    Pbt { perturbation_interval: u64, space: SearchSpace },
}

impl SchedulerKind {
    /// Instantiate the concrete scheduler (PBT derives its RNG from
    /// `seed`).
    pub fn build(&self, seed: u64) -> Box<dyn TrialScheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(FifoScheduler::new()),
            SchedulerKind::Asha { grace_period, reduction_factor, max_t } => {
                Box::new(AshaScheduler::new(*grace_period, *reduction_factor, *max_t))
            }
            SchedulerKind::HyperBand { max_t, eta } => {
                Box::new(HyperBandScheduler::new(*max_t, *eta))
            }
            SchedulerKind::MedianStopping { grace_period, min_samples } => {
                Box::new(MedianStoppingRule::new(*grace_period, *min_samples))
            }
            SchedulerKind::Pbt { perturbation_interval, space } => {
                Box::new(PbtScheduler::new(*perturbation_interval, space.clone(), seed ^ 0x9B7))
            }
        }
    }

    /// Stable CLI/log label for the scheduler.
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "fifo",
            SchedulerKind::Asha { .. } => "asha",
            SchedulerKind::HyperBand { .. } => "hyperband",
            SchedulerKind::MedianStopping { .. } => "median_stopping",
            SchedulerKind::Pbt { .. } => "pbt",
        }
    }
}

/// Search-algorithm selection.
#[derive(Clone, Debug)]
pub enum SearchKind {
    /// Full cross-product over `grid_search` dimensions.
    Grid,
    /// I.i.d. sampling from the space (Bergstra & Bengio 2012).
    Random,
    /// Tree-structured Parzen Estimator (HyperOpt's algorithm).
    Tpe,
    /// (mu + lambda) evolutionary search.
    Evolution,
}

impl SearchKind {
    /// Instantiate the concrete search algorithm over `space`.
    pub fn build(&self, space: SearchSpace, num_samples: usize) -> Box<dyn SearchAlgorithm> {
        match self {
            SearchKind::Grid => Box::new(GridSearch::new(space, num_samples)),
            SearchKind::Random => Box::new(RandomSearch::new(space, num_samples)),
            SearchKind::Tpe => Box::new(TpeSearch::new(space, num_samples)),
            SearchKind::Evolution => Box::new(EvolutionSearch::new(space, num_samples)),
        }
    }

    /// Stable CLI/log label for the search algorithm.
    pub fn label(&self) -> &'static str {
        match self {
            SearchKind::Grid => "grid",
            SearchKind::Random => "random",
            SearchKind::Tpe => "tpe",
            SearchKind::Evolution => "evolution",
        }
    }
}

/// Execution substrate selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Discrete-event simulation over `Trainable::step_cost` virtual
    /// seconds — scheduler research mode.
    Sim,
    /// One real thread per live trial, wall-clock time — mirrors Ray's
    /// process-per-trial model (PJRT models run here).
    Threads,
    /// Bounded worker pool: `workers` threads service every live trial
    /// through a shared injector queue — production mode; concurrency is
    /// decoupled from trial count.
    Pool {
        /// Number of pool worker threads (min 1).
        workers: usize,
    },
}

impl ExecMode {
    /// Stable CLI/log label for the mode.
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::Sim => "sim",
            ExecMode::Threads => "threads",
            ExecMode::Pool { .. } => "pool",
        }
    }
}

/// Options bag for [`run_experiments`].
pub struct RunOptions {
    /// The (simulated) cluster trials are placed onto.
    pub cluster: Cluster,
    /// Which executor runs the trainables.
    pub exec: ExecMode,
    /// Print progress every N results (0 = quiet).
    pub progress_every: u64,
    /// Write JSONL logs under this directory (without durability; see
    /// `experiment_dir` for the crash-safe variant).
    pub log_dir: Option<std::path::PathBuf>,
    /// Durable experiment directory: JSONL logs, spilled checkpoints,
    /// a spec/options manifest and periodic atomic runner snapshots all
    /// live here, making the experiment resumable after a crash.
    pub experiment_dir: Option<std::path::PathBuf>,
    /// Snapshot the runner state every N processed results when
    /// `experiment_dir` is set (0 = only the final snapshot).
    pub snapshot_every: u64,
    /// Resume from `experiment_dir` instead of starting over: rebuild
    /// the trial table, scheduler, search and checkpoint state from the
    /// latest snapshot and continue to the same deterministic outcome an
    /// uninterrupted run would have reached. Starts fresh (with a note)
    /// when the directory holds no snapshot yet.
    pub resume: bool,
    /// Elastic autoscaling policy for the cluster (None = fixed size):
    /// scale up on sustained unplaceable queue pressure, drain and
    /// retire idle/low-utilization nodes with checkpoint-then-requeue
    /// preemption.
    pub autoscale: Option<AutoscalePolicy>,
    /// Per-worker capacity vectors for `ExecMode::Pool` (None =
    /// capacity-oblivious workers, the historical behavior): admission
    /// of live trainables becomes a first-fit vector fit of
    /// `resources_per_trial` against these, so e.g. only GPU-bearing
    /// workers ever hold GPU trials. Overrides the pool's worker count
    /// with `worker_caps.len()`.
    pub worker_caps: Option<Vec<Resources>>,
    /// Cap on the checkpoint store's memory-resident bytes (assembled
    /// blobs + chunk payloads); cold chunks evict to the experiment
    /// directory's `checkpoints/chunks/` tier and fault back in on
    /// demand. `None` = unbounded. Effective with `experiment_dir` set
    /// (the disk tier is where evicted chunks go).
    pub checkpoint_mem_budget: Option<usize>,
    /// Planted shape-dependent step-time multipliers for
    /// `ExecMode::Sim` (ignored by other executors): the deterministic
    /// stand-in for heterogeneous hardware that hardware-aware
    /// scheduling tests and benches run against.
    pub shape_factors: Option<crate::ray::ShapeFactors>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(8.0)),
            exec: ExecMode::Sim,
            progress_every: 0,
            log_dir: None,
            experiment_dir: None,
            snapshot_every: 50,
            resume: false,
            autoscale: None,
            worker_caps: None,
            checkpoint_mem_budget: None,
            shape_factors: None,
        }
    }
}

/// The spec + options manifest written into an experiment directory, so
/// `--resume` can sanity-check that it is continuing the same run.
/// Shared with the hub, which writes one per multiplexed experiment.
pub(crate) fn manifest_json(
    spec: &ExperimentSpec,
    scheduler: &SchedulerKind,
    search: &SearchKind,
    exec: ExecMode,
    snapshot_every: u64,
) -> Json {
    Json::obj(vec![
        ("version", Json::Num(FORMAT_VERSION as f64)),
        ("name", Json::Str(spec.name.clone())),
        ("metric", Json::Str(spec.metric.clone())),
        (
            "mode",
            Json::Str(if spec.mode == Mode::Max { "max" } else { "min" }.into()),
        ),
        ("num_samples", Json::Num(spec.num_samples as f64)),
        ("max_iterations_per_trial", Json::Num(spec.max_iterations_per_trial as f64)),
        // Informational (not part of resume validation): lets `analyze`
        // report what each trial demanded.
        ("resources_per_trial", spec.resources_per_trial.to_json()),
        ("seed", u64_to_json(spec.seed)),
        ("scheduler", Json::Str(scheduler.label().into())),
        ("search", Json::Str(search.label().into())),
        ("exec", Json::Str(exec.label().into())),
        ("snapshot_every", Json::Num(snapshot_every as f64)),
    ])
}

/// Assemble the runner [`run_experiments`] drives — exposed so tests and
/// tools can hold the runner itself (e.g. crash-injection via
/// [`TrialRunner::run_to_crash`]). Honors `opts.experiment_dir` /
/// `opts.resume` exactly like [`run_experiments`].
pub fn build_runner(
    spec: ExperimentSpec,
    space: SearchSpace,
    scheduler: SchedulerKind,
    search: SearchKind,
    factory: TrainableFactory,
    opts: RunOptions,
) -> TrialRunner {
    let RunOptions {
        cluster,
        exec,
        progress_every,
        log_dir,
        experiment_dir,
        snapshot_every,
        resume,
        autoscale,
        worker_caps,
        checkpoint_mem_budget,
        shape_factors,
    } = opts;
    let executor: Box<dyn Executor> = match (exec, worker_caps) {
        (ExecMode::Sim, _) => {
            let mut sim = SimExecutor::new(factory);
            if let Some(f) = shape_factors {
                sim = sim.with_shape_factors(f);
            }
            Box::new(sim)
        }
        (ExecMode::Threads, _) => Box::new(ThreadExecutor::new(factory)),
        (ExecMode::Pool { .. }, Some(caps)) => {
            Box::new(PoolExecutor::with_capacities(factory, caps))
        }
        (ExecMode::Pool { workers }, None) => Box::new(PoolExecutor::new(factory, workers)),
    };
    let sched = scheduler.build(spec.seed);
    let search_alg = search.build(space, spec.num_samples);
    let mut runner = TrialRunner::new(spec, sched, search_alg, executor, cluster);
    if let Some(policy) = autoscale {
        runner.set_autoscaler(policy);
    }

    if let Some(root) = experiment_dir {
        let dir = ExperimentDir::new(root.clone()).expect("create experiment dir");
        let mut resumed = false;
        if resume {
            if dir.has_snapshot() {
                validate_manifest(&dir, &runner.spec, &scheduler, &search);
                runner
                    .restore_from_dir(&dir)
                    .unwrap_or_else(|e| panic!("resume from {root:?} failed: {e}"));
                resumed = true;
            } else {
                eprintln!("note: --resume but {root:?} has no snapshot yet; starting fresh");
            }
        }
        if !resumed {
            // A fresh run into a reused directory must not leave a prior
            // run's snapshot/logs/checkpoints behind: a later --resume
            // would silently restore the abandoned run's state.
            dir.reset().expect("clear stale experiment state");
            let manifest =
                manifest_json(&runner.spec, &scheduler, &search, exec, snapshot_every);
            dir.write_manifest(&manifest).expect("write experiment manifest");
        }
        let logger =
            if resumed { JsonlLogger::resume(root) } else { JsonlLogger::new(root) };
        runner.add_logger(Box::new(logger.expect("create experiment dir logger")));
        runner.enable_persistence(dir, snapshot_every);
    } else if let Some(dir) = log_dir {
        runner.add_logger(Box::new(JsonlLogger::new(dir).expect("create log dir")));
    }
    if progress_every > 0 {
        let metric = runner.spec.metric.clone();
        runner.add_logger(Box::new(ProgressReporter::new(&metric, progress_every)));
    }
    // After enable_persistence, so eviction has its disk tier.
    if checkpoint_mem_budget.is_some() {
        runner.set_checkpoint_mem_budget(checkpoint_mem_budget);
    }
    runner
}

/// Refuse to resume a directory that was written by a different
/// experiment — a mismatched name/seed/objective/algorithm/shape would
/// silently corrupt it (e.g. restored ASHA rungs sized for a different
/// max_t, or a restored best-so-far reinterpreted under the opposite
/// mode).
fn validate_manifest(
    dir: &ExperimentDir,
    spec: &ExperimentSpec,
    scheduler: &SchedulerKind,
    search: &SearchKind,
) {
    let Some(m) = dir.read_manifest() else {
        return; // manifest lost but snapshot present: trust the snapshot
    };
    let s = |k: &str| m.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
    let got = (
        s("name"),
        m.get("seed").and_then(u64_from_json).unwrap_or(0),
        s("metric"),
        s("mode"),
        s("scheduler"),
        s("search"),
        m.get("num_samples").and_then(|v| v.as_u64()).unwrap_or(0) as usize,
        m.get("max_iterations_per_trial").and_then(|v| v.as_u64()).unwrap_or(0),
    );
    let want = (
        spec.name.clone(),
        spec.seed,
        spec.metric.clone(),
        (if spec.mode == Mode::Max { "max" } else { "min" }).to_string(),
        scheduler.label().to_string(),
        search.label().to_string(),
        spec.num_samples,
        spec.max_iterations_per_trial,
    );
    assert!(
        got == want,
        "resume mismatch: directory manifest (name, seed, metric, mode, scheduler, search, \
         samples, iters) = {got:?} but the caller asked for {want:?}",
    );
}

/// §4.3's entry point: run an experiment end to end.
pub fn run_experiments(
    spec: ExperimentSpec,
    space: SearchSpace,
    scheduler: SchedulerKind,
    search: SearchKind,
    factory: TrainableFactory,
    opts: RunOptions,
) -> ExperimentResult {
    build_runner(spec, space, scheduler, search, factory, opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;
    use crate::trainable::factory;
    use crate::trainable::synthetic::CurveTrainable;

    #[test]
    fn facade_runs_grid_experiment() {
        let mut spec = ExperimentSpec::named("quickstart");
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.max_iterations_per_trial = 10;
        let space = SpaceBuilder::new()
            .grid_f64("lr", &[0.01, 0.001, 0.0001])
            .grid_str("activation", &["relu", "tanh"])
            .build();
        let res = run_experiments(
            spec,
            space,
            SchedulerKind::Fifo,
            SearchKind::Grid,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            RunOptions::default(),
        );
        assert_eq!(res.trials.len(), 6); // 3 x 2 grid, §4.3
        assert!(res.best_metric().unwrap() > 0.0);
    }

    #[test]
    fn scheduler_kinds_build() {
        let space = SpaceBuilder::new().uniform("lr", 0.0, 1.0).build();
        for k in [
            SchedulerKind::Fifo,
            SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 81 },
            SchedulerKind::HyperBand { max_t: 81, eta: 3.0 },
            SchedulerKind::MedianStopping { grace_period: 5, min_samples: 3 },
            SchedulerKind::Pbt { perturbation_interval: 5, space: space.clone() },
        ] {
            let s = k.build(0);
            assert_eq!(s.name(), k.label());
        }
    }
}
