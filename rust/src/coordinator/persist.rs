//! Durable experiments: the versioned on-disk experiment directory and
//! the JSON (de)serialization helpers the snapshot/restore machinery
//! shares.
//!
//! The paper (§4.2) keeps trial metadata in memory and "relies on
//! checkpoints for fault tolerance" — which recovers *trials*, but a
//! coordinator crash still loses the *experiment*. This module makes
//! experiment state durable end to end. Layout of an experiment
//! directory:
//!
//! ```text
//! <dir>/
//!   experiment.meta.json   # manifest: version, spec + run options
//!   snapshot.json          # atomic BASE snapshot of runner state
//!   snapshot.delta.jsonl   # fsync'd incremental records since the base
//!   trial_0000.jsonl ...   # per-trial result logs (JsonlLogger)
//!   experiment.json        # final summary (written at experiment end)
//!   checkpoints/           # spilled trainable checkpoints (*.bin)
//! ```
//!
//! Base snapshots are written atomically (`snapshot.json.tmp` +
//! rename), so a crash mid-write leaves the previous snapshot intact.
//! Between bases the runner appends compact **delta** records — dirty
//! trials, appended scheduler state, checkpoint-manifest changes — to
//! `snapshot.delta.jsonl`, each line fsync'd, so the periodic
//! persistence cost is proportional to what changed since the last
//! snapshot, not to total experiment size. `resume` (see
//! [`crate::coordinator::run_experiments`]) restores the base and folds
//! the deltas back in order; each base carries a monotone `delta_epoch`
//! that deltas must match, so a crash between writing a new base and
//! clearing the delta file can never fold stale records onto it. A
//! directory holding only a full `snapshot.json` (the pre-delta format)
//! restores exactly as before.
//!
//! # Example: durable run + resume
//!
//! ```
//! use tune::coordinator::spec::SpaceBuilder;
//! use tune::coordinator::{run_experiments, ExperimentSpec, Mode, RunOptions,
//!                         SchedulerKind, SearchKind};
//! use tune::trainable::{factory, synthetic::CurveTrainable};
//!
//! let dir = std::env::temp_dir().join(format!("tune_doc_resume_{}", std::process::id()));
//! std::fs::remove_dir_all(&dir).ok();
//! let mut spec = ExperimentSpec::named("doc-resume");
//! spec.metric = "accuracy".into();
//! spec.mode = Mode::Max;
//! spec.num_samples = 4;
//! spec.max_iterations_per_trial = 9;
//! let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
//! let run = |resume: bool| {
//!     run_experiments(
//!         spec.clone(), space.clone(),
//!         SchedulerKind::Fifo, SearchKind::Random,
//!         factory(|c, s| Box::new(CurveTrainable::new(c, s))),
//!         RunOptions {
//!             experiment_dir: Some(dir.clone()),
//!             snapshot_every: 10,
//!             resume,
//!             ..Default::default()
//!         },
//!     )
//! };
//! let first = run(false);           // durable run: logs + snapshots on disk
//! let resumed = run(true);          // finished experiment: resume is a no-op
//! assert_eq!(resumed.best, first.best);
//! assert_eq!(resumed.best_metric(), first.best_metric());
//! std::fs::remove_dir_all(&dir).ok();
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::coordinator::trial::{Config, ParamValue};
use crate::util::json::{parse, Json};

/// Version stamp written into manifests and snapshots; bumped whenever
/// the on-disk format changes incompatibly.
pub const FORMAT_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// JSON helpers shared by the snapshot/restore implementations
// ---------------------------------------------------------------------------

// Lossless u64 encoding lives in util::json (the fault injector in
// `ray` uses it too); re-exported here next to its sibling helpers.
pub use crate::util::json::{u64_from_json, u64_to_json};

/// Encode a [`ParamValue`] with enough tagging to round-trip the
/// variant: floats/strings/bools map directly; integers are wrapped as
/// `{"$i": n}` so they do not come back as `F64`.
pub fn param_to_json(v: &ParamValue) -> Json {
    match v {
        ParamValue::F64(f) => Json::Num(*f),
        ParamValue::I64(i) => Json::obj(vec![("$i", Json::Num(*i as f64))]),
        ParamValue::Str(s) => Json::Str(s.clone()),
        ParamValue::Bool(b) => Json::Bool(*b),
    }
}

/// Decode a [`ParamValue`] written by [`param_to_json`].
pub fn param_from_json(j: &Json) -> Option<ParamValue> {
    Some(match j {
        Json::Num(n) => ParamValue::F64(*n),
        Json::Str(s) => ParamValue::Str(s.clone()),
        Json::Bool(b) => ParamValue::Bool(*b),
        Json::Obj(o) => ParamValue::I64(o.get("$i")?.as_f64()? as i64),
        _ => return None,
    })
}

/// Encode a full config (ordered map of tagged params).
pub fn config_to_json(c: &Config) -> Json {
    Json::Obj(c.iter().map(|(k, v)| (k.clone(), param_to_json(v))).collect())
}

/// Decode a config written by [`config_to_json`].
pub fn config_from_json(j: &Json) -> Option<Config> {
    let mut out = Config::new();
    for (k, v) in j.as_obj()? {
        out.insert(k.clone(), param_from_json(v)?);
    }
    Some(out)
}

/// Encode one `f64` losslessly, including the non-finite values JSON
/// cannot represent as numbers: `NaN`/`±inf` are written as tagged
/// strings. Scheduler state (ASHA rungs, median-rule running means) can
/// legitimately hold `NaN` once a trial diverges — the comparator ranks
/// it worst instead of panicking — and a snapshot/resume cycle must
/// preserve exactly that state.
pub fn num_to_json(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x.is_nan() {
        Json::Str("NaN".into())
    } else if x > 0.0 {
        Json::Str("Infinity".into())
    } else {
        Json::Str("-Infinity".into())
    }
}

/// Decode an `f64` written by [`num_to_json`].
pub fn num_from_json(j: &Json) -> Option<f64> {
    match j {
        Json::Num(n) => Some(*n),
        Json::Str(s) => match s.as_str() {
            "NaN" => Some(f64::NAN),
            "Infinity" => Some(f64::INFINITY),
            "-Infinity" => Some(f64::NEG_INFINITY),
            _ => None,
        },
        _ => None,
    }
}

/// Encode a `Vec<f64>` (non-finite values survive, see [`num_to_json`]).
pub fn f64s_to_json(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| num_to_json(*x)).collect())
}

/// Decode a `Vec<f64>` written by [`f64s_to_json`].
pub fn f64s_from_json(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(num_from_json).collect()
}

/// Encode a map keyed by trial id (decimal-string keys).
pub fn id_map_to_json<V>(m: &BTreeMap<u64, V>, f: impl Fn(&V) -> Json) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (k.to_string(), f(v))).collect())
}

/// Decode a map written by [`id_map_to_json`].
pub fn id_map_from_json<V>(j: &Json, f: impl Fn(&Json) -> Option<V>) -> Option<BTreeMap<u64, V>> {
    let mut out = BTreeMap::new();
    for (k, v) in j.as_obj()? {
        out.insert(k.parse().ok()?, f(v)?);
    }
    Some(out)
}

// ---------------------------------------------------------------------------
// The experiment directory
// ---------------------------------------------------------------------------

/// Handle to a durable experiment directory (layout in the module docs).
#[derive(Clone, Debug)]
pub struct ExperimentDir {
    root: PathBuf,
}

impl ExperimentDir {
    /// Open (creating directories as needed) an experiment directory.
    pub fn new(root: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(root.join("checkpoints"))?;
        Ok(ExperimentDir { root })
    }

    /// Read-only handle to an existing directory: no directories are
    /// created and nothing is written — the right constructor for
    /// inspection paths like `tune analyze` (which may run against a
    /// read-only mount).
    pub fn open(root: PathBuf) -> Self {
        ExperimentDir { root }
    }

    /// Remove all durable state from a previous run — the stale
    /// snapshot, trial logs, summary and spilled checkpoints — so a
    /// fresh (non-resume) run reusing the directory can never be
    /// accidentally "resumed" into the abandoned run's state later.
    /// The manifest is left for the caller to overwrite.
    pub fn reset(&self) -> std::io::Result<()> {
        let snapshot = self.snapshot_path();
        if snapshot.exists() {
            std::fs::remove_file(&snapshot)?;
        }
        self.clear_deltas()?;
        let summary = self.root.join("experiment.json");
        if summary.exists() {
            std::fs::remove_file(&summary)?;
        }
        for entry in std::fs::read_dir(&self.root)?.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.starts_with("trial_") && name.ends_with(".jsonl") {
                std::fs::remove_file(entry.path())?;
            }
        }
        for entry in std::fs::read_dir(self.checkpoints_dir())?.flatten() {
            let path = entry.path();
            if path.is_dir() {
                // The content-addressed store keeps its chunk tier in a
                // `chunks/` subdirectory.
                std::fs::remove_dir_all(&path)?;
            } else {
                std::fs::remove_file(&path)?;
            }
        }
        Ok(())
    }

    /// The directory root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Where spilled trainable checkpoints live.
    pub fn checkpoints_dir(&self) -> PathBuf {
        self.root.join("checkpoints")
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("experiment.meta.json")
    }

    fn snapshot_path(&self) -> PathBuf {
        self.root.join("snapshot.json")
    }

    fn delta_path(&self) -> PathBuf {
        self.root.join("snapshot.delta.jsonl")
    }

    /// Does the directory hold a runner snapshot to resume from?
    pub fn has_snapshot(&self) -> bool {
        self.snapshot_path().exists()
    }

    /// Write the run manifest (spec + run options), overwriting.
    pub fn write_manifest(&self, manifest: &Json) -> std::io::Result<()> {
        write_atomic(&self.manifest_path(), &manifest.to_string())
    }

    /// Read the run manifest back, if present and parseable.
    pub fn read_manifest(&self) -> Option<Json> {
        let text = std::fs::read_to_string(self.manifest_path()).ok()?;
        parse(&text).ok()
    }

    /// Atomically replace the runner snapshot (tmp file + rename, so a
    /// crash mid-write never corrupts the previous snapshot).
    pub fn write_snapshot(&self, snapshot: &Json) -> std::io::Result<()> {
        write_atomic(&self.snapshot_path(), &snapshot.to_string())
    }

    /// Read the runner snapshot back, if present and parseable.
    pub fn read_snapshot(&self) -> Option<Json> {
        let text = std::fs::read_to_string(self.snapshot_path()).ok()?;
        parse(&text).ok()
    }

    /// Append one delta record to `snapshot.delta.jsonl`, fsync'd: a
    /// delta acknowledged here survives power loss, matching the base
    /// snapshot's durability contract at a cost proportional to the
    /// record, not the experiment.
    pub fn append_delta(&self, delta: &Json) -> std::io::Result<()> {
        use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
        let path = self.delta_path();
        let created = !path.exists();
        // Torn-tail guard: a crash mid-append can leave a final line
        // with no trailing newline. Appending directly would merge the
        // next (acknowledged!) record into that garbage; start a fresh
        // line instead, so the torn fragment stays an isolated
        // unparseable line that `read_deltas` skips.
        let needs_newline = if created {
            false
        } else {
            let mut f = std::fs::File::open(&path)?;
            let len = f.metadata()?.len();
            if len == 0 {
                false
            } else {
                f.seek(SeekFrom::End(-1))?;
                let mut last = [0u8; 1];
                f.read_exact(&mut last)?;
                last[0] != b'\n'
            }
        };
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
        let mut line = delta.to_string();
        line.push('\n');
        if needs_newline {
            line.insert(0, '\n');
        }
        f.write_all(line.as_bytes())?;
        f.sync_all()?;
        if created {
            // First append since the file was (re)created: fsync the
            // parent so the directory entry itself survives power loss
            // — same reasoning (and same best-effort caveat) as
            // `write_atomic`'s rename durability.
            if let Some(parent) = path.parent() {
                if let Ok(d) = std::fs::File::open(parent) {
                    d.sync_all().ok();
                }
            }
        }
        Ok(())
    }

    /// Remove the delta file (called right after a new base snapshot is
    /// written — the base subsumes every delta).
    pub fn clear_deltas(&self) -> std::io::Result<()> {
        match std::fs::remove_file(self.delta_path()) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e),
        }
    }

    /// Read the delta records in append order, skipping unparseable
    /// lines. A bad line is always a record whose append was never
    /// acknowledged (a crash tore the write before its fsync returned)
    /// — every acknowledged record is a complete, newline-terminated
    /// JSON line, and [`ExperimentDir::append_delta`]'s torn-tail guard
    /// keeps post-resume appends from merging into a torn fragment — so
    /// dropping it never loses durable state.
    pub fn read_deltas(&self) -> Vec<Json> {
        let Ok(text) = std::fs::read_to_string(self.delta_path()) else {
            return Vec::new();
        };
        text.lines().filter_map(|line| parse(line).ok()).collect()
    }

    /// Path of one trial's JSONL result log.
    pub fn trial_log_path(&self, trial: u64) -> PathBuf {
        self.root.join(format!("trial_{trial:04}.jsonl"))
    }

    /// Ids of every `trial_*.jsonl` log currently in the directory.
    pub fn trial_log_ids(&self) -> Vec<u64> {
        let Ok(entries) = std::fs::read_dir(&self.root) else {
            return Vec::new();
        };
        let mut ids: Vec<u64> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name();
                let name = name.to_str()?;
                name.strip_prefix("trial_")?.strip_suffix(".jsonl")?.parse().ok()
            })
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Truncate a trial's JSONL log to its header plus result rows with
    /// `iteration <= max_iter`, dropping end lines and anything
    /// unparseable (e.g. a half-written final line from a crash). Called
    /// on resume for every non-terminal trial so the log and the
    /// restored runner state agree, and replayed iterations are not
    /// logged twice.
    pub fn prune_trial_log(&self, trial: u64, max_iter: u64) -> std::io::Result<()> {
        let path = self.trial_log_path(trial);
        let Ok(text) = std::fs::read_to_string(&path) else {
            return Ok(()); // no log yet: nothing to prune
        };
        let mut kept = String::new();
        for line in text.lines() {
            let Ok(v) = parse(line) else { continue };
            let keep = if v.get("config").is_some() {
                true // header
            } else if v.get("end").is_some() {
                false // a resumed trial is not over; drop stale end lines
            } else {
                v.get("iteration").and_then(|i| i.as_u64()).map_or(false, |i| i <= max_iter)
            };
            if keep {
                kept.push_str(line);
                kept.push('\n');
            }
        }
        write_atomic(&path, &kept)
    }
}

/// Write `text` to `path` atomically *and durably*: write a sibling
/// `.tmp` file, fsync it, rename over the target (atomic on POSIX
/// filesystems), then fsync the parent directory — without the syncs a
/// power loss can persist the rename before the data, replacing the
/// previous good file with garbage.
pub fn write_atomic(path: &Path, text: &str) -> std::io::Result<()> {
    write_atomic_bytes(path, text.as_bytes())
}

/// Byte-blob variant of [`write_atomic`] — same tmp/fsync/rename/dir-sync
/// discipline, used by the checkpoint chunk tier for binary chunk files.
pub fn write_atomic_bytes(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    let tmp = path.with_file_name(name);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    // Directory fsync makes the rename itself durable; best-effort since
    // opening a directory for sync is not supported everywhere.
    if let Some(parent) = path.parent() {
        if let Ok(d) = std::fs::File::open(parent) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tune_persist_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn u64_roundtrip_is_lossless_above_2_53() {
        for v in [0u64, 1, u64::MAX, 0x9E3779B97F4A7C15, (1 << 53) + 1] {
            assert_eq!(u64_from_json(&u64_to_json(v)), Some(v), "{v}");
        }
    }

    #[test]
    fn param_roundtrip_preserves_variants() {
        for v in [
            ParamValue::F64(0.1),
            ParamValue::I64(-3),
            ParamValue::Str("relu".into()),
            ParamValue::Bool(true),
        ] {
            let j = param_to_json(&v);
            let back = param_from_json(&parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(back, v);
        }
    }

    #[test]
    fn config_roundtrip() {
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(0.015625));
        c.insert("layers".into(), ParamValue::I64(4));
        c.insert("act".into(), ParamValue::Str("tanh".into()));
        let j = config_to_json(&c);
        assert_eq!(config_from_json(&parse(&j.to_string()).unwrap()).unwrap(), c);
    }

    #[test]
    fn snapshot_write_is_atomic_and_readable() {
        let dir = ExperimentDir::new(tmpdir("snap")).unwrap();
        assert!(!dir.has_snapshot());
        dir.write_snapshot(&Json::obj(vec![("version", Json::Num(1.0))])).unwrap();
        assert!(dir.has_snapshot());
        let s = dir.read_snapshot().unwrap();
        assert_eq!(s.get("version").unwrap().as_u64(), Some(1));
        // The tmp file must not linger.
        assert!(!dir.root().join("snapshot.json.tmp").exists());
        std::fs::remove_dir_all(dir.root()).ok();
    }

    #[test]
    fn reset_clears_stale_durable_state_but_keeps_manifest() {
        let dir = ExperimentDir::new(tmpdir("reset")).unwrap();
        dir.write_snapshot(&Json::obj(vec![("version", Json::Num(1.0))])).unwrap();
        dir.write_manifest(&Json::obj(vec![("name", Json::Str("x".into()))])).unwrap();
        std::fs::write(dir.trial_log_path(0), "stale\n").unwrap();
        std::fs::write(dir.root().join("experiment.json"), "[]").unwrap();
        std::fs::write(dir.checkpoints_dir().join("trial0_iter1_ckpt1.bin"), [1]).unwrap();
        dir.reset().unwrap();
        assert!(!dir.has_snapshot());
        assert!(!dir.trial_log_path(0).exists());
        assert!(!dir.root().join("experiment.json").exists());
        assert_eq!(std::fs::read_dir(dir.checkpoints_dir()).unwrap().count(), 0);
        assert!(dir.read_manifest().is_some()); // caller overwrites it
        std::fs::remove_dir_all(dir.root()).ok();
    }

    #[test]
    fn delta_file_appends_reads_and_clears() {
        let dir = ExperimentDir::new(tmpdir("delta")).unwrap();
        assert!(dir.read_deltas().is_empty());
        dir.append_delta(&Json::obj(vec![("seq", Json::Num(1.0))])).unwrap();
        dir.append_delta(&Json::obj(vec![("seq", Json::Num(2.0))])).unwrap();
        let deltas = dir.read_deltas();
        assert_eq!(deltas.len(), 2);
        assert_eq!(deltas[1].get("seq").unwrap().as_u64(), Some(2));
        dir.clear_deltas().unwrap();
        assert!(dir.read_deltas().is_empty());
        dir.clear_deltas().unwrap(); // idempotent on a missing file
        std::fs::remove_dir_all(dir.root()).ok();
    }

    #[test]
    fn torn_final_delta_line_is_dropped_and_appends_stay_readable() {
        let dir = ExperimentDir::new(tmpdir("delta_torn")).unwrap();
        dir.append_delta(&Json::obj(vec![("seq", Json::Num(1.0))])).unwrap();
        // Simulate a crash mid-append: raw partial line at the tail.
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.root().join("snapshot.delta.jsonl"))
            .unwrap();
        f.write_all(b"{\"seq\":2,\"tri").unwrap();
        drop(f);
        let deltas = dir.read_deltas();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].get("seq").unwrap().as_u64(), Some(1));
        // A resumed run appends past the torn fragment: the guard must
        // start a fresh line so the new (acknowledged) record does not
        // merge into the garbage and vanish.
        dir.append_delta(&Json::obj(vec![("seq", Json::Num(3.0))])).unwrap();
        let deltas = dir.read_deltas();
        assert_eq!(deltas.len(), 2, "post-torn append must stay readable");
        assert_eq!(deltas[1].get("seq").unwrap().as_u64(), Some(3));
        std::fs::remove_dir_all(dir.root()).ok();
    }

    #[test]
    fn reset_also_clears_the_delta_file() {
        let dir = ExperimentDir::new(tmpdir("delta_reset")).unwrap();
        dir.append_delta(&Json::obj(vec![("seq", Json::Num(1.0))])).unwrap();
        dir.write_manifest(&Json::obj(vec![("name", Json::Str("x".into()))])).unwrap();
        dir.reset().unwrap();
        assert!(dir.read_deltas().is_empty());
        std::fs::remove_dir_all(dir.root()).ok();
    }

    #[test]
    fn prune_drops_future_rows_end_lines_and_garbage() {
        let dir = ExperimentDir::new(tmpdir("prune")).unwrap();
        let path = dir.trial_log_path(3);
        std::fs::write(
            &path,
            "{\"trial\":3,\"config\":{\"lr\":0.1},\"seed\":0}\n\
             {\"trial\":3,\"iteration\":1,\"loss\":0.5}\n\
             {\"trial\":3,\"iteration\":2,\"loss\":0.4}\n\
             {\"trial\":3,\"iteration\":3,\"loss\":0.3}\n\
             {\"trial\":3,\"end\":\"Stopped\"}\n\
             {\"trial\":3,\"iteration\":4,\"lo",
        )
        .unwrap();
        dir.prune_trial_log(3, 2).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3); // header + iterations 1, 2
        assert!(lines[0].contains("config"));
        assert!(lines[2].contains("\"iteration\":2"));
        std::fs::remove_dir_all(dir.root()).ok();
    }
}
