//! The Tune coordinator — the paper's system contribution.
//!
//! Layout mirrors the paper's API split:
//! * [`trial`] / [`spec`] — trials, configs, the parameter DSL (§3, §4.3)
//! * [`schedulers`] — the trial-scheduling API + Table 1 algorithms (§4.2)
//! * [`search`] — suggestion algorithms (grid / random / TPE)
//! * [`executor`] — where trainables run (discrete-event sim,
//!   thread-per-trial, or bounded worker pool)
//! * [`runner`] — the central event loop tying it all together
//! * [`experiment`] — user-facing `run_experiments` facade (§4.3)
//! * [`hub`] — the serving layer: N experiments multiplexed over one
//!   shared worker pool (`tune serve`)
//! * [`persist`] — the durable experiment directory (crash-safe
//!   snapshots + `--resume`)

pub mod executor;
pub mod experiment;
pub mod hub;
pub mod persist;
pub mod runner;
pub mod schedulers;
pub mod search;
pub mod spec;
pub mod spec_file;
pub mod trial;

pub use experiment::{
    build_runner, run_experiments, ExecMode, ExperimentSpec, RunOptions, SchedulerKind, SearchKind,
};
pub use hub::{ExperimentHub, ExperimentState, Submission};
pub use persist::ExperimentDir;
pub use runner::{ExperimentResult, RunnerStats, TrialRunner};
pub use spec_file::SpecFile;
pub use trial::{Config, Mode, ParamValue, ResultRow, Trial, TrialId, TrialStatus};
