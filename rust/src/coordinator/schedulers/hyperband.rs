//! HyperBand, the original synchronous formulation (Li et al. 2016;
//! Table 1: 215 LoC — the most intricate scheduler in the paper, and
//! the algorithm whose rung *barriers* motivated Tune's pause/resume
//! machinery: trials must checkpoint, yield resources while waiting for
//! their cohort, and resume when promoted).
//!
//! Structure: brackets indexed by s = s_max .. 0 trade off the number of
//! configurations n_s = ceil((s_max+1)/(s+1) * eta^s) against their
//! starting budget r_s = R / eta^s. Within a bracket, successive halving
//! runs rungs at milestones r_s * eta^k; at each rung barrier the top
//! 1/eta of the cohort is promoted and the rest are terminated.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};
use crate::coordinator::trial::{TrialId, TrialStatus};
use crate::util::json::Json;

struct Bracket {
    /// Bracket index s (larger = more configs, less initial budget).
    #[allow(dead_code)]
    s: u32,
    /// Max trials admitted to this bracket.
    capacity: usize,
    /// Current rung milestone in iterations.
    milestone: u64,
    /// Members still in play (not stopped/errored/bracket-dropped).
    active: BTreeSet<TrialId>,
    /// Scores recorded at the current rung (ascending-normalized).
    recorded: BTreeMap<TrialId, f64>,
    /// Paused trials approved to resume at the next rung.
    promoted: VecDeque<TrialId>,
    /// Closed to new members once the first rung cut has happened.
    closed: bool,
}

impl Bracket {
    fn new(s: u32, capacity: usize, r0: u64) -> Self {
        Bracket {
            s,
            capacity,
            milestone: r0.max(1),
            active: BTreeSet::new(),
            recorded: BTreeMap::new(),
            promoted: VecDeque::new(),
            closed: false,
        }
    }

    fn is_full(&self) -> bool {
        self.closed || self.active.len() + self.recorded.len() >= self.capacity
    }

    /// All live members have reached the barrier?
    fn barrier_complete(&self) -> bool {
        self.active.is_empty() && !self.recorded.is_empty()
    }
}

/// Synchronous HyperBand: brackets of successive-halving cohorts with
/// rung barriers (pause, cut, resume the promoted).
pub struct HyperBandScheduler {
    /// R: maximum iterations a single trial may consume.
    pub max_t: u64,
    /// Halving factor: keep the top 1/eta of each rung cohort.
    pub eta: f64,
    s_max: u32,
    brackets: Vec<Bracket>,
    /// trial -> bracket index.
    assignment: BTreeMap<TrialId, usize>,
    /// Next bracket s to open when the current one fills.
    next_s: u32,
    /// Losers of completed rung cuts, to be terminated by the runner.
    pending_stops: Vec<TrialId>,
    stopped: u64,
}

impl HyperBandScheduler {
    /// New scheduler with brackets shaped by `R = max_t` and `eta`.
    pub fn new(max_t: u64, eta: f64) -> Self {
        assert!(eta > 1.0 && max_t >= 1);
        let s_max = (max_t as f64).ln().div_euclid((eta).ln()) as u32;
        HyperBandScheduler {
            max_t,
            eta,
            s_max,
            brackets: Vec::new(),
            assignment: BTreeMap::new(),
            next_s: s_max,
            pending_stops: Vec::new(),
            stopped: 0,
        }
    }

    /// Trials terminated by rung cuts so far.
    pub fn num_stopped(&self) -> u64 {
        self.stopped
    }

    /// n_s = ceil((s_max + 1) / (s + 1) * eta^s), r_s = R / eta^s.
    fn bracket_shape(&self, s: u32) -> (usize, u64) {
        let n = ((self.s_max + 1) as f64 / (s + 1) as f64 * self.eta.powi(s as i32)).ceil();
        let r = (self.max_t as f64 / self.eta.powi(s as i32)).round().max(1.0);
        (n as usize, r as u64)
    }

    fn open_bracket(&mut self) -> usize {
        let s = self.next_s;
        self.next_s = if s == 0 { self.s_max } else { s - 1 };
        let (n, r) = self.bracket_shape(s);
        self.brackets.push(Bracket::new(s, n, r));
        self.brackets.len() - 1
    }

    /// Cut the current rung of bracket `bi`: promote the top 1/eta,
    /// terminate the rest, advance the milestone.
    fn cut_rung(&mut self, bi: usize) {
        let eta = self.eta;
        let max_t = self.max_t;
        let b = &mut self.brackets[bi];
        let mut scored: Vec<(TrialId, f64)> = b.recorded.iter().map(|(k, v)| (*k, *v)).collect();
        // Best first; NaN-proof (diverged cohort members sort last and
        // are cut at the rung instead of panicking the barrier).
        scored.sort_by(|a, b| crate::util::order::desc(a.1, b.1));
        let keep = ((scored.len() as f64 / eta).floor() as usize).max(1);
        let next_milestone = ((b.milestone as f64) * eta).round() as u64;

        b.recorded.clear();
        b.active.clear();
        b.closed = true;
        if next_milestone > max_t || scored.len() == 1 {
            // Final rung: the single survivor trains to max_t and then
            // completes via the experiment's stopping criterion.
            let (winner, _) = scored[0];
            b.active.insert(winner);
            b.promoted.push_back(winner);
            b.milestone = max_t;
            for (id, _) in &scored[1..] {
                self.pending_stops.push(*id);
                self.stopped += 1;
            }
        } else {
            b.milestone = next_milestone;
            for (i, (id, _)) in scored.iter().enumerate() {
                if i < keep {
                    b.active.insert(*id);
                    b.promoted.push_back(*id);
                } else {
                    self.pending_stops.push(*id);
                    self.stopped += 1;
                }
            }
        }
    }
}

impl TrialScheduler for HyperBandScheduler {
    fn name(&self) -> &'static str {
        "hyperband"
    }

    fn on_trial_add(&mut self, _ctx: &SchedulerCtx, trial: &Trial) {
        // Fill the newest open bracket; open the next (smaller-s) one
        // when full — cycling brackets exactly like the reference
        // implementation, so an arbitrary num_samples spreads across
        // the bracket spectrum.
        let bi = match self.brackets.iter().rposition(|b| !b.is_full()) {
            Some(bi) => bi,
            None => self.open_bracket(),
        };
        self.brackets[bi].active.insert(trial.id);
        self.assignment.insert(trial.id, bi);
    }

    fn on_result(&mut self, ctx: &SchedulerCtx, trial: &Trial, result: &ResultRow) -> Decision {
        let Some(&bi) = self.assignment.get(&trial.id) else {
            return Decision::Continue;
        };
        let Some(value) = result.get(ctx.metric_id).map(|v| ctx.mode.ascending(v)) else {
            return Decision::Continue;
        };
        let b = &mut self.brackets[bi];
        if result.iteration < b.milestone {
            return Decision::Continue;
        }
        // Barrier reached: record and pause (checkpoint + yield).
        b.recorded.insert(trial.id, value);
        b.active.remove(&trial.id);
        let complete = b.barrier_complete();
        if complete {
            self.cut_rung(bi);
            // If this trial survived the cut it is in `promoted` and
            // will be resumed by choose_trial_to_run; if it lost, it is
            // in pending_stops. Either way it pauses now — unless it
            // lost, in which case stop it directly (cheaper than
            // pause-then-stop).
            if let Some(pos) = self.pending_stops.iter().position(|id| *id == trial.id) {
                self.pending_stops.remove(pos);
                return Decision::Stop;
            }
        }
        Decision::Pause
    }

    fn on_trial_remove(&mut self, _ctx: &SchedulerCtx, id: TrialId) {
        // Keep rung barriers from waiting on dead trials.
        if let Some(bi) = self.assignment.remove(&id) {
            let b = &mut self.brackets[bi];
            b.active.remove(&id);
            b.recorded.remove(&id);
            b.promoted.retain(|p| *p != id);
            if b.barrier_complete() {
                self.cut_rung(bi);
            }
        }
    }

    fn choose_trial_to_run(&mut self, ctx: &SchedulerCtx) -> Option<TrialId> {
        // Resume promoted (paused) trials first — they hold rung
        // progress; then admit fresh pending trials.
        for b in &mut self.brackets {
            while let Some(id) = b.promoted.front().copied() {
                match ctx.trials.get(&id).map(|t| t.status) {
                    Some(TrialStatus::Paused) => {
                        b.promoted.pop_front();
                        return Some(id);
                    }
                    Some(TrialStatus::Running) | Some(TrialStatus::Pending) => break,
                    _ => {
                        b.promoted.pop_front(); // terminal: drop stale entry
                    }
                }
            }
        }
        ctx.first_pending()
    }

    /// Trials the last rung cut condemned (they are Paused).
    fn drain_stops(&mut self) -> Vec<TrialId> {
        std::mem::take(&mut self.pending_stops)
    }

    fn snapshot(&self) -> Json {
        fn ids<I: IntoIterator<Item = TrialId>>(it: I) -> Json {
            Json::Arr(it.into_iter().map(|id| Json::Num(id as f64)).collect())
        }
        let brackets = self
            .brackets
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("s", Json::Num(b.s as f64)),
                    ("capacity", Json::Num(b.capacity as f64)),
                    ("milestone", Json::Num(b.milestone as f64)),
                    ("active", ids(b.active.iter().copied())),
                    (
                        "recorded",
                        Json::Obj(
                            b.recorded
                                .iter()
                                // num_to_json: a diverged (NaN) rung score
                                // must survive the snapshot roundtrip.
                                .map(|(id, v)| {
                                    (id.to_string(), crate::coordinator::persist::num_to_json(*v))
                                })
                                .collect(),
                        ),
                    ),
                    ("promoted", ids(b.promoted.iter().copied())),
                    ("closed", Json::Bool(b.closed)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("brackets", Json::Arr(brackets)),
            (
                "assignment",
                Json::Obj(
                    self.assignment
                        .iter()
                        .map(|(id, bi)| (id.to_string(), Json::Num(*bi as f64)))
                        .collect(),
                ),
            ),
            ("next_s", Json::Num(self.next_s as f64)),
            ("pending_stops", ids(self.pending_stops.iter().copied())),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        let id_arr = |j: &Json| -> Option<Vec<TrialId>> {
            j.as_arr()?.iter().map(|v| v.as_u64()).collect()
        };
        let mut brackets = Vec::new();
        for bj in snap
            .get("brackets")
            .and_then(|b| b.as_arr())
            .ok_or("hyperband snapshot: missing brackets")?
        {
            let mut recorded = BTreeMap::new();
            for (k, v) in bj
                .get("recorded")
                .and_then(|r| r.as_obj())
                .ok_or("hyperband snapshot: bad recorded")?
            {
                recorded.insert(
                    k.parse::<TrialId>().map_err(|e| e.to_string())?,
                    crate::coordinator::persist::num_from_json(v)
                        .ok_or("hyperband snapshot: bad recorded value")?,
                );
            }
            brackets.push(Bracket {
                s: bj.get("s").and_then(|v| v.as_u64()).ok_or("bad s")? as u32,
                capacity: bj.get("capacity").and_then(|v| v.as_u64()).ok_or("bad capacity")?
                    as usize,
                milestone: bj.get("milestone").and_then(|v| v.as_u64()).ok_or("bad milestone")?,
                active: bj
                    .get("active")
                    .and_then(id_arr)
                    .ok_or("bad active")?
                    .into_iter()
                    .collect(),
                recorded,
                promoted: bj
                    .get("promoted")
                    .and_then(id_arr)
                    .ok_or("bad promoted")?
                    .into_iter()
                    .collect(),
                closed: bj.get("closed").and_then(|v| v.as_bool()).ok_or("bad closed")?,
            });
        }
        self.brackets = brackets;
        self.assignment = BTreeMap::new();
        for (k, v) in snap
            .get("assignment")
            .and_then(|a| a.as_obj())
            .ok_or("hyperband snapshot: missing assignment")?
        {
            self.assignment.insert(
                k.parse::<TrialId>().map_err(|e| e.to_string())?,
                v.as_u64().ok_or("hyperband snapshot: bad bracket index")? as usize,
            );
        }
        self.next_s =
            snap.get("next_s").and_then(|v| v.as_u64()).ok_or("hyperband snapshot: bad next_s")?
                as u32;
        self.pending_stops = snap
            .get("pending_stops")
            .and_then(id_arr)
            .ok_or("hyperband snapshot: bad pending_stops")?;
        self.stopped = snap.get("stopped").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::trial::Mode;

    #[test]
    fn bracket_shapes_match_hyperband_paper() {
        let s = HyperBandScheduler::new(81, 3.0);
        assert_eq!(s.s_max, 4);
        assert_eq!(s.bracket_shape(4), (81, 1));
        assert_eq!(s.bracket_shape(3), (34, 3));
        assert_eq!(s.bracket_shape(2), (15, 9));
        assert_eq!(s.bracket_shape(1), (8, 27));
        assert_eq!(s.bracket_shape(0), (5, 81));
    }

    #[test]
    fn rung_barrier_promotes_top_third() {
        let mut sb = Sandbox::new(9, "acc", Mode::Max);
        let mut s = HyperBandScheduler::new(27, 3.0);
        sb.add_all(&mut s);
        // All 9 land in bracket s_max=3 (capacity 54 at R=27? shape:
        // s_max = floor(ln27/ln3)=3, bracket s=3: n=ceil(4/4*27)=27,r=1).
        let mut decisions = Vec::new();
        for id in 0..9u64 {
            let acc = (id + 1) as f64 / 10.0;
            decisions.push(sb.feed(&mut s, id, 1, acc));
        }
        // Barrier completes only when the whole cohort reports... but
        // capacity 27 > 9 members: barrier waits for active set == 9
        // reports. Since all 9 reported, the last feed triggers the cut.
        let stops = s.drain_stops();
        let paused = decisions.iter().filter(|d| **d == Decision::Pause).count();
        let stopped_inline = decisions.iter().filter(|d| **d == Decision::Stop).count();
        // 9 trials, keep floor(9/3)=3: 6 terminated (inline or drained).
        assert_eq!(stops.len() + stopped_inline, 6, "{decisions:?}");
        assert_eq!(paused, 9 - stopped_inline);
        // Promoted trials are the top-3 scorers: ids 6, 7, 8.
        let sb2 = sb;
        let _ = sb2;
        assert_eq!(s.num_stopped(), 6);
    }

    #[test]
    fn promoted_trials_resume_first() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = HyperBandScheduler::new(9, 3.0);
        sb.add_all(&mut s);
        for id in 0..3u64 {
            sb.feed(&mut s, id, 1, (id + 1) as f64);
        }
        let _ = s.drain_stops();
        // Top trial (id 2) should be offered before any pending trial.
        let choice = s.choose_trial_to_run(&sb.ctx());
        assert_eq!(choice, Some(2));
    }

    #[test]
    fn trial_error_unblocks_barrier() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = HyperBandScheduler::new(9, 3.0);
        sb.add_all(&mut s);
        sb.feed(&mut s, 0, 1, 0.9);
        sb.feed(&mut s, 1, 1, 0.5);
        // Trial 2 dies before reaching the rung: barrier must cut anyway.
        sb.trials.get_mut(&2).unwrap().status = TrialStatus::Errored;
        let ctx = sb.ctx();
        s.on_trial_remove(&ctx, 2);
        // Cohort of 2 recorded, cut happened: keep floor(2/3)=0 -> max(1).
        assert!(s.num_stopped() >= 1 || !s.brackets[0].promoted.is_empty());
    }

    #[test]
    fn multiple_brackets_open_as_capacity_fills() {
        let mut sb = Sandbox::new(100, "acc", Mode::Max);
        let mut s = HyperBandScheduler::new(9, 3.0);
        sb.add_all(&mut s);
        // R=9, eta=3: s_max=2; bracket s=2 capacity ceil(3/3*9)=9.
        assert!(s.brackets.len() > 1, "brackets={}", s.brackets.len());
        assert_eq!(s.brackets[0].capacity, 9);
    }

    #[test]
    fn snapshot_restore_preserves_barrier_and_promotions() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut a = HyperBandScheduler::new(9, 3.0);
        sb.add_all(&mut a);
        for id in 0..3u64 {
            sb.feed(&mut a, id, 1, (id + 1) as f64);
        }
        // Snapshot BEFORE draining: pending stops and the promotion
        // queue must both survive the roundtrip.
        let text = TrialScheduler::snapshot(&a).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut b = HyperBandScheduler::new(9, 3.0);
        TrialScheduler::restore(&mut b, &parsed).unwrap();
        assert_eq!(b.num_stopped(), a.num_stopped());
        assert_eq!(b.drain_stops(), a.drain_stops());
        assert_eq!(b.choose_trial_to_run(&sb.ctx()), Some(2));
        assert_eq!(b.brackets.len(), a.brackets.len());
        assert_eq!(b.brackets[0].milestone, a.brackets[0].milestone);
        assert_eq!(b.assignment, a.assignment);
    }

    #[test]
    fn below_milestone_continues() {
        let mut sb = Sandbox::new(2, "acc", Mode::Max);
        let mut s = HyperBandScheduler::new(27, 3.0);
        sb.add_all(&mut s);
        // Bracket s=3 starts at r=1, so iteration 1 hits the barrier;
        // feed a lower-s bracket instead: fill bracket 0 (cap 27) fully
        // is overkill — instead verify continue below milestone with a
        // custom bracket: use max_t=27 bracket s=0 via direct shape.
        // Simpler: milestone of bracket 0 is 1, so nothing to check
        // below it; assert iteration 0 result (no rung) continues.
        let d = sb.feed(&mut s, 0, 0, 0.5);
        assert_eq!(d, Decision::Continue);
    }
}
