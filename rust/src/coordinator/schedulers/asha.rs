//! Asynchronous HyperBand / ASHA (Li et al. 2018; Table 1: 78 LoC) —
//! "the asynchronous variation which is simpler to implement in the
//! distributed setting".
//!
//! Rungs sit at iterations r, r*eta, r*eta^2, ... up to max_t. When a
//! trial reaches a rung it records its metric there; it is promoted
//! (continues) iff it sits in the top 1/eta of everything recorded at
//! that rung so far, else it stops. No barrier, no paused trials — the
//! asynchrony that makes it cluster-friendly.

use std::collections::BTreeMap;

use crate::coordinator::persist::{f64s_from_json, f64s_to_json, id_map_from_json, id_map_to_json};
use crate::util::json::Json;

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};

/// Asynchronous successive halving: promote the top 1/eta at each rung,
/// stop the rest, no barriers.
pub struct AshaScheduler {
    /// First rung: never stop before this many iterations.
    pub grace_period: u64,
    /// eta: rung spacing factor and promotion fraction 1/eta.
    pub reduction_factor: f64,
    /// Maximum iterations a single trial may train for.
    pub max_t: u64,
    /// rung iteration -> ascending-normalized metrics recorded there.
    rungs: BTreeMap<u64, Vec<f64>>,
    stopped: u64,
}

impl AshaScheduler {
    /// New scheduler with rungs at `grace_period * reduction_factor^k`.
    pub fn new(grace_period: u64, reduction_factor: f64, max_t: u64) -> Self {
        assert!(reduction_factor > 1.0 && grace_period >= 1);
        AshaScheduler {
            grace_period,
            reduction_factor,
            max_t,
            rungs: BTreeMap::new(),
            stopped: 0,
        }
    }

    /// Trials this scheduler has stopped at a rung so far.
    pub fn num_stopped(&self) -> u64 {
        self.stopped
    }

    /// Largest rung milestone <= iter (None below the first rung).
    fn milestone(&self, iter: u64) -> Option<u64> {
        let mut rung = self.grace_period;
        let mut hit = None;
        while rung <= iter && rung < self.max_t {
            hit = Some(rung);
            rung = ((rung as f64) * self.reduction_factor).round() as u64;
        }
        hit.filter(|m| *m == iter)
    }

    /// Top 1/eta cutoff of the values recorded at a rung: keep
    /// max(1, floor(n/eta)) values; the cutoff is the worst kept value.
    fn cutoff(values: &[f64], eta: f64) -> Option<f64> {
        if values.is_empty() {
            return None;
        }
        // O(n) selection of the keep-th best (perf iteration 3, §Perf).
        // NaN-proof: diverged trials rank strictly worst at the rung.
        let mut scratch = values.to_vec();
        let keep = ((scratch.len() as f64 / eta).floor() as usize).max(1);
        let (_, kth, _) =
            scratch.select_nth_unstable_by(keep - 1, |a, b| crate::util::order::desc(*a, *b));
        Some(*kth)
    }
}

impl TrialScheduler for AshaScheduler {
    fn name(&self) -> &'static str {
        "asha"
    }

    fn on_result(&mut self, ctx: &SchedulerCtx, _trial: &Trial, result: &ResultRow) -> Decision {
        let Some(value) = result.metric(ctx.metric).map(|v| ctx.mode.ascending(v)) else {
            return Decision::Continue;
        };
        let Some(rung) = self.milestone(result.iteration) else {
            return Decision::Continue;
        };
        let values = self.rungs.entry(rung).or_default();
        values.push(value);
        let cut = Self::cutoff(values, self.reduction_factor).unwrap();
        // Total order, not `<`: a NaN value must stop (it is below every
        // cutoff), not slip through because `NaN < cut` is false.
        if crate::util::order::asc(value, cut) == std::cmp::Ordering::Less {
            self.stopped += 1;
            Decision::Stop
        } else {
            // Promotion is implicit: the trial just keeps training
            // toward the next rung (checkpoint so late arrivals at this
            // rung that displace us lose nothing — cheap insurance).
            Decision::Checkpoint
        }
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("rungs", id_map_to_json(&self.rungs, |vs| f64s_to_json(vs))),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.rungs = snap
            .get("rungs")
            .and_then(|r| id_map_from_json(r, f64s_from_json))
            .ok_or("asha snapshot: bad rungs")?;
        self.stopped = snap.get("stopped").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::trial::Mode;

    #[test]
    fn milestones_are_geometric() {
        let s = AshaScheduler::new(2, 3.0, 100);
        assert_eq!(s.milestone(2), Some(2));
        assert_eq!(s.milestone(6), Some(6));
        assert_eq!(s.milestone(18), Some(18));
        assert_eq!(s.milestone(54), Some(54));
        assert_eq!(s.milestone(3), None);
        assert_eq!(s.milestone(1), None);
    }

    #[test]
    fn bottom_trials_stop_at_first_rung() {
        let mut sb = Sandbox::new(9, "acc", Mode::Max);
        let mut s = AshaScheduler::new(1, 3.0, 81);
        let mut stopped = 0;
        // Trials arrive at rung 1 in descending quality.
        for id in 0..9u64 {
            let acc = 1.0 - id as f64 * 0.1;
            match sb.feed(&mut s, id, 1, acc) {
                Decision::Stop => stopped += 1,
                Decision::Checkpoint | Decision::Continue => {}
                d => panic!("{d:?}"),
            }
        }
        // With eta=3, roughly 2/3 of later arrivals are below cutoff.
        assert!(stopped >= 4, "stopped={stopped}");
        assert!(s.num_stopped() == stopped);
    }

    #[test]
    fn early_arrivals_are_optimistically_promoted() {
        let mut sb = Sandbox::new(2, "acc", Mode::Max);
        let mut s = AshaScheduler::new(1, 2.0, 100);
        // First at a rung always promotes (top-1 of 1).
        assert_ne!(sb.feed(&mut s, 0, 1, 0.1), Decision::Stop);
    }

    #[test]
    fn non_rung_iterations_continue() {
        let mut sb = Sandbox::new(1, "acc", Mode::Max);
        let mut s = AshaScheduler::new(4, 2.0, 100);
        for iter in 1..4 {
            assert_eq!(sb.feed(&mut s, 0, iter, 0.0), Decision::Continue);
        }
    }

    #[test]
    fn min_mode_promotes_low_loss() {
        let mut sb = Sandbox::new(4, "loss", Mode::Min);
        let mut s = AshaScheduler::new(1, 2.0, 100);
        sb.feed(&mut s, 0, 1, 0.1);
        sb.feed(&mut s, 1, 1, 0.2);
        sb.feed(&mut s, 2, 1, 0.3);
        // Worst loss among 4 with eta=2 -> below top-half cutoff.
        assert_eq!(sb.feed(&mut s, 3, 1, 0.9), Decision::Stop);
    }

    #[test]
    fn snapshot_restore_preserves_rung_decisions() {
        let mut sb = Sandbox::new(12, "acc", Mode::Max);
        let mut a = AshaScheduler::new(1, 3.0, 81);
        for id in 0..6u64 {
            sb.feed(&mut a, id, 1, 0.9 - id as f64 * 0.1);
        }
        // Serialize through text (what the snapshot file does), restore
        // into a fresh instance, then feed identical follow-ups to both.
        let text = TrialScheduler::snapshot(&a).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut b = AshaScheduler::new(1, 3.0, 81);
        TrialScheduler::restore(&mut b, &parsed).unwrap();
        assert_eq!(b.num_stopped(), a.num_stopped());
        // ASHA decisions depend only on result + rung state, so both
        // instances can consume the same follow-up stream.
        for id in 6..12u64 {
            let v = 0.95 - id as f64 * 0.07;
            let da = sb.feed(&mut a, id, 1, v);
            let t = sb.trials[&id].clone();
            let r = super::super::testutil::row(1, "acc", v);
            let db = b.on_result(&sb.ctx(), &t, &r);
            assert_eq!(da, db, "diverged at trial {id}");
        }
    }

    #[test]
    fn no_rungs_at_or_past_max_t() {
        let s = AshaScheduler::new(1, 2.0, 8);
        assert_eq!(s.milestone(8), None); // max_t itself is not a rung
        assert_eq!(s.milestone(4), Some(4));
    }
}
