//! Asynchronous HyperBand / ASHA (Li et al. 2018; Table 1: 78 LoC) —
//! "the asynchronous variation which is simpler to implement in the
//! distributed setting".
//!
//! Rungs sit at iterations r, r*eta, r*eta^2, ... up to max_t. When a
//! trial reaches a rung it records its metric there; it is promoted
//! (continues) iff it sits in the top 1/eta of everything recorded at
//! that rung so far, else it stops. No barrier, no paused trials — the
//! asynchrony that makes it cluster-friendly.
//!
//! Perf: the rung ladder is computed once at construction (`milestone`
//! is a binary search, not a geometric re-derivation per result), and
//! each rung keeps a two-heap order statistic over its recorded values
//! so the top-1/eta cutoff is O(log n) per result instead of an O(n)
//! selection over a freshly copied vector.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::coordinator::persist::{f64s_from_json, f64s_to_json, id_map_from_json, id_map_to_json};
use crate::util::json::Json;
use crate::util::order::OrdF64;

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};

/// One rung's recorded values with an incremental top-1/eta cutoff.
///
/// Invariant: `top` (a min-heap) holds the `max(1, floor(n/eta))` best
/// values seen so far, `rest` (a max-heap) the others, and every value
/// in `top` is >= every value in `rest` under the NaN-proof total
/// order. The cutoff — the worst *kept* value, exactly what
/// `select_nth_unstable` at index keep-1 of the descending sort would
/// return — is `top`'s minimum, read in O(1) and maintained in
/// O(log n) per insert.
///
/// `all` additionally keeps the values in arrival order: it serves the
/// (unchanged) snapshot format and the delta cursor (`flushed` marks
/// how much of it the last persisted snapshot already contains), and
/// costs exactly what the pre-incremental rung vector cost.
#[derive(Default)]
struct Rung {
    all: Vec<f64>,
    top: BinaryHeap<Reverse<OrdF64>>,
    rest: BinaryHeap<OrdF64>,
    flushed: usize,
}

impl Rung {
    fn len(&self) -> usize {
        self.all.len()
    }

    /// Record `v`; returns the rung's new top-1/eta cutoff.
    fn insert(&mut self, v: f64, eta: f64) -> f64 {
        self.all.push(v);
        self.rest.push(OrdF64(v));
        // keep is monotone in n (eta > 1), so `top` only ever grows.
        let keep = ((self.len() as f64 / eta).floor() as usize).max(1);
        while self.top.len() < keep {
            let x = self.rest.pop().expect("rest holds at least keep - top values");
            self.top.push(Reverse(x));
        }
        // At most one element (the new one) can sit on the wrong side.
        let out_of_place = match (self.rest.peek(), self.top.peek()) {
            (Some(&r), Some(&Reverse(t))) => r > t,
            _ => false,
        };
        if out_of_place {
            let r = self.rest.pop().unwrap();
            let Reverse(t) = self.top.pop().unwrap();
            self.rest.push(t);
            self.top.push(Reverse(r));
        }
        self.top.peek().expect("top is non-empty after insert").0 .0
    }

    /// Rebuild from persisted values (snapshot restore / delta fold).
    fn extend_from(&mut self, values: &[f64], eta: f64) {
        for v in values {
            self.insert(*v, eta);
        }
        self.flushed = self.all.len(); // came from disk: already durable
    }
}

/// Asynchronous successive halving: promote the top 1/eta at each rung,
/// stop the rest, no barriers.
pub struct AshaScheduler {
    /// First rung: never stop before this many iterations.
    pub grace_period: u64,
    /// eta: rung spacing factor and promotion fraction 1/eta.
    pub reduction_factor: f64,
    /// Maximum iterations a single trial may train for.
    pub max_t: u64,
    /// Rung milestones `grace * eta^k` below `max_t`, precomputed once.
    ladder: Vec<u64>,
    /// rung iteration -> order statistics over the ascending-normalized
    /// metrics recorded there.
    rungs: BTreeMap<u64, Rung>,
    stopped: u64,
}

impl AshaScheduler {
    /// New scheduler with rungs at `grace_period * reduction_factor^k`.
    pub fn new(grace_period: u64, reduction_factor: f64, max_t: u64) -> Self {
        assert!(reduction_factor > 1.0 && grace_period >= 1);
        let mut ladder = Vec::new();
        let mut rung = grace_period;
        while rung < max_t {
            ladder.push(rung);
            let next = ((rung as f64) * reduction_factor).round() as u64;
            // Guard degenerate rounding (eta barely above 1): the ladder
            // must strictly ascend or the old derivation loop would spin.
            rung = next.max(rung + 1);
        }
        AshaScheduler {
            grace_period,
            reduction_factor,
            max_t,
            ladder,
            rungs: BTreeMap::new(),
            stopped: 0,
        }
    }

    /// Trials this scheduler has stopped at a rung so far.
    pub fn num_stopped(&self) -> u64 {
        self.stopped
    }

    /// Is `iter` exactly a rung milestone? (Binary search over the
    /// precomputed ladder — O(log log-spaced rung count) per result.)
    fn milestone(&self, iter: u64) -> Option<u64> {
        self.ladder.binary_search(&iter).ok().map(|i| self.ladder[i])
    }
}

impl TrialScheduler for AshaScheduler {
    fn name(&self) -> &'static str {
        "asha"
    }

    fn on_result(&mut self, ctx: &SchedulerCtx, _trial: &Trial, result: &ResultRow) -> Decision {
        let Some(value) = result.get(ctx.metric_id).map(|v| ctx.mode.ascending(v)) else {
            return Decision::Continue;
        };
        let Some(rung) = self.milestone(result.iteration) else {
            return Decision::Continue;
        };
        let cut = self
            .rungs
            .entry(rung)
            .or_default()
            .insert(value, self.reduction_factor);
        // Total order, not `<`: a NaN value must stop (it is below every
        // cutoff), not slip through because `NaN < cut` is false.
        if crate::util::order::asc(value, cut) == std::cmp::Ordering::Less {
            self.stopped += 1;
            Decision::Stop
        } else {
            // Promotion is implicit: the trial just keeps training
            // toward the next rung (checkpoint so late arrivals at this
            // rung that displace us lose nothing — cheap insurance).
            Decision::Checkpoint
        }
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("rungs", id_map_to_json(&self.rungs, |r| f64s_to_json(&r.all))),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        let values = snap
            .get("rungs")
            .and_then(|r| id_map_from_json(r, f64s_from_json))
            .ok_or("asha snapshot: bad rungs")?;
        self.rungs = BTreeMap::new();
        for (rung, vs) in values {
            self.rungs.entry(rung).or_default().extend_from(&vs, self.reduction_factor);
        }
        self.stopped = snap.get("stopped").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }

    fn snapshot_delta(&mut self) -> Json {
        let append: BTreeMap<u64, Vec<f64>> = self
            .rungs
            .iter()
            .filter(|(_, r)| r.flushed < r.all.len())
            .map(|(rung, r)| (*rung, r.all[r.flushed..].to_vec()))
            .collect();
        for r in self.rungs.values_mut() {
            r.flushed = r.all.len();
        }
        Json::obj(vec![
            ("rungs_append", id_map_to_json(&append, |vs| f64s_to_json(vs))),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn apply_delta(&mut self, delta: &Json) -> Result<(), String> {
        let append = delta
            .get("rungs_append")
            .and_then(|r| id_map_from_json(r, f64s_from_json))
            .ok_or("asha delta: bad rungs_append")?;
        for (rung, vs) in append {
            self.rungs.entry(rung).or_default().extend_from(&vs, self.reduction_factor);
        }
        self.stopped = delta.get("stopped").and_then(|v| v.as_u64()).unwrap_or(self.stopped);
        Ok(())
    }

    fn reset_delta_cursor(&mut self) {
        for r in self.rungs.values_mut() {
            r.flushed = r.all.len();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::trial::Mode;

    #[test]
    fn milestones_are_geometric() {
        let s = AshaScheduler::new(2, 3.0, 100);
        assert_eq!(s.milestone(2), Some(2));
        assert_eq!(s.milestone(6), Some(6));
        assert_eq!(s.milestone(18), Some(18));
        assert_eq!(s.milestone(54), Some(54));
        assert_eq!(s.milestone(3), None);
        assert_eq!(s.milestone(1), None);
    }

    #[test]
    fn bottom_trials_stop_at_first_rung() {
        let mut sb = Sandbox::new(9, "acc", Mode::Max);
        let mut s = AshaScheduler::new(1, 3.0, 81);
        let mut stopped = 0;
        // Trials arrive at rung 1 in descending quality.
        for id in 0..9u64 {
            let acc = 1.0 - id as f64 * 0.1;
            match sb.feed(&mut s, id, 1, acc) {
                Decision::Stop => stopped += 1,
                Decision::Checkpoint | Decision::Continue => {}
                d => panic!("{d:?}"),
            }
        }
        // With eta=3, roughly 2/3 of later arrivals are below cutoff.
        assert!(stopped >= 4, "stopped={stopped}");
        assert!(s.num_stopped() == stopped);
    }

    /// The incremental two-heap cutoff must agree with the reference
    /// O(n) selection (`select_nth_unstable` over a copy) at every
    /// insertion, including with NaNs in the stream.
    #[test]
    fn incremental_cutoff_matches_selection_reference() {
        for eta in [2.0, 3.0, 4.0] {
            let mut rung = Rung::default();
            let mut reference: Vec<f64> = Vec::new();
            let mut x = 0.42_f64;
            for i in 0..200 {
                // Deterministic pseudo-random walk with NaN injections.
                x = (x * 997.0 + i as f64 * 0.137).sin();
                let v = if i % 17 == 9 { f64::NAN } else { x };
                let cut = rung.insert(v, eta);
                reference.push(v);
                let mut scratch = reference.clone();
                let keep = ((scratch.len() as f64 / eta).floor() as usize).max(1);
                let (_, kth, _) = scratch
                    .select_nth_unstable_by(keep - 1, |a, b| crate::util::order::desc(*a, *b));
                assert_eq!(
                    crate::util::order::asc(cut, *kth),
                    std::cmp::Ordering::Equal,
                    "eta {eta}, n {}: {cut} vs {kth}",
                    reference.len()
                );
            }
        }
    }

    #[test]
    fn early_arrivals_are_optimistically_promoted() {
        let mut sb = Sandbox::new(2, "acc", Mode::Max);
        let mut s = AshaScheduler::new(1, 2.0, 100);
        // First at a rung always promotes (top-1 of 1).
        assert_ne!(sb.feed(&mut s, 0, 1, 0.1), Decision::Stop);
    }

    #[test]
    fn non_rung_iterations_continue() {
        let mut sb = Sandbox::new(1, "acc", Mode::Max);
        let mut s = AshaScheduler::new(4, 2.0, 100);
        for iter in 1..4 {
            assert_eq!(sb.feed(&mut s, 0, iter, 0.0), Decision::Continue);
        }
    }

    #[test]
    fn min_mode_promotes_low_loss() {
        let mut sb = Sandbox::new(4, "loss", Mode::Min);
        let mut s = AshaScheduler::new(1, 2.0, 100);
        sb.feed(&mut s, 0, 1, 0.1);
        sb.feed(&mut s, 1, 1, 0.2);
        sb.feed(&mut s, 2, 1, 0.3);
        // Worst loss among 4 with eta=2 -> below top-half cutoff.
        assert_eq!(sb.feed(&mut s, 3, 1, 0.9), Decision::Stop);
    }

    #[test]
    fn snapshot_restore_preserves_rung_decisions() {
        let mut sb = Sandbox::new(12, "acc", Mode::Max);
        let mut a = AshaScheduler::new(1, 3.0, 81);
        for id in 0..6u64 {
            sb.feed(&mut a, id, 1, 0.9 - id as f64 * 0.1);
        }
        // Serialize through text (what the snapshot file does), restore
        // into a fresh instance, then feed identical follow-ups to both.
        let text = TrialScheduler::snapshot(&a).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut b = AshaScheduler::new(1, 3.0, 81);
        TrialScheduler::restore(&mut b, &parsed).unwrap();
        assert_eq!(b.num_stopped(), a.num_stopped());
        // ASHA decisions depend only on result + rung state, so both
        // instances can consume the same follow-up stream.
        for id in 6..12u64 {
            let v = 0.95 - id as f64 * 0.07;
            let da = sb.feed(&mut a, id, 1, v);
            let t = sb.trials[&id].clone();
            let r = super::super::testutil::row(1, sb.metric_id, v);
            let db = b.on_result(&sb.ctx(), &t, &r);
            assert_eq!(da, db, "diverged at trial {id}");
        }
    }

    /// Base snapshot + incremental delta folds to the same state a full
    /// snapshot of the final moment would produce.
    #[test]
    fn delta_fold_equals_full_snapshot() {
        let mut sb = Sandbox::new(16, "acc", Mode::Max);
        let mut a = AshaScheduler::new(1, 3.0, 81);
        for id in 0..5u64 {
            sb.feed(&mut a, id, 1, 0.9 - id as f64 * 0.05);
        }
        let base = TrialScheduler::snapshot(&a);
        a.reset_delta_cursor();
        for id in 5..10u64 {
            sb.feed(&mut a, id, 1, 0.7 - id as f64 * 0.03);
        }
        let delta = a.snapshot_delta();
        // The delta only carries the 5 new values, not the 10 totals.
        let appended = delta.get("rungs_append.1").unwrap().as_arr().unwrap();
        assert_eq!(appended.len(), 5);
        // Fold base + delta into a fresh instance (both through text).
        let mut b = AshaScheduler::new(1, 3.0, 81);
        TrialScheduler::restore(
            &mut b,
            &crate::util::json::parse(&base.to_string()).unwrap(),
        )
        .unwrap();
        b.apply_delta(&crate::util::json::parse(&delta.to_string()).unwrap()).unwrap();
        assert_eq!(b.num_stopped(), a.num_stopped());
        assert_eq!(
            TrialScheduler::snapshot(&b).to_string(),
            TrialScheduler::snapshot(&a).to_string()
        );
        // And a drained cursor yields an empty follow-up delta.
        let empty = a.snapshot_delta();
        assert_eq!(empty.get("rungs_append").unwrap().as_obj().unwrap().len(), 0);
    }

    #[test]
    fn no_rungs_at_or_past_max_t() {
        let s = AshaScheduler::new(1, 2.0, 8);
        assert_eq!(s.milestone(8), None); // max_t itself is not a rung
        assert_eq!(s.milestone(4), Some(4));
    }
}
