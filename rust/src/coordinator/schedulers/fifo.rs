//! FIFO — the paper's "trivial scheduler" (Table 1: 10 LoC). Runs each
//! trial to its stopping condition, launching pending trials in arrival
//! order whenever resources free up. Baseline for every comparison.

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};

/// The trivial scheduler: always continue, launch in arrival order.
#[derive(Default)]
pub struct FifoScheduler;

impl FifoScheduler {
    /// New FIFO scheduler (stateless).
    pub fn new() -> Self {
        FifoScheduler
    }
}

impl TrialScheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn on_result(&mut self, _ctx: &SchedulerCtx, _trial: &Trial, _r: &ResultRow) -> Decision {
        Decision::Continue
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::trial::Mode;

    #[test]
    fn always_continues_and_picks_in_order() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = FifoScheduler::new();
        assert_eq!(s.choose_trial_to_run(&sb.ctx()), Some(0));
        for i in 1..=5 {
            assert_eq!(sb.feed(&mut s, 0, i, 0.1), Decision::Continue);
        }
    }
}
