//! Median Stopping Rule (Golovin et al. 2017, as in Table 1: 68 LoC).
//!
//! Stop a trial at iteration t if its best running-average metric is
//! strictly worse than the median of the running averages of all other
//! trials *at the same iteration*, once past a grace period and with
//! enough peers to make the median meaningful.

use std::collections::BTreeMap;

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};
use crate::coordinator::persist::{f64s_from_json, f64s_to_json, id_map_from_json, id_map_to_json};
use crate::coordinator::trial::TrialId;
use crate::util::json::Json;

/// Stop trials whose running average falls below the peer median.
pub struct MedianStoppingRule {
    /// Never stop before this many iterations.
    pub grace_period: u64,
    /// Minimum number of peer trials with history at iteration t.
    pub min_samples_required: usize,
    /// Running mean of the (ascending-normalized) metric per trial,
    /// indexed by iteration: histories[trial][t-1] = mean over 1..=t.
    histories: BTreeMap<TrialId, Vec<f64>>,
    stopped: u64,
}

impl MedianStoppingRule {
    /// New rule with the given grace period and peer quorum.
    pub fn new(grace_period: u64, min_samples_required: usize) -> Self {
        MedianStoppingRule {
            grace_period,
            min_samples_required,
            histories: BTreeMap::new(),
            stopped: 0,
        }
    }

    /// Trials stopped by the rule so far.
    pub fn num_stopped(&self) -> u64 {
        self.stopped
    }

    fn running_mean_at(history: &[f64], t: u64) -> Option<f64> {
        if history.is_empty() || t == 0 {
            return None;
        }
        let upto = (t as usize).min(history.len());
        Some(history[upto - 1])
    }
}

impl TrialScheduler for MedianStoppingRule {
    fn name(&self) -> &'static str {
        "median_stopping"
    }

    fn on_result(&mut self, ctx: &SchedulerCtx, trial: &Trial, result: &ResultRow) -> Decision {
        let Some(value) = result.metric(ctx.metric).map(|v| ctx.mode.ascending(v)) else {
            return Decision::Continue;
        };
        // Update this trial's running mean history.
        let h = self.histories.entry(trial.id).or_default();
        let n = h.len() as f64;
        let prev = h.last().copied().unwrap_or(0.0);
        h.push((prev * n + value) / (n + 1.0));
        let t = h.len() as u64;

        if t < self.grace_period {
            return Decision::Continue;
        }
        // Median of peers' running means at iteration t.
        let mut peers: Vec<f64> = self
            .histories
            .iter()
            .filter(|(id, _)| **id != trial.id)
            .filter_map(|(_, ph)| Self::running_mean_at(ph, t))
            .collect();
        if peers.len() < self.min_samples_required {
            return Decision::Continue;
        }
        // O(n) selection instead of an O(n log n) sort — this callback
        // runs once per intermediate result (perf iteration 2, §Perf).
        // NaN-proof: a peer whose running mean diverged ranks smallest.
        let mid = peers.len() / 2;
        let (_, median, _) =
            peers.select_nth_unstable_by(mid, |a, b| crate::util::order::asc(*a, *b));
        let median = *median;
        let own = Self::running_mean_at(&self.histories[&trial.id], t).unwrap();
        // Total order, not `<`: once a trial's own running mean is NaN
        // (one NaN result poisons the mean for good) it must stop.
        if crate::util::order::asc(own, median) == std::cmp::Ordering::Less {
            self.stopped += 1;
            Decision::Stop
        } else {
            Decision::Continue
        }
    }

    fn on_trial_remove(&mut self, _ctx: &SchedulerCtx, id: TrialId) {
        // Keep history (peers still compare against it) but cap memory:
        // the rule only ever reads running means, which are already
        // incremental — nothing to drop. Hook kept for symmetry.
        let _ = id;
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("histories", id_map_to_json(&self.histories, |vs| f64s_to_json(vs))),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.histories = snap
            .get("histories")
            .and_then(|h| id_map_from_json(h, f64s_from_json))
            .ok_or("median snapshot: bad histories")?;
        self.stopped = snap.get("stopped").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::trial::Mode;

    #[test]
    fn stops_below_median_after_grace() {
        let mut sb = Sandbox::new(5, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(3, 2);
        // Trials 1..4 are good (acc 0.8), trial 0 is bad (acc 0.1).
        let mut stopped_at = None;
        for iter in 1..=10 {
            for id in 1..5u64 {
                assert_eq!(sb.feed(&mut s, id, iter, 0.8), Decision::Continue);
            }
            if sb.feed(&mut s, 0, iter, 0.1) == Decision::Stop {
                stopped_at = Some(iter);
                break;
            }
        }
        assert_eq!(stopped_at, Some(3)); // first iteration past grace
        assert_eq!(s.num_stopped(), 1);
    }

    #[test]
    fn grace_period_protects_slow_starters() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(5, 1);
        for iter in 1..5 {
            for id in 1..3u64 {
                sb.feed(&mut s, id, iter, 0.9);
            }
            assert_eq!(sb.feed(&mut s, 0, iter, 0.0), Decision::Continue, "iter {iter}");
        }
    }

    #[test]
    fn needs_min_samples() {
        let mut sb = Sandbox::new(2, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(1, 5);
        for iter in 1..10 {
            sb.feed(&mut s, 1, iter, 0.9);
            assert_eq!(sb.feed(&mut s, 0, iter, 0.0), Decision::Continue);
        }
    }

    #[test]
    fn min_mode_stops_high_loss() {
        let mut sb = Sandbox::new(4, "loss", Mode::Min);
        let mut s = MedianStoppingRule::new(2, 2);
        let mut stopped = false;
        for iter in 1..=5 {
            for id in 1..4u64 {
                sb.feed(&mut s, id, iter, 0.1);
            }
            if sb.feed(&mut s, 0, iter, 5.0) == Decision::Stop {
                stopped = true;
                break;
            }
        }
        assert!(stopped);
    }

    #[test]
    fn snapshot_restore_preserves_running_means() {
        let mut sb = Sandbox::new(5, "acc", Mode::Max);
        let mut a = MedianStoppingRule::new(3, 2);
        for iter in 1..=2 {
            for id in 0..5u64 {
                sb.feed(&mut a, id, iter, if id == 0 { 0.1 } else { 0.8 });
            }
        }
        let text = TrialScheduler::snapshot(&a).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut b = MedianStoppingRule::new(3, 2);
        TrialScheduler::restore(&mut b, &parsed).unwrap();
        // Iteration 3 is past grace: the restored instance must stop the
        // bad trial exactly like the original would.
        for id in 1..5u64 {
            sb.feed(&mut b, id, 3, 0.8);
        }
        assert_eq!(sb.feed(&mut b, 0, 3, 0.1), Decision::Stop);
        assert_eq!(b.num_stopped(), 1);
    }

    #[test]
    fn median_trial_survives() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(1, 2);
        for iter in 1..=20 {
            sb.feed(&mut s, 2, iter, 0.9);
            sb.feed(&mut s, 1, iter, 0.5);
            // Exactly at median (peers 0.9, 0.5 -> median 0.9? no: sorted
            // [0.5, 0.9], len 2, idx 1 -> 0.9). 0.7 < 0.9 stops; use >=.
            if sb.feed(&mut s, 0, iter, 0.95) == Decision::Stop {
                panic!("top trial must never stop");
            }
        }
    }
}
