//! Median Stopping Rule (Golovin et al. 2017, as in Table 1: 68 LoC).
//!
//! Stop a trial at iteration t if its best running-average metric is
//! strictly worse than the median of the running averages of all other
//! trials *at the same iteration*, once past a grace period and with
//! enough peers to make the median meaningful.
//!
//! Perf: instead of re-collecting every peer history and running an
//! O(n) selection per result, the rule keeps a dual-heap running median
//! per iteration index. Each trial's running mean at iteration t is
//! inserted into the structure for t exactly once (when the trial
//! reports its t-th result), so when a later trial reaches t the peer
//! median is an O(1) peek — and the decision path is O(log n) per
//! result, independent of how many peers exist.
//!
//! Semantics note: the peer set at iteration t is now exactly "other
//! trials that have reached iteration t", matching this header's
//! definition. The previous re-collecting implementation additionally
//! *clamped* shorter histories — a peer stuck (or stopped) at iteration
//! s < t contributed its mean-at-s to queries at t. The at-iteration
//! form compares like against like (no iteration-3 mean judging an
//! iteration-50 trial) and is what makes the median an O(1) peek; the
//! observable difference is confined to the few frontier trials that
//! temporarily lack `min_samples_required` peers at their iteration
//! (they continue instead of being judged against laggards) and to
//! long-dead trials no longer dragging every later median.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};
use crate::coordinator::persist::{f64s_from_json, f64s_to_json, id_map_from_json, id_map_to_json};
use crate::coordinator::trial::TrialId;
use crate::util::json::Json;
use crate::util::order::OrdF64;

/// Incremental upper median (the element at index n/2 of the ascending
/// sort — exactly what the old `select_nth_unstable` read): a max-heap
/// over the lower half and a min-heap over the upper half, rebalanced so
/// `|hi| ∈ {|lo|, |lo|+1}`; the median is `hi`'s minimum. Insert is
/// O(log n), read is O(1), and the NaN-proof total order makes diverged
/// running means rank smallest instead of corrupting the heaps.
#[derive(Default)]
struct RunningMedian {
    lo: BinaryHeap<OrdF64>,
    hi: BinaryHeap<Reverse<OrdF64>>,
}

impl RunningMedian {
    fn len(&self) -> usize {
        self.lo.len() + self.hi.len()
    }

    fn insert(&mut self, v: f64) {
        let v = OrdF64(v);
        let below_upper_half = matches!(self.hi.peek(), Some(&Reverse(h)) if v < h);
        if below_upper_half {
            self.lo.push(v);
        } else {
            self.hi.push(Reverse(v));
        }
        while self.hi.len() > self.lo.len() + 1 {
            let Reverse(x) = self.hi.pop().unwrap();
            self.lo.push(x);
        }
        while self.lo.len() > self.hi.len() {
            let x = self.lo.pop().unwrap();
            self.hi.push(Reverse(x));
        }
    }

    /// The upper median (index n/2 of the ascending sort), if non-empty.
    fn median(&self) -> Option<f64> {
        self.hi.peek().map(|r| r.0 .0)
    }
}

/// Stop trials whose running average falls below the peer median.
pub struct MedianStoppingRule {
    /// Never stop before this many iterations.
    pub grace_period: u64,
    /// Minimum number of peer trials with history at iteration t.
    pub min_samples_required: usize,
    /// Running mean of the (ascending-normalized) metric per trial,
    /// indexed by iteration: histories[trial][t-1] = mean over 1..=t.
    /// Retained verbatim — it is the (unchanged) snapshot format and
    /// the source the delta cursor slices from.
    histories: BTreeMap<TrialId, Vec<f64>>,
    /// Per-trial count of history entries the last persisted snapshot
    /// already contains (the delta cursor). Invariant: `flushed[id] ==
    /// histories[id].len()` for every id NOT in `dirty`.
    flushed: BTreeMap<TrialId, usize>,
    /// Trials whose history grew since the cursor was last drained, so
    /// a periodic delta scans O(changed) trials, not the population.
    dirty: BTreeSet<TrialId>,
    /// Per-iteration running median over every trial's mean at that
    /// iteration (each trial contributes to iteration t exactly once).
    medians: BTreeMap<u64, RunningMedian>,
    stopped: u64,
}

impl MedianStoppingRule {
    /// New rule with the given grace period and peer quorum.
    pub fn new(grace_period: u64, min_samples_required: usize) -> Self {
        MedianStoppingRule {
            grace_period,
            min_samples_required,
            histories: BTreeMap::new(),
            flushed: BTreeMap::new(),
            dirty: BTreeSet::new(),
            medians: BTreeMap::new(),
            stopped: 0,
        }
    }

    /// Trials stopped by the rule so far.
    pub fn num_stopped(&self) -> u64 {
        self.stopped
    }

    /// Append one running mean to a trial's history and mirror it into
    /// the per-iteration median structure. Shared by the hot path and
    /// the restore/fold paths so all three stay in exact agreement.
    fn push_mean(
        histories: &mut BTreeMap<TrialId, Vec<f64>>,
        medians: &mut BTreeMap<u64, RunningMedian>,
        dirty: &mut BTreeSet<TrialId>,
        id: TrialId,
        mean: f64,
    ) -> u64 {
        let h = histories.entry(id).or_default();
        h.push(mean);
        let t = h.len() as u64;
        medians.entry(t).or_default().insert(mean);
        dirty.insert(id);
        t
    }
}

impl TrialScheduler for MedianStoppingRule {
    fn name(&self) -> &'static str {
        "median_stopping"
    }

    fn on_result(&mut self, ctx: &SchedulerCtx, trial: &Trial, result: &ResultRow) -> Decision {
        let Some(value) = result.get(ctx.metric_id).map(|v| ctx.mode.ascending(v)) else {
            return Decision::Continue;
        };
        // This trial's updated running mean (incremental, O(1)).
        let h = self.histories.entry(trial.id).or_default();
        let n = h.len() as f64;
        let prev = h.last().copied().unwrap_or(0.0);
        let own = (prev * n + value) / (n + 1.0);
        let t = h.len() as u64 + 1;

        // Query the peer median BEFORE inserting our own mean: the
        // structure for iteration t then holds exactly the running
        // means of the OTHER trials that already reached t (see the
        // module docs for how this at-iteration peer set relates to the
        // old clamped re-collection).
        let peers = self.medians.get(&t);
        let decision = if t < self.grace_period {
            Decision::Continue
        } else {
            match peers {
                Some(m) if m.len() >= self.min_samples_required => {
                    let median = m.median().expect("non-empty median structure");
                    // Total order, not `<`: once a trial's own running
                    // mean is NaN (one NaN result poisons the mean for
                    // good) it must stop.
                    if crate::util::order::asc(own, median) == std::cmp::Ordering::Less {
                        self.stopped += 1;
                        Decision::Stop
                    } else {
                        Decision::Continue
                    }
                }
                _ => Decision::Continue,
            }
        };
        // Record our mean either way — future peers at iteration t
        // compare against it, stopped trials included (history is kept,
        // exactly like the re-collecting implementation kept it).
        Self::push_mean(&mut self.histories, &mut self.medians, &mut self.dirty, trial.id, own);
        decision
    }

    fn on_trial_remove(&mut self, _ctx: &SchedulerCtx, id: TrialId) {
        // Keep history (peers still compare against it) but cap memory:
        // the rule only ever reads running means, which are already
        // incremental — nothing to drop. Hook kept for symmetry.
        let _ = id;
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("histories", id_map_to_json(&self.histories, |vs| f64s_to_json(vs))),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        let histories = snap
            .get("histories")
            .and_then(|h| id_map_from_json(h, f64s_from_json))
            .ok_or("median snapshot: bad histories")?;
        self.histories = BTreeMap::new();
        self.flushed = BTreeMap::new();
        self.dirty = BTreeSet::new();
        self.medians = BTreeMap::new();
        for (id, h) in histories {
            for mean in &h {
                Self::push_mean(&mut self.histories, &mut self.medians, &mut self.dirty, id, *mean);
            }
            self.flushed.insert(id, h.len());
        }
        self.dirty.clear(); // restored state IS the durable state
        self.stopped = snap.get("stopped").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }

    fn snapshot_delta(&mut self) -> Json {
        // O(changed): only trials in the dirty set can have grown.
        let append: BTreeMap<TrialId, Vec<f64>> = self
            .dirty
            .iter()
            .filter_map(|id| {
                let h = self.histories.get(id)?;
                let from = self.flushed.get(id).copied().unwrap_or(0);
                (from < h.len()).then(|| (*id, h[from..].to_vec()))
            })
            .collect();
        for id in std::mem::take(&mut self.dirty) {
            if let Some(h) = self.histories.get(&id) {
                self.flushed.insert(id, h.len());
            }
        }
        Json::obj(vec![
            ("histories_append", id_map_to_json(&append, |vs| f64s_to_json(vs))),
            ("stopped", Json::Num(self.stopped as f64)),
        ])
    }

    fn apply_delta(&mut self, delta: &Json) -> Result<(), String> {
        let append = delta
            .get("histories_append")
            .and_then(|h| id_map_from_json(h, f64s_from_json))
            .ok_or("median delta: bad histories_append")?;
        for (id, means) in append {
            for mean in means {
                Self::push_mean(&mut self.histories, &mut self.medians, &mut self.dirty, id, mean);
            }
            self.flushed.insert(id, self.histories[&id].len());
            self.dirty.remove(&id); // folded state IS the durable state
        }
        self.stopped = delta.get("stopped").and_then(|v| v.as_u64()).unwrap_or(self.stopped);
        Ok(())
    }

    fn reset_delta_cursor(&mut self) {
        // O(changed), same as snapshot_delta: clean trials already
        // satisfy flushed == len by invariant.
        for id in std::mem::take(&mut self.dirty) {
            if let Some(h) = self.histories.get(&id) {
                self.flushed.insert(id, h.len());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::trial::Mode;

    #[test]
    fn stops_below_median_after_grace() {
        let mut sb = Sandbox::new(5, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(3, 2);
        // Trials 1..4 are good (acc 0.8), trial 0 is bad (acc 0.1).
        let mut stopped_at = None;
        for iter in 1..=10 {
            for id in 1..5u64 {
                assert_eq!(sb.feed(&mut s, id, iter, 0.8), Decision::Continue);
            }
            if sb.feed(&mut s, 0, iter, 0.1) == Decision::Stop {
                stopped_at = Some(iter);
                break;
            }
        }
        assert_eq!(stopped_at, Some(3)); // first iteration past grace
        assert_eq!(s.num_stopped(), 1);
    }

    /// The incremental per-iteration median structure must agree with a
    /// brute-force re-collection of the SAME at-iteration peer set
    /// (other trials with history length >= t) at every decision point
    /// — this pins the dual-heap machinery, not the (intentionally
    /// refined, see module docs) peer-set semantics.
    #[test]
    fn incremental_median_matches_recollection_reference() {
        let n_trials = 7u64;
        let mut s = MedianStoppingRule::new(1, 1);
        // Reference state: full histories, recomputed per query.
        let mut ref_hist: BTreeMap<TrialId, Vec<f64>> = BTreeMap::new();
        let mut x = 0.2_f64;
        for iter in 0..40u64 {
            for id in 0..n_trials {
                x = (x * 131.0 + id as f64 + iter as f64 * 0.31).sin();
                let value = if (iter + id) % 13 == 7 { f64::NAN } else { x };
                // Reference running-mean update.
                let h = ref_hist.entry(id).or_default();
                let n = h.len() as f64;
                let prev = h.last().copied().unwrap_or(0.0);
                h.push((prev * n + value) / (n + 1.0));
                let t = h.len() as u64;
                // Brute-force reference over the same at-iteration peer
                // set: all OTHER trials whose history reaches t.
                let mut peers: Vec<f64> = Vec::new();
                for (pid, ph) in &ref_hist {
                    if *pid != id && ph.len() >= t as usize {
                        peers.push(ph[t as usize - 1]);
                    }
                }
                let reference = if peers.is_empty() {
                    None
                } else {
                    let mid = peers.len() / 2;
                    let (_, m, _) = peers
                        .select_nth_unstable_by(mid, |a, b| crate::util::order::asc(*a, *b));
                    Some(*m)
                };
                // Incremental: query before inserting (what on_result
                // does), then insert.
                let incremental = s.medians.get(&t).and_then(|m| m.median());
                let own = *ref_hist[&id].last().unwrap();
                MedianStoppingRule::push_mean(
                    &mut s.histories,
                    &mut s.medians,
                    &mut s.dirty,
                    id,
                    own,
                );
                match (incremental, reference) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(
                        crate::util::order::asc(a, b),
                        std::cmp::Ordering::Equal,
                        "iter {iter} trial {id}: {a} vs {b}"
                    ),
                    other => panic!("iter {iter} trial {id}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn grace_period_protects_slow_starters() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(5, 1);
        for iter in 1..5 {
            for id in 1..3u64 {
                sb.feed(&mut s, id, iter, 0.9);
            }
            assert_eq!(sb.feed(&mut s, 0, iter, 0.0), Decision::Continue, "iter {iter}");
        }
    }

    #[test]
    fn needs_min_samples() {
        let mut sb = Sandbox::new(2, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(1, 5);
        for iter in 1..10 {
            sb.feed(&mut s, 1, iter, 0.9);
            assert_eq!(sb.feed(&mut s, 0, iter, 0.0), Decision::Continue);
        }
    }

    #[test]
    fn min_mode_stops_high_loss() {
        let mut sb = Sandbox::new(4, "loss", Mode::Min);
        let mut s = MedianStoppingRule::new(2, 2);
        let mut stopped = false;
        for iter in 1..=5 {
            for id in 1..4u64 {
                sb.feed(&mut s, id, iter, 0.1);
            }
            if sb.feed(&mut s, 0, iter, 5.0) == Decision::Stop {
                stopped = true;
                break;
            }
        }
        assert!(stopped);
    }

    #[test]
    fn snapshot_restore_preserves_running_means() {
        let mut sb = Sandbox::new(5, "acc", Mode::Max);
        let mut a = MedianStoppingRule::new(3, 2);
        for iter in 1..=2 {
            for id in 0..5u64 {
                sb.feed(&mut a, id, iter, if id == 0 { 0.1 } else { 0.8 });
            }
        }
        let text = TrialScheduler::snapshot(&a).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut b = MedianStoppingRule::new(3, 2);
        TrialScheduler::restore(&mut b, &parsed).unwrap();
        // Iteration 3 is past grace: the restored instance must stop the
        // bad trial exactly like the original would.
        for id in 1..5u64 {
            sb.feed(&mut b, id, 3, 0.8);
        }
        assert_eq!(sb.feed(&mut b, 0, 3, 0.1), Decision::Stop);
        assert_eq!(b.num_stopped(), 1);
    }

    /// Base + delta fold equals a full snapshot of the final state.
    #[test]
    fn delta_fold_equals_full_snapshot() {
        let mut sb = Sandbox::new(6, "acc", Mode::Max);
        let mut a = MedianStoppingRule::new(2, 2);
        for iter in 1..=2 {
            for id in 0..6u64 {
                sb.feed(&mut a, id, iter, 0.5 + id as f64 * 0.05);
            }
        }
        let base = TrialScheduler::snapshot(&a);
        a.reset_delta_cursor();
        for id in 0..6u64 {
            sb.feed(&mut a, id, 3, 0.6 + id as f64 * 0.01);
        }
        let delta = a.snapshot_delta();
        // One appended mean per trial, not the whole history.
        let appended = delta.get("histories_append.0").unwrap().as_arr().unwrap();
        assert_eq!(appended.len(), 1);
        let mut b = MedianStoppingRule::new(2, 2);
        TrialScheduler::restore(
            &mut b,
            &crate::util::json::parse(&base.to_string()).unwrap(),
        )
        .unwrap();
        b.apply_delta(&crate::util::json::parse(&delta.to_string()).unwrap()).unwrap();
        assert_eq!(
            TrialScheduler::snapshot(&b).to_string(),
            TrialScheduler::snapshot(&a).to_string()
        );
        assert_eq!(b.num_stopped(), a.num_stopped());
    }

    #[test]
    fn median_trial_survives() {
        let mut sb = Sandbox::new(3, "acc", Mode::Max);
        let mut s = MedianStoppingRule::new(1, 2);
        for iter in 1..=20 {
            sb.feed(&mut s, 2, iter, 0.9);
            sb.feed(&mut s, 1, iter, 0.5);
            // Exactly at median (peers 0.9, 0.5 -> median 0.9? no: sorted
            // [0.5, 0.9], len 2, idx 1 -> 0.9). 0.7 < 0.9 stops; use >=.
            if sb.feed(&mut s, 0, iter, 0.95) == Decision::Stop {
                panic!("top trial must never stop");
            }
        }
    }
}
