//! Population-Based Training (Jaderberg et al. 2017; Table 1: 169 LoC).
//!
//! The scheduler the paper's requirements are really about: it needs
//! *intermediate results* (to rank the population), *checkpoint/clone*
//! (exploit: bottom-quantile trials copy the weights of top-quantile
//! trials) and *runtime hyperparameter mutation* (explore: the cloned
//! config is perturbed) — all mid-training, all expressible with the
//! narrow scheduler API.

use std::collections::BTreeMap;

use super::{Decision, ResultRow, SchedulerCtx, Trial, TrialScheduler};
use crate::coordinator::persist::{id_map_from_json, id_map_to_json, u64_from_json, u64_to_json};
use crate::coordinator::spec::{ParamDist, SearchSpace};
use crate::coordinator::trial::{Config, ParamValue, TrialId, TrialStatus};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Population-Based Training: bottom-quantile trials clone top-quantile
/// checkpoints (exploit) with perturbed configs (explore).
pub struct PbtScheduler {
    /// Exploit/explore every this many iterations.
    pub perturbation_interval: u64,
    /// Fraction of the population considered top/bottom.
    pub quantile: f64,
    /// Probability a mutated hyperparameter is resampled from its
    /// distribution instead of perturbed.
    pub resample_prob: f64,
    /// Multiplicative perturbation factors for continuous params.
    pub perturb_factors: (f64, f64),
    /// Distributions used for resampling (the mutable subspace).
    space: SearchSpace,
    /// Last interval at which each trial was considered (dedup guard).
    last_perturb: BTreeMap<TrialId, u64>,
    rng: Rng,
    /// Exploit decisions issued so far.
    pub exploits: u64,
}

impl PbtScheduler {
    /// New PBT scheduler mutating within `space`, seeded for replay.
    pub fn new(perturbation_interval: u64, space: SearchSpace, seed: u64) -> Self {
        assert!(perturbation_interval >= 1);
        PbtScheduler {
            perturbation_interval,
            quantile: 0.25,
            resample_prob: 0.25,
            perturb_factors: (0.8, 1.2),
            space,
            last_perturb: BTreeMap::new(),
            rng: Rng::new(seed),
            exploits: 0,
        }
    }

    /// Explore: perturb the exploited config (Jaderberg et al., §3.2).
    fn explore(&mut self, source: &Config) -> Config {
        let mut out = source.clone();
        for (key, dist) in self.space.clone() {
            let resample = self.rng.bool(self.resample_prob);
            let cur = out.get(&key).cloned();
            let newv = match (&dist, cur, resample) {
                (_, None, _) | (_, _, true) => dist.sample(&mut self.rng),
                (ParamDist::Const(v), _, false) => v.clone(),
                (ParamDist::Choice(_), Some(v), false)
                | (ParamDist::GridSearch(_), Some(v), false) => v.clone(),
                (_, Some(v), false) => match v.as_f64() {
                    Some(x) => {
                        let f = if self.rng.bool(0.5) {
                            self.perturb_factors.0
                        } else {
                            self.perturb_factors.1
                        };
                        clamp_to(&dist, x * f)
                    }
                    None => v.clone(),
                },
            };
            out.insert(key, newv);
        }
        out
    }

    /// Rank the live population by last reported score (best first).
    fn ranking(&self, ctx: &SchedulerCtx) -> Vec<(TrialId, f64)> {
        let mut ranked: Vec<(TrialId, f64)> = ctx
            .trials
            .values()
            .filter(|t| {
                matches!(
                    t.status,
                    TrialStatus::Running | TrialStatus::Paused | TrialStatus::Pending
                )
            })
            .filter_map(|t| ctx.score(t).map(|s| (t.id, s)))
            .collect();
        // NaN-proof best-first order: diverged trials rank bottom, so
        // they become exploiters (cloning a healthy top performer) —
        // exactly PBT's recovery story — instead of panicking the sort.
        ranked.sort_by(|a, b| crate::util::order::desc(a.1, b.1));
        ranked
    }
}

fn clamp_to(dist: &ParamDist, x: f64) -> ParamValue {
    match dist {
        ParamDist::Uniform(lo, hi) | ParamDist::LogUniform(lo, hi) => {
            ParamValue::F64(x.clamp(*lo, *hi))
        }
        ParamDist::QUniform(lo, hi, q) => {
            ParamValue::F64(((x / q).round() * q).clamp(*lo, *hi))
        }
        ParamDist::RandInt(lo, hi) => ParamValue::I64((x.round() as i64).clamp(*lo, *hi - 1)),
        _ => ParamValue::F64(x),
    }
}

impl TrialScheduler for PbtScheduler {
    fn name(&self) -> &'static str {
        "pbt"
    }

    fn on_result(&mut self, ctx: &SchedulerCtx, trial: &Trial, result: &ResultRow) -> Decision {
        let interval = result.iteration / self.perturbation_interval;
        if result.iteration % self.perturbation_interval != 0 || interval == 0 {
            return Decision::Continue;
        }
        if self.last_perturb.get(&trial.id).copied() == Some(interval) {
            return Decision::Continue;
        }
        self.last_perturb.insert(trial.id, interval);

        let ranked = self.ranking(ctx);
        if ranked.len() < 4 {
            // Population too small for meaningful quantiles: checkpoint
            // so future exploits have donors.
            return Decision::Checkpoint;
        }
        let k = ((ranked.len() as f64 * self.quantile).ceil() as usize).max(1);
        let top: Vec<TrialId> = ranked[..k].iter().map(|(id, _)| *id).collect();
        let bottom: Vec<TrialId> = ranked[ranked.len() - k..].iter().map(|(id, _)| *id).collect();

        if bottom.contains(&trial.id) && !top.contains(&trial.id) {
            // Exploit: clone a random top performer (that has a
            // checkpoint — the runner validates and falls back).
            let source = *self.rng.choose(&top);
            let source_config = &ctx.trials[&source].config;
            let config = self.explore(source_config);
            self.exploits += 1;
            Decision::Exploit { source, config }
        } else if top.contains(&trial.id) {
            // Top performers snapshot so exploiters can clone them.
            Decision::Checkpoint
        } else {
            Decision::Continue
        }
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            (
                "last_perturb",
                id_map_to_json(&self.last_perturb, |v| Json::Num(*v as f64)),
            ),
            ("rng", u64_to_json(self.rng.state())),
            ("exploits", Json::Num(self.exploits as f64)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.last_perturb = snap
            .get("last_perturb")
            .and_then(|m| id_map_from_json(m, |v| v.as_u64()))
            .ok_or("pbt snapshot: bad last_perturb")?;
        let state = snap
            .get("rng")
            .and_then(u64_from_json)
            .ok_or("pbt snapshot: bad rng state")?;
        self.rng.set_state(state);
        self.exploits = snap.get("exploits").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Sandbox;
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;
    use crate::coordinator::trial::Mode;

    fn space() -> SearchSpace {
        SpaceBuilder::new().loguniform("lr", 1e-5, 1.0).build()
    }

    fn feed_population(sb: &mut Sandbox, s: &mut PbtScheduler, iter: u64) -> Vec<Decision> {
        // Trial id i reports score proportional to i: 0 is worst.
        (0..8u64)
            .map(|id| sb.feed(s, id, iter, id as f64))
            .collect()
    }

    #[test]
    fn no_action_between_intervals() {
        let mut sb = Sandbox::new(8, "score", Mode::Max);
        let mut s = PbtScheduler::new(5, space(), 1);
        for d in feed_population(&mut sb, &mut s, 3) {
            assert_eq!(d, Decision::Continue);
        }
    }

    #[test]
    fn bottom_exploits_top_at_interval() {
        let mut sb = Sandbox::new(8, "score", Mode::Max);
        let mut s = PbtScheduler::new(5, space(), 1);
        feed_population(&mut sb, &mut s, 4);
        let ds = feed_population(&mut sb, &mut s, 5);
        // Worst trials (ids 0,1) must exploit; best (6,7) checkpoint.
        assert!(matches!(ds[0], Decision::Exploit { .. }), "{ds:?}");
        assert!(matches!(ds[1], Decision::Exploit { .. }), "{ds:?}");
        assert_eq!(ds[6], Decision::Checkpoint);
        assert_eq!(ds[7], Decision::Checkpoint);
        assert_eq!(ds[3], Decision::Continue);
        // Exploit source must be a top-quantile trial.
        if let Decision::Exploit { source, .. } = ds[0] {
            assert!(source >= 6, "source={source}");
        }
        assert_eq!(s.exploits, 2);
    }

    #[test]
    fn exploit_config_stays_in_support() {
        let mut sb = Sandbox::new(8, "score", Mode::Max);
        let mut s = PbtScheduler::new(1, space(), 2);
        for iter in 1..=20 {
            for d in feed_population(&mut sb, &mut s, iter) {
                if let Decision::Exploit { config, .. } = d {
                    let lr = config["lr"].as_f64().unwrap();
                    assert!((1e-5..=1.0).contains(&lr), "lr={lr}");
                }
            }
        }
    }

    #[test]
    fn dedup_guard_fires_once_per_interval() {
        let mut sb = Sandbox::new(8, "score", Mode::Max);
        let mut s = PbtScheduler::new(5, space(), 3);
        // Same iteration fed twice (e.g. duplicated report): second is a
        // plain Continue.
        feed_population(&mut sb, &mut s, 5);
        let d = sb.feed(&mut s, 0, 5, 0.0);
        assert_eq!(d, Decision::Continue);
    }

    #[test]
    fn small_population_checkpoints_instead() {
        let mut sb = Sandbox::new(2, "score", Mode::Max);
        let mut s = PbtScheduler::new(1, space(), 4);
        sb.feed(&mut s, 1, 1, 1.0);
        let d = sb.feed(&mut s, 0, 1, 0.0);
        assert_eq!(d, Decision::Checkpoint);
    }

    #[test]
    fn snapshot_restore_replays_explore_stream() {
        let mut sb = Sandbox::new(8, "score", Mode::Max);
        let mut a = PbtScheduler::new(5, space(), 7);
        feed_population(&mut sb, &mut a, 4);
        feed_population(&mut sb, &mut a, 5); // consumes rng via exploits
        let text = TrialScheduler::snapshot(&a).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut b = PbtScheduler::new(5, space(), 999); // wrong seed on purpose
        TrialScheduler::restore(&mut b, &parsed).unwrap();
        assert_eq!(b.exploits, a.exploits);
        // Identical subsequent decisions, including rng-driven explore
        // output, despite the different construction seed.
        let mut sb_b = sb.clone();
        let da = feed_population(&mut sb, &mut a, 10);
        let db = feed_population(&mut sb_b, &mut b, 10);
        assert_eq!(da, db);
    }

    #[test]
    fn explore_perturbs_or_resamples() {
        let mut s = PbtScheduler::new(1, space(), 5);
        let mut src = Config::new();
        src.insert("lr".into(), ParamValue::F64(0.01));
        let mut changed = 0;
        for _ in 0..50 {
            let c = s.explore(&src);
            let lr = c["lr"].as_f64().unwrap();
            if (lr - 0.01).abs() > 1e-12 {
                changed += 1;
            }
            // perturbation is x0.8 / x1.2 / resample — never identity
            // unless resample landed exactly (measure-zero)
            assert!((1e-5..=1.0).contains(&lr));
        }
        assert!(changed >= 49, "{changed}");
    }
}
