//! The scheduling API (§4.2 of the paper) — the narrow waist between
//! the execution engine and hyperparameter-search research.
//!
//! The paper's interface is event-based:
//!
//! ```text
//! class TrialScheduler:
//!     def on_result(self, trial, result): ...
//!     def choose_trial_to_run(self): ...
//! ```
//!
//! `on_result` is invoked as intermediate results arrive and returns a
//! flag "indicating whether to continue, checkpoint, stop, or restart a
//! trial with an updated hyperparameter configuration" — our
//! [`Decision`]. `choose_trial_to_run` is called whenever the cluster
//! has free resources. This module hosts the trait plus the shared
//! context; the concrete algorithms of Table 1 live in the submodules:
//!
//! | module              | algorithm                         | paper LoC |
//! |---------------------|-----------------------------------|-----------|
//! | `fifo`              | FIFO (trivial scheduler)          | 10        |
//! | `asha`              | Asynchronous HyperBand            | 78        |
//! | `hyperband`         | HyperBand (original, synchronous) | 215       |
//! | `median_stopping`   | Median Stopping Rule              | 68        |
//! | `pbt`               | Population-Based Training         | 169       |
//!
//! (HyperOpt-style TPE is a *search algorithm*, `coordinator::search::tpe`.)

use std::collections::{BTreeMap, BTreeSet};

use super::trial::{Config, Mode, ResultRow, Trial, TrialId, TrialStatus};
use crate::ray::Utilization;
use crate::util::intern::MetricId;

pub mod asha;
pub mod fifo;
pub mod hyperband;
pub mod median_stopping;
pub mod pbt;

pub use asha::AshaScheduler;
pub use fifo::FifoScheduler;
pub use hyperband::HyperBandScheduler;
pub use median_stopping::MedianStoppingRule;
pub use pbt::PbtScheduler;

/// What the scheduler wants done with a trial after a result.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Keep training.
    Continue,
    /// Snapshot, then keep training.
    Checkpoint,
    /// Snapshot and deschedule; resumable later via
    /// `choose_trial_to_run` (HyperBand rung barrier).
    Pause,
    /// Terminate early.
    Stop,
    /// Restart from `source`'s latest checkpoint with a mutated config
    /// (PBT exploit+explore).
    Exploit { source: TrialId, config: Config },
}

/// Read-only view of experiment state passed to scheduler callbacks.
pub struct SchedulerCtx<'a> {
    /// The full trial table, by id.
    pub trials: &'a BTreeMap<TrialId, Trial>,
    /// Ids of Pending trials in ascending id (= creation) order — the
    /// runner's incrementally maintained FIFO queue. Always consistent
    /// with `trials`: a scheduler reading either view sees the same
    /// Pending set, but this one answers "who runs next" in O(1)
    /// instead of scanning the table.
    pub pending: &'a BTreeSet<TrialId>,
    /// Interned id of the metric being optimized (resolved once per
    /// experiment by the runner; per-result lookups are integer
    /// compares, not string hashing).
    pub metric_id: MetricId,
    /// Optimization direction.
    pub mode: Mode,
    /// Current cluster utilization snapshot (CPU/GPU leased fractions,
    /// alive/draining node counts) — refreshed by the runner on every
    /// lease change, so resource-aware schedulers can modulate their
    /// aggressiveness and `tune status` can report it.
    pub utilization: Utilization,
}

impl<'a> SchedulerCtx<'a> {
    /// Last reported metric of a trial, normalized so higher is better.
    pub fn score(&self, trial: &Trial) -> Option<f64> {
        trial
            .last_result
            .as_ref()
            .and_then(|r| r.get(self.metric_id))
            .map(|v| self.mode.ascending(v))
    }

    /// First Pending trial in id order (the FIFO policy) — an O(1)
    /// read of the maintained queue, not a table scan.
    pub fn first_pending(&self) -> Option<TrialId> {
        self.pending.iter().next().copied()
    }
}

/// The trial scheduler interface (§4.2).
pub trait TrialScheduler: Send {
    /// Stable label ("fifo", "asha", ...) for logs and tables.
    fn name(&self) -> &'static str;

    /// A new trial has been added to the experiment.
    fn on_trial_add(&mut self, _ctx: &SchedulerCtx, _trial: &Trial) {}

    /// An intermediate result arrived; decide the trial's fate.
    fn on_result(&mut self, ctx: &SchedulerCtx, trial: &Trial, result: &ResultRow) -> Decision;

    /// The trial reached a terminal state (completed/stopped/errored).
    fn on_trial_remove(&mut self, _ctx: &SchedulerCtx, _id: TrialId) {}

    /// Pick the next trial to launch (among Pending/Paused) now that
    /// resources are available. Default: FIFO over pending trials.
    fn choose_trial_to_run(&mut self, ctx: &SchedulerCtx) -> Option<TrialId> {
        ctx.first_pending()
    }

    /// Trials condemned outside an `on_result` return value (HyperBand
    /// rung cuts terminate *paused* peers). Runner drains after every
    /// event. Default: none.
    fn drain_stops(&mut self) -> Vec<TrialId> {
        Vec::new()
    }

    /// Serialize all mutable state for the experiment snapshot (see
    /// `coordinator::persist`). Stateless schedulers return `Null`.
    fn snapshot(&self) -> crate::util::json::Json {
        crate::util::json::Json::Null
    }

    /// Rebuild state from a [`TrialScheduler::snapshot`] value, so a
    /// resumed experiment continues with identical decisions. The
    /// receiver was freshly constructed with the same parameters.
    fn restore(&mut self, _snap: &crate::util::json::Json) -> Result<(), String> {
        Ok(())
    }

    /// Incremental snapshot for the delta-snapshot machinery (see
    /// `coordinator::persist`): the state appended/changed since the
    /// last [`TrialScheduler::snapshot_delta`] call or
    /// [`TrialScheduler::reset_delta_cursor`], in an
    /// implementation-private format consumed only by the same
    /// implementation's [`TrialScheduler::apply_delta`]. The default
    /// returns the full snapshot — always correct, O(state) — and
    /// append-mostly schedulers (ASHA rungs, median histories) override
    /// it so a periodic delta costs O(changed since last snapshot).
    fn snapshot_delta(&mut self) -> crate::util::json::Json {
        self.snapshot()
    }

    /// Fold a value produced by [`TrialScheduler::snapshot_delta`] into
    /// the current state. The default pairs with the default
    /// `snapshot_delta`: a full-state replace via
    /// [`TrialScheduler::restore`].
    fn apply_delta(&mut self, delta: &crate::util::json::Json) -> Result<(), String> {
        self.restore(delta)
    }

    /// A *full* snapshot was just persisted: the next
    /// [`TrialScheduler::snapshot_delta`] must be relative to it.
    /// Default: nothing tracked, nothing to reset.
    fn reset_delta_cursor(&mut self) {}
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::coordinator::trial::ParamValue;
    use crate::ray::Resources;

    /// Test metric id: sandboxes intern exactly one metric, so it is
    /// always id 0 regardless of the display name the test picks.
    pub const METRIC: MetricId = 0;

    pub fn mk_trial(id: TrialId, lr: f64) -> Trial {
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(lr));
        Trial::new(id, c, Resources::cpu(1.0), id)
    }

    pub fn row(iter: u64, metric: MetricId, v: f64) -> ResultRow {
        ResultRow::new(iter, iter as f64).with(metric, v)
    }

    /// Drive `n` trials through `scheduler`, feeding per-trial metric
    /// sequences; returns the decisions taken at each (trial, iter).
    #[derive(Clone)]
    pub struct Sandbox {
        pub trials: BTreeMap<TrialId, Trial>,
        pub pending: BTreeSet<TrialId>,
        pub metric_id: MetricId,
        pub mode: Mode,
    }

    impl Sandbox {
        pub fn new(n: u64, _metric: &str, mode: Mode) -> Self {
            let trials: BTreeMap<TrialId, Trial> =
                (0..n).map(|i| (i, mk_trial(i, 0.01 * (i + 1) as f64))).collect();
            let mut sb = Sandbox { trials, pending: BTreeSet::new(), metric_id: METRIC, mode };
            sb.refresh_pending();
            sb
        }

        /// Recompute the pending set from trial statuses (the sandbox
        /// takes the slow path; the runner maintains it incrementally).
        fn refresh_pending(&mut self) {
            self.pending = self
                .trials
                .values()
                .filter(|t| t.status == TrialStatus::Pending)
                .map(|t| t.id)
                .collect();
        }

        pub fn ctx(&self) -> SchedulerCtx<'_> {
            SchedulerCtx {
                trials: &self.trials,
                pending: &self.pending,
                metric_id: self.metric_id,
                mode: self.mode,
                utilization: Utilization::default(),
            }
        }

        pub fn add_all(&mut self, s: &mut dyn TrialScheduler) {
            let ids: Vec<TrialId> = self.trials.keys().copied().collect();
            for id in ids {
                let t = self.trials[&id].clone();
                let ctx = SchedulerCtx {
                    trials: &self.trials,
                    pending: &self.pending,
                    metric_id: self.metric_id,
                    mode: self.mode,
                    utilization: Utilization::default(),
                };
                s.on_trial_add(&ctx, &t);
            }
        }

        pub fn feed(
            &mut self,
            s: &mut dyn TrialScheduler,
            id: TrialId,
            iter: u64,
            value: f64,
        ) -> Decision {
            let r = row(iter, self.metric_id, value);
            {
                let t = self.trials.get_mut(&id).unwrap();
                t.status = TrialStatus::Running;
                t.record(r.clone(), self.metric_id, self.mode);
            }
            self.refresh_pending();
            let t = self.trials[&id].clone();
            let ctx = SchedulerCtx {
                trials: &self.trials,
                pending: &self.pending,
                metric_id: self.metric_id,
                mode: self.mode,
                utilization: Utilization::default(),
            };
            let d = s.on_result(&ctx, &t, &r);
            match &d {
                Decision::Stop => self.trials.get_mut(&id).unwrap().status = TrialStatus::Stopped,
                Decision::Pause => self.trials.get_mut(&id).unwrap().status = TrialStatus::Paused,
                _ => {}
            }
            self.refresh_pending();
            d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn ctx_score_normalizes_mode() {
        let mut sb = Sandbox::new(1, "loss", Mode::Min);
        let (metric, mode) = (sb.metric_id, sb.mode);
        sb.trials.get_mut(&0).unwrap().record(row(1, metric, 2.0), metric, mode);
        let ctx = sb.ctx();
        assert_eq!(ctx.score(&ctx.trials[&0]), Some(-2.0));
    }

    #[test]
    fn default_choose_is_first_pending() {
        let sb = Sandbox::new(3, "loss", Mode::Min);
        struct S;
        impl TrialScheduler for S {
            fn name(&self) -> &'static str {
                "s"
            }
            fn on_result(&mut self, _: &SchedulerCtx, _: &Trial, _: &ResultRow) -> Decision {
                Decision::Continue
            }
        }
        assert_eq!(S.choose_trial_to_run(&sb.ctx()), Some(0));
    }
}
