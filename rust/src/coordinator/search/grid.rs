//! Grid search over the DSL's `grid_search` dimensions (§4.3's
//! quickstart: a 3x2 grid over lr x activation), with stochastic
//! dimensions sampled per repetition. `num_samples` repeats the whole
//! grid, matching Tune's semantics.

use super::SearchAlgorithm;
use crate::coordinator::persist::{config_from_json, config_to_json};
use crate::coordinator::spec::{expand_grid, SearchSpace};
use crate::coordinator::trial::Config;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Exhaustive sweep over the grid cross-product, repeated `num_samples`
/// times with stochastic dims re-sampled per pass.
pub struct GridSearch {
    space: SearchSpace,
    num_samples: usize,
    emitted_in_pass: usize,
    pass: usize,
    current: Vec<Config>,
}

impl GridSearch {
    /// New grid search over `space` (`num_samples` grid repetitions).
    pub fn new(space: SearchSpace, num_samples: usize) -> Self {
        GridSearch {
            space,
            num_samples: num_samples.max(1),
            emitted_in_pass: 0,
            pass: 0,
            current: Vec::new(),
        }
    }
}

impl SearchAlgorithm for GridSearch {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn next_config(&mut self, rng: &mut Rng) -> Option<Config> {
        if self.pass >= self.num_samples {
            return None;
        }
        if self.emitted_in_pass == 0 {
            // Each pass re-samples stochastic dimensions.
            self.current = expand_grid(&self.space, rng);
        }
        let cfg = self.current.get(self.emitted_in_pass).cloned();
        self.emitted_in_pass += 1;
        if self.emitted_in_pass >= self.current.len() {
            self.emitted_in_pass = 0;
            self.pass += 1;
        }
        cfg
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("pass", Json::Num(self.pass as f64)),
            ("emitted_in_pass", Json::Num(self.emitted_in_pass as f64)),
            ("current", Json::Arr(self.current.iter().map(config_to_json).collect())),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.pass =
            snap.get("pass").and_then(|v| v.as_u64()).ok_or("grid snapshot: bad pass")? as usize;
        self.emitted_in_pass = snap
            .get("emitted_in_pass")
            .and_then(|v| v.as_u64())
            .ok_or("grid snapshot: bad cursor")? as usize;
        self.current = snap
            .get("current")
            .and_then(|c| c.as_arr())
            .ok_or("grid snapshot: bad current pass")?
            .iter()
            .map(config_from_json)
            .collect::<Option<_>>()
            .ok_or("grid snapshot: bad config")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;

    #[test]
    fn emits_full_grid_then_exhausts() {
        let sp = SpaceBuilder::new()
            .grid_f64("lr", &[0.01, 0.001, 0.0001])
            .grid_str("activation", &["relu", "tanh"])
            .build();
        let mut g = GridSearch::new(sp, 1);
        let mut rng = Rng::new(0);
        let mut n = 0;
        while g.next_config(&mut rng).is_some() {
            n += 1;
        }
        assert_eq!(n, 6);
        assert!(g.next_config(&mut rng).is_none());
    }

    #[test]
    fn num_samples_repeats_grid() {
        let sp = SpaceBuilder::new().grid_f64("lr", &[0.1, 0.2]).build();
        let mut g = GridSearch::new(sp, 3);
        let mut rng = Rng::new(0);
        let mut n = 0;
        while g.next_config(&mut rng).is_some() {
            n += 1;
        }
        assert_eq!(n, 6);
    }

    #[test]
    fn stochastic_dims_resample_each_pass() {
        let sp = SpaceBuilder::new()
            .grid_f64("lr", &[0.1])
            .uniform("m", 0.0, 1.0)
            .build();
        let mut g = GridSearch::new(sp, 2);
        let mut rng = Rng::new(1);
        let a = g.next_config(&mut rng).unwrap()["m"].as_f64().unwrap();
        let b = g.next_config(&mut rng).unwrap()["m"].as_f64().unwrap();
        assert_ne!(a, b);
    }
}
