//! Tree-structured Parzen Estimator — the algorithm behind HyperOpt
//! (Bergstra et al. 2013), which Table 1 lists at 137 LoC as the
//! "HyperOpt" integration. Tune wraps HyperOpt as a suggestion service;
//! we implement the estimator itself so the whole system stays
//! self-contained.
//!
//! Per dimension (TPE factorizes the space): completed observations are
//! split into the top `gamma` fraction ("good", density l) and the rest
//! ("bad", density g). Continuous dims model l and g as Parzen windows
//! (Gaussian KDE, bandwidth per Bergstra's heuristic); categorical dims
//! use smoothed category frequencies. Each suggestion draws `n_ei`
//! candidates from l and keeps the candidate maximizing l(x)/g(x) — the
//! expected-improvement surrogate.

use super::{scored_from_json, scored_to_json, SearchAlgorithm};
use crate::coordinator::spec::{ParamDist, SearchSpace};
use crate::coordinator::trial::{Config, Mode, ParamValue, ResultRow};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Tree-structured Parzen Estimator: model good/bad observation
/// densities per dimension and suggest the best l(x)/g(x) candidate.
pub struct TpeSearch {
    space: SearchSpace,
    remaining: usize,
    /// Random warmup before the estimator kicks in.
    pub n_initial: usize,
    /// Top fraction regarded as "good".
    pub gamma: f64,
    /// Candidates drawn from l(x) per suggestion.
    pub n_ei: usize,
    /// (config, ascending score) for completed trials.
    observations: Vec<(Config, f64)>,
}

impl TpeSearch {
    /// New TPE search with HyperOpt-like defaults (10 random warmup
    /// trials, gamma 0.25, 24 EI candidates).
    pub fn new(space: SearchSpace, num_samples: usize) -> Self {
        TpeSearch {
            space,
            remaining: num_samples,
            n_initial: 10,
            gamma: 0.25,
            n_ei: 24,
            observations: Vec::new(),
        }
    }

    /// Completed observations the estimator currently conditions on.
    pub fn num_observations(&self) -> usize {
        self.observations.len()
    }

    /// Split observed values of `key` into (good, bad) by score.
    fn split(&self, key: &str) -> (Vec<ParamValue>, Vec<ParamValue>) {
        let mut scored: Vec<(&Config, f64)> =
            self.observations.iter().map(|(c, s)| (c, *s)).collect();
        // Best first, NaN-proof (observations are filtered on entry, but
        // the order must stay total for snapshots written by older runs).
        scored.sort_by(|a, b| crate::util::order::desc(a.1, b.1));
        let n_good = ((scored.len() as f64 * self.gamma).ceil() as usize).max(1);
        let take = |slice: &[(&Config, f64)]| {
            slice
                .iter()
                .filter_map(|(c, _)| c.get(key).cloned())
                .collect::<Vec<_>>()
        };
        (take(&scored[..n_good]), take(&scored[n_good..]))
    }

    /// Suggest one value for a continuous dimension in (possibly log)
    /// coordinate space.
    fn suggest_continuous(
        &self,
        rng: &mut Rng,
        dist: &ParamDist,
        good: &[f64],
        bad: &[f64],
        lo: f64,
        hi: f64,
        log: bool,
    ) -> ParamValue {
        let tf = |x: f64| if log { x.ln() } else { x };
        let inv = |x: f64| if log { x.exp() } else { x };
        let (tlo, thi) = (tf(lo), tf(hi));
        let g: Vec<f64> = good.iter().map(|x| tf(*x)).collect();
        let b: Vec<f64> = bad.iter().map(|x| tf(*x)).collect();
        let bw = |n: usize| ((thi - tlo) / (n as f64).sqrt().max(1.0)).max(1e-3 * (thi - tlo));
        let (bw_g, bw_b) = (bw(g.len()), bw(b.len()));

        let kde = |xs: &[f64], bwv: f64, x: f64| -> f64 {
            if xs.is_empty() {
                return 1.0 / (thi - tlo); // uniform prior
            }
            // Mixture including a uniform prior component (HyperOpt's
            // prior-weighted Parzen window).
            let prior = 1.0 / (thi - tlo);
            let mut d = prior;
            for m in xs {
                let z = (x - m) / bwv;
                d += (-0.5 * z * z).exp() / (bwv * (2.0 * std::f64::consts::PI).sqrt());
            }
            d / (xs.len() + 1) as f64
        };

        let mut best_x = rng.uniform(tlo, thi);
        let mut best_ratio = f64::NEG_INFINITY;
        for _ in 0..self.n_ei {
            // Draw from l: pick a good point (or the prior) and jitter.
            let x = if g.is_empty() || rng.bool(1.0 / (g.len() + 1) as f64) {
                rng.uniform(tlo, thi)
            } else {
                (rng.choose(&g) + rng.normal() * bw_g).clamp(tlo, thi)
            };
            let ratio = kde(&g, bw_g, x).ln() - kde(&b, bw_b, x).ln();
            if ratio > best_ratio {
                best_ratio = ratio;
                best_x = x;
            }
        }
        match dist {
            ParamDist::QUniform(_, _, q) => {
                ParamValue::F64(((inv(best_x) / q).round() * q).clamp(lo, hi))
            }
            ParamDist::RandInt(ilo, ihi) => {
                ParamValue::I64((inv(best_x).round() as i64).clamp(*ilo, *ihi - 1))
            }
            _ => ParamValue::F64(inv(best_x).clamp(lo, hi)),
        }
    }

    /// Suggest a categorical value by smoothed good/bad frequency ratio.
    fn suggest_categorical(
        &self,
        rng: &mut Rng,
        options: &[ParamValue],
        good: &[ParamValue],
        bad: &[ParamValue],
    ) -> ParamValue {
        let count = |obs: &[ParamValue], v: &ParamValue| {
            obs.iter().filter(|o| *o == v).count() as f64
        };
        let mut best = None;
        let mut best_ratio = f64::NEG_INFINITY;
        for v in options {
            let l = (count(good, v) + 1.0) / (good.len() + options.len()) as f64;
            let g = (count(bad, v) + 1.0) / (bad.len() + options.len()) as f64;
            // Tiny jitter breaks ties randomly.
            let ratio = (l / g).ln() + rng.uniform(0.0, 1e-6);
            if ratio > best_ratio {
                best_ratio = ratio;
                best = Some(v.clone());
            }
        }
        best.unwrap_or_else(|| rng.choose(options).clone())
    }
}

impl SearchAlgorithm for TpeSearch {
    fn name(&self) -> &'static str {
        "tpe"
    }

    fn next_config(&mut self, rng: &mut Rng) -> Option<Config> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        if self.observations.len() < self.n_initial {
            return Some(crate::coordinator::spec::sample_config(&self.space, rng));
        }
        let mut cfg = Config::new();
        for (key, dist) in &self.space.clone() {
            let (goodv, badv) = self.split(key);
            let value = match dist {
                ParamDist::Uniform(lo, hi) => {
                    let f = |v: &[ParamValue]| {
                        v.iter().filter_map(|p| p.as_f64()).collect::<Vec<_>>()
                    };
                    self.suggest_continuous(rng, dist, &f(&goodv), &f(&badv), *lo, *hi, false)
                }
                ParamDist::QUniform(lo, hi, _) => {
                    let f = |v: &[ParamValue]| {
                        v.iter().filter_map(|p| p.as_f64()).collect::<Vec<_>>()
                    };
                    self.suggest_continuous(rng, dist, &f(&goodv), &f(&badv), *lo, *hi, false)
                }
                ParamDist::LogUniform(lo, hi) => {
                    let f = |v: &[ParamValue]| {
                        v.iter().filter_map(|p| p.as_f64()).collect::<Vec<_>>()
                    };
                    self.suggest_continuous(rng, dist, &f(&goodv), &f(&badv), *lo, *hi, true)
                }
                ParamDist::RandInt(lo, hi) => {
                    let f = |v: &[ParamValue]| {
                        v.iter().filter_map(|p| p.as_f64()).collect::<Vec<_>>()
                    };
                    self.suggest_continuous(
                        rng, dist, &f(&goodv), &f(&badv), *lo as f64, (*hi - 1) as f64, false,
                    )
                }
                ParamDist::Choice(opts) | ParamDist::GridSearch(opts) => {
                    self.suggest_categorical(rng, opts, &goodv, &badv)
                }
                ParamDist::Const(v) => v.clone(),
            };
            cfg.insert(key.clone(), value);
        }
        Some(cfg)
    }

    fn on_complete(&mut self, config: &Config, final_metric: Option<f64>, mode: Mode) {
        // A NaN outcome carries no density information — conditioning
        // the Parzen windows on it would only produce NaN likelihood
        // ratios. Diverged trials are simply not observations.
        if let Some(m) = final_metric.filter(|m| !m.is_nan()) {
            self.observations.push((config.clone(), mode.ascending(m)));
        }
    }

    fn on_result(&mut self, _config: &Config, _result: &ResultRow) {}

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("remaining", Json::Num(self.remaining as f64)),
            ("observations", scored_to_json(&self.observations)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.remaining = snap
            .get("remaining")
            .and_then(|v| v.as_u64())
            .ok_or("tpe snapshot: bad remaining")? as usize;
        self.observations = snap
            .get("observations")
            .and_then(scored_from_json)
            .ok_or("tpe snapshot: bad observations")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;

    /// Quadratic bowl: best at x = 0.3.
    fn objective(c: &Config) -> f64 {
        let x = c["x"].as_f64().unwrap();
        -(x - 0.3).powi(2)
    }

    #[test]
    fn concentrates_near_optimum() {
        let sp = SpaceBuilder::new().uniform("x", 0.0, 1.0).build();
        let mut tpe = TpeSearch::new(sp, 200);
        let mut rng = Rng::new(7);
        let mut last50 = Vec::new();
        let mut i = 0;
        while let Some(c) = tpe.next_config(&mut rng) {
            let y = objective(&c);
            tpe.on_complete(&c, Some(y), Mode::Max);
            i += 1;
            if i > 150 {
                last50.push(c["x"].as_f64().unwrap());
            }
        }
        let mean = last50.iter().sum::<f64>() / last50.len() as f64;
        assert!((mean - 0.3).abs() < 0.12, "mean={mean}");
        // TPE should beat random search's expected best on the bowl.
        let near = last50.iter().filter(|x| (**x - 0.3).abs() < 0.1).count();
        assert!(near * 2 > last50.len(), "near={near}/{}", last50.len());
    }

    #[test]
    fn warmup_is_random() {
        let sp = SpaceBuilder::new().uniform("x", 0.0, 1.0).build();
        let mut tpe = TpeSearch::new(sp, 5);
        let mut rng = Rng::new(1);
        for _ in 0..5 {
            assert!(tpe.next_config(&mut rng).is_some());
        }
        assert!(tpe.next_config(&mut rng).is_none());
        assert_eq!(tpe.num_observations(), 0);
    }

    #[test]
    fn loguniform_stays_in_support() {
        let sp = SpaceBuilder::new().loguniform("lr", 1e-5, 1e-1).build();
        let mut tpe = TpeSearch::new(sp, 60);
        let mut rng = Rng::new(2);
        while let Some(c) = tpe.next_config(&mut rng) {
            let lr = c["lr"].as_f64().unwrap();
            assert!((1e-5..=1e-1).contains(&lr), "lr={lr}");
            tpe.on_complete(&c, Some(-(lr.log10() + 3.0).powi(2)), Mode::Max);
        }
    }

    #[test]
    fn categorical_prefers_good_option() {
        let sp = SpaceBuilder::new().choice_str("act", &["relu", "tanh", "bad"]).build();
        let mut tpe = TpeSearch::new(sp, 120);
        let mut rng = Rng::new(3);
        let mut picks = std::collections::BTreeMap::new();
        let mut i = 0;
        while let Some(c) = tpe.next_config(&mut rng) {
            let act = c["act"].as_str().unwrap().to_string();
            let y = if act == "relu" { 1.0 } else { 0.0 };
            tpe.on_complete(&c, Some(y + rng.uniform(0.0, 0.1)), Mode::Max);
            i += 1;
            if i > 40 {
                *picks.entry(act).or_insert(0) += 1;
            }
        }
        let relu = picks.get("relu").copied().unwrap_or(0);
        let total: i32 = picks.values().sum();
        assert!(relu * 2 > total, "{picks:?}");
    }

    #[test]
    fn randint_suggestions_are_integers_in_range() {
        let sp = SpaceBuilder::new().randint("layers", 1, 6).build();
        let mut tpe = TpeSearch::new(sp, 40);
        let mut rng = Rng::new(4);
        while let Some(c) = tpe.next_config(&mut rng) {
            match &c["layers"] {
                ParamValue::I64(v) => assert!((1..6).contains(v)),
                other => panic!("{other:?}"),
            }
            tpe.on_complete(&c, Some(0.0), Mode::Max);
        }
    }
}
