//! Random search (Bergstra & Bengio 2012): i.i.d. samples from the
//! search space. The workhorse baseline under ASHA/HyperBand/median
//! stopping in C1, and the static baseline PBT must beat in C2.

use super::SearchAlgorithm;
use crate::coordinator::spec::{sample_config, SearchSpace};
use crate::coordinator::trial::Config;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// I.i.d. sampling from the search space, `num_samples` times.
pub struct RandomSearch {
    space: SearchSpace,
    remaining: usize,
}

impl RandomSearch {
    /// New random search emitting exactly `num_samples` configs.
    pub fn new(space: SearchSpace, num_samples: usize) -> Self {
        RandomSearch { space, remaining: num_samples }
    }
}

impl SearchAlgorithm for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn next_config(&mut self, rng: &mut Rng) -> Option<Config> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        Some(sample_config(&self.space, rng))
    }

    fn snapshot(&self) -> Json {
        Json::obj(vec![("remaining", Json::Num(self.remaining as f64))])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.remaining = snap
            .get("remaining")
            .and_then(|v| v.as_u64())
            .ok_or("random snapshot: bad remaining")? as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;

    #[test]
    fn emits_exactly_n() {
        let sp = SpaceBuilder::new().uniform("x", 0.0, 1.0).build();
        let mut s = RandomSearch::new(sp, 5);
        let mut rng = Rng::new(0);
        let mut n = 0;
        while s.next_config(&mut rng).is_some() {
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn samples_are_distinct() {
        let sp = SpaceBuilder::new().uniform("x", 0.0, 1.0).build();
        let mut s = RandomSearch::new(sp, 10);
        let mut rng = Rng::new(1);
        let mut xs = Vec::new();
        while let Some(c) = s.next_config(&mut rng) {
            xs.push(c["x"].as_f64().unwrap());
        }
        xs.dedup();
        assert_eq!(xs.len(), 10);
    }
}
