//! (mu + lambda) evolutionary search — the "genetic algorithms" family
//! §3 cites as a motivating workload class ("genetic algorithms
//! commonly clone or mutate model parameters in the middle of
//! training"). This is the *search-side* variant (PBT is the
//! scheduler-side one): parents are the top-mu completed trials;
//! children mutate a random parent's config (perturb continuous dims,
//! occasionally resample; resample categoricals with low probability).

use super::{scored_from_json, scored_to_json, SearchAlgorithm};
use crate::coordinator::spec::{sample_config, ParamDist, SearchSpace};
use crate::coordinator::trial::{Config, Mode, ParamValue, ResultRow};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// (mu + lambda) evolutionary search: children mutate top-mu parents.
pub struct EvolutionSearch {
    space: SearchSpace,
    remaining: usize,
    /// Parents pool size.
    pub mu: usize,
    /// Random configs before evolution starts (and exploration mix-in).
    pub population_size: usize,
    pub resample_prob: f64,
    pub perturb_sigma: f64,
    /// Completed (config, ascending score), kept sorted best-first,
    /// truncated to mu.
    parents: Vec<(Config, f64)>,
    evaluated: usize,
}

impl EvolutionSearch {
    /// New evolutionary search with default mu/population/mutation rates.
    pub fn new(space: SearchSpace, num_samples: usize) -> Self {
        EvolutionSearch {
            space,
            remaining: num_samples,
            mu: 4,
            population_size: 12,
            resample_prob: 0.15,
            perturb_sigma: 0.25,
            parents: Vec::new(),
            evaluated: 0,
        }
    }

    /// Current parent-pool size (grows to mu, then stays).
    pub fn num_parents(&self) -> usize {
        self.parents.len()
    }

    fn mutate(&self, parent: &Config, rng: &mut Rng) -> Config {
        let mut child = parent.clone();
        for (key, dist) in &self.space {
            if rng.bool(self.resample_prob) {
                child.insert(key.clone(), dist.sample(rng));
                continue;
            }
            let cur = child.get(key).cloned();
            let newv = match (dist, cur) {
                (ParamDist::Uniform(lo, hi), Some(v)) => {
                    let x = v.as_f64().unwrap_or((*lo + *hi) / 2.0);
                    let sigma = (hi - lo) * self.perturb_sigma;
                    Some(ParamValue::F64((x + rng.normal() * sigma).clamp(*lo, *hi)))
                }
                (ParamDist::LogUniform(lo, hi), Some(v)) => {
                    // Perturb in log space (scale parameters).
                    let x = v.as_f64().unwrap_or((lo * hi).sqrt()).max(*lo);
                    let span = (hi / lo).ln();
                    let y = x.ln() + rng.normal() * span * self.perturb_sigma;
                    Some(ParamValue::F64(y.exp().clamp(*lo, *hi)))
                }
                (ParamDist::QUniform(lo, hi, q), Some(v)) => {
                    let x = v.as_f64().unwrap_or(*lo);
                    let sigma = (hi - lo) * self.perturb_sigma;
                    let y = ((x + rng.normal() * sigma) / q).round() * q;
                    Some(ParamValue::F64(y.clamp(*lo, *hi)))
                }
                (ParamDist::RandInt(lo, hi), Some(v)) => {
                    let x = match v {
                        ParamValue::I64(i) => i,
                        _ => *lo,
                    };
                    let step = rng.range(-2, 3);
                    Some(ParamValue::I64((x + step).clamp(*lo, *hi - 1)))
                }
                // Categorical / grid / const: inherit (resample handled
                // above).
                (_, Some(v)) => Some(v),
                (_, None) => None,
            };
            match newv {
                Some(v) => {
                    child.insert(key.clone(), v);
                }
                None => {
                    child.insert(key.clone(), dist.sample(rng));
                }
            }
        }
        child
    }
}

impl SearchAlgorithm for EvolutionSearch {
    fn name(&self) -> &'static str {
        "evolution"
    }

    fn next_config(&mut self, rng: &mut Rng) -> Option<Config> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        // Warmup generation, plus a persistent exploration mix-in.
        if self.parents.is_empty() || self.evaluated < self.population_size || rng.bool(0.1) {
            return Some(sample_config(&self.space, rng));
        }
        let parent = &self.parents[rng.index(self.parents.len())].0.clone();
        Some(self.mutate(parent, rng))
    }

    fn on_complete(&mut self, config: &Config, final_metric: Option<f64>, mode: Mode) {
        // Diverged (NaN) trials cannot parent the next generation; drop
        // them before the pool instead of letting NaN poison the sort.
        let Some(m) = final_metric.filter(|m| !m.is_nan()) else { return };
        self.evaluated += 1;
        self.parents.push((config.clone(), mode.ascending(m)));
        self.parents.sort_by(|a, b| crate::util::order::desc(a.1, b.1));
        self.parents.truncate(self.mu);
    }

    fn on_result(&mut self, _config: &Config, _result: &ResultRow) {}

    fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("remaining", Json::Num(self.remaining as f64)),
            ("evaluated", Json::Num(self.evaluated as f64)),
            ("parents", scored_to_json(&self.parents)),
        ])
    }

    fn restore(&mut self, snap: &Json) -> Result<(), String> {
        self.remaining = snap
            .get("remaining")
            .and_then(|v| v.as_u64())
            .ok_or("evolution snapshot: bad remaining")? as usize;
        self.evaluated = snap
            .get("evaluated")
            .and_then(|v| v.as_u64())
            .ok_or("evolution snapshot: bad evaluated")? as usize;
        self.parents = snap
            .get("parents")
            .and_then(scored_from_json)
            .ok_or("evolution snapshot: bad parents")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;

    fn space() -> SearchSpace {
        SpaceBuilder::new()
            .loguniform("lr", 1e-5, 1.0)
            .uniform("m", 0.0, 1.0)
            .choice_str("act", &["a", "b"])
            .randint("layers", 1, 6)
            .build()
    }

    /// Bowl objective: best at lr = 1e-2, m = 0.7.
    fn objective(c: &Config) -> f64 {
        let lr = c["lr"].as_f64().unwrap();
        let m = c["m"].as_f64().unwrap();
        -(lr.log10() + 2.0).powi(2) - 4.0 * (m - 0.7).powi(2)
    }

    #[test]
    fn converges_toward_optimum() {
        let mut es = EvolutionSearch::new(space(), 300);
        let mut rng = Rng::new(3);
        let mut late = Vec::new();
        let mut i = 0;
        while let Some(c) = es.next_config(&mut rng) {
            es.on_complete(&c, Some(objective(&c)), Mode::Max);
            i += 1;
            if i > 200 {
                late.push(c["lr"].as_f64().unwrap().log10());
            }
        }
        let mean: f64 = late.iter().sum::<f64>() / late.len() as f64;
        assert!((mean + 2.0).abs() < 0.8, "mean log10(lr) = {mean}");
    }

    #[test]
    fn children_stay_in_support() {
        let sp = space();
        let mut es = EvolutionSearch::new(sp.clone(), 200);
        let mut rng = Rng::new(5);
        while let Some(c) = es.next_config(&mut rng) {
            for (k, d) in &sp {
                assert!(d.contains(&c[k]), "{k}: {:?}", c[k]);
            }
            es.on_complete(&c, Some(rng.f64()), Mode::Max);
        }
    }

    #[test]
    fn parent_pool_is_truncated_to_mu() {
        let mut es = EvolutionSearch::new(space(), 100);
        let mut rng = Rng::new(7);
        for i in 0..50 {
            let c = es.next_config(&mut rng).unwrap();
            es.on_complete(&c, Some(i as f64), Mode::Max);
        }
        assert_eq!(es.num_parents(), es.mu);
        // Parents are the best scores seen (46..49 ascending-normalized).
        assert!(es.parents.iter().all(|(_, s)| *s >= 46.0));
    }

    #[test]
    fn exhausts_after_num_samples() {
        let mut es = EvolutionSearch::new(space(), 7);
        let mut rng = Rng::new(9);
        let mut n = 0;
        while es.next_config(&mut rng).is_some() {
            n += 1;
        }
        assert_eq!(n, 7);
    }
}
