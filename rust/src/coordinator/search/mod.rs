//! Search algorithms: the *suggestion* side of model selection.
//!
//! §4.2: trial schedulers "can add to the list of trials to execute
//! (e.g., based on suggestions from HyperOpt)". In Tune (as in Ray
//! today) this is factored into a second narrow interface: a
//! [`SearchAlgorithm`] proposes hyperparameter configurations; the trial
//! scheduler decides how to allocate resources among the resulting
//! trials. Any search algorithm composes with any scheduler.

use super::spec::SearchSpace;
use super::trial::{Config, Mode, ResultRow};
use crate::util::rng::Rng;

pub mod evolution;
pub mod grid;
pub mod random;
pub mod tpe;

pub use evolution::EvolutionSearch;
pub use grid::GridSearch;
pub use random::RandomSearch;
pub use tpe::TpeSearch;

/// Produces trial configurations, optionally conditioning on results.
pub trait SearchAlgorithm: Send {
    /// Stable label ("grid", "random", ...) for logs and tables.
    fn name(&self) -> &'static str;

    /// Next configuration to try; None = exhausted.
    fn next_config(&mut self, rng: &mut Rng) -> Option<Config>;

    /// Intermediate result feedback (most algorithms ignore it).
    fn on_result(&mut self, _config: &Config, _result: &ResultRow) {}

    /// A trial finished with `final_metric` (already in the raw metric
    /// space; `mode` tells the algorithm which direction is better).
    fn on_complete(&mut self, _config: &Config, _final_metric: Option<f64>, _mode: Mode) {}

    /// Serialize all mutable state (cursors, observations, populations)
    /// for the experiment snapshot (see `coordinator::persist`).
    fn snapshot(&self) -> crate::util::json::Json {
        crate::util::json::Json::Null
    }

    /// Rebuild state from a [`SearchAlgorithm::snapshot`] value, so a
    /// resumed experiment proposes the same remaining configurations.
    /// The receiver was freshly constructed with the same parameters.
    fn restore(&mut self, _snap: &crate::util::json::Json) -> Result<(), String> {
        Ok(())
    }

    /// Incremental snapshot for the delta-snapshot machinery. Search
    /// state only changes on suggestion/completion (orders of magnitude
    /// rarer than results), so the default — the full snapshot, folded
    /// back by the default [`SearchAlgorithm::apply_delta`] as a full
    /// replace — is already proportional to a small state and no
    /// implementation overrides it today.
    fn snapshot_delta(&mut self) -> crate::util::json::Json {
        self.snapshot()
    }

    /// Fold a value produced by [`SearchAlgorithm::snapshot_delta`]
    /// into the current state (default: full replace via
    /// [`SearchAlgorithm::restore`]).
    fn apply_delta(&mut self, delta: &crate::util::json::Json) -> Result<(), String> {
        self.restore(delta)
    }

    /// A full snapshot was just persisted; reset any delta tracking.
    fn reset_delta_cursor(&mut self) {}
}

/// Helper shared by search impls: total configs a space yields for
/// `num_samples` (grid dims multiply, per §4.3's DSL semantics).
pub fn total_trials(space: &SearchSpace, num_samples: usize) -> usize {
    super::spec::grid_size(space) * num_samples.max(1)
}

/// Serialize a scored-config list (TPE observations, evolution parents)
/// for a search snapshot.
pub(crate) fn scored_to_json(v: &[(Config, f64)]) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::Arr(
        v.iter()
            .map(|(c, s)| {
                Json::obj(vec![
                    ("config", super::persist::config_to_json(c)),
                    ("score", Json::Num(*s)),
                ])
            })
            .collect(),
    )
}

/// Decode a list written by [`scored_to_json`].
pub(crate) fn scored_from_json(j: &crate::util::json::Json) -> Option<Vec<(Config, f64)>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Some((
                super::persist::config_from_json(e.get("config")?)?,
                e.get("score")?.as_f64()?,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;

    /// Every search algorithm must resume mid-stream: snapshot after a
    /// few suggestions, restore into a fresh instance, and (with the
    /// runner's rng stream also restored — modeled here by cloning)
    /// produce exactly the configs the original would have produced.
    #[test]
    fn all_searchers_resume_identically_mid_stream() {
        let space = SpaceBuilder::new()
            .loguniform("lr", 1e-4, 1.0)
            .choice_str("act", &["relu", "tanh"])
            .grid_f64("bs", &[16.0, 32.0])
            .randint("layers", 1, 4)
            .build();
        let n = 30;
        type Builder = Box<dyn Fn() -> Box<dyn SearchAlgorithm>>;
        let mk: Vec<(&str, Builder)> = vec![
            ("random", {
                let s = space.clone();
                Box::new(move || {
                    Box::new(RandomSearch::new(s.clone(), n)) as Box<dyn SearchAlgorithm>
                })
            }),
            ("grid", {
                let s = space.clone();
                Box::new(move || {
                    Box::new(GridSearch::new(s.clone(), n)) as Box<dyn SearchAlgorithm>
                })
            }),
            ("tpe", {
                let s = space.clone();
                Box::new(move || {
                    Box::new(TpeSearch::new(s.clone(), n)) as Box<dyn SearchAlgorithm>
                })
            }),
            ("evolution", {
                let s = space.clone();
                Box::new(move || {
                    Box::new(EvolutionSearch::new(s.clone(), n)) as Box<dyn SearchAlgorithm>
                })
            }),
        ];
        for (name, build) in mk {
            let mut rng = Rng::new(13);
            let mut a = build();
            // Advance past TPE's warmup so estimator state is exercised.
            for i in 0..15 {
                let c = a.next_config(&mut rng).unwrap();
                a.on_complete(&c, Some(i as f64), Mode::Max);
            }
            let text = a.snapshot().to_string();
            let parsed = crate::util::json::parse(&text).unwrap();
            let mut b = build();
            b.restore(&parsed).unwrap();
            let mut rng_b = rng.clone();
            loop {
                let ca = a.next_config(&mut rng);
                let cb = b.next_config(&mut rng_b);
                assert_eq!(ca, cb, "{name} diverged after restore");
                if ca.is_none() {
                    break;
                }
            }
        }
    }

    #[test]
    fn total_trials_multiplies_grid() {
        let sp = SpaceBuilder::new()
            .grid_f64("lr", &[0.1, 0.01])
            .uniform("m", 0.0, 1.0)
            .build();
        assert_eq!(total_trials(&sp, 3), 6);
        assert_eq!(total_trials(&sp, 0), 2);
    }
}
