//! Search algorithms: the *suggestion* side of model selection.
//!
//! §4.2: trial schedulers "can add to the list of trials to execute
//! (e.g., based on suggestions from HyperOpt)". In Tune (as in Ray
//! today) this is factored into a second narrow interface: a
//! [`SearchAlgorithm`] proposes hyperparameter configurations; the trial
//! scheduler decides how to allocate resources among the resulting
//! trials. Any search algorithm composes with any scheduler.

use super::spec::SearchSpace;
use super::trial::{Config, Mode, ResultRow};
use crate::util::rng::Rng;

pub mod evolution;
pub mod grid;
pub mod random;
pub mod tpe;

pub use evolution::EvolutionSearch;
pub use grid::GridSearch;
pub use random::RandomSearch;
pub use tpe::TpeSearch;

/// Produces trial configurations, optionally conditioning on results.
pub trait SearchAlgorithm: Send {
    /// Stable label ("grid", "random", ...) for logs and tables.
    fn name(&self) -> &'static str;

    /// Next configuration to try; None = exhausted.
    fn next_config(&mut self, rng: &mut Rng) -> Option<Config>;

    /// Intermediate result feedback (most algorithms ignore it).
    fn on_result(&mut self, _config: &Config, _result: &ResultRow) {}

    /// A trial finished with `final_metric` (already in the raw metric
    /// space; `mode` tells the algorithm which direction is better).
    fn on_complete(&mut self, _config: &Config, _final_metric: Option<f64>, _mode: Mode) {}
}

/// Helper shared by search impls: total configs a space yields for
/// `num_samples` (grid dims multiply, per §4.3's DSL semantics).
pub fn total_trials(space: &SearchSpace, num_samples: usize) -> usize {
    super::spec::grid_size(space) * num_samples.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::spec::SpaceBuilder;

    #[test]
    fn total_trials_multiplies_grid() {
        let sp = SpaceBuilder::new()
            .grid_f64("lr", &[0.1, 0.01])
            .uniform("m", 0.0, 1.0)
            .build();
        assert_eq!(total_trials(&sp, 3), 6);
        assert_eq!(total_trials(&sp, 0), 2);
    }
}
