//! Trial executors: where trainables actually run.
//!
//! Two implementations behind one interface, so every scheduler/search
//! algorithm is oblivious to the execution substrate (§3's requirement
//! to "handle irregular computations" lives here):
//!
//! * [`SimExecutor`] — discrete-event, virtual clock. Each step costs
//!   `Trainable::step_cost()` virtual seconds; a binary heap orders
//!   completions. Runs thousand-trial experiments in milliseconds of
//!   wall time; the scheduler benches (C1–C3) use it.
//! * [`ThreadExecutor`] — one worker thread per live trial, command
//!   channels in, one shared event channel out. Wall-clock time. The
//!   end-to-end PJRT workloads run here, mirroring Ray's
//!   process-per-trial model in-process.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::coordinator::trial::{Config, Trial, TrialId};
use crate::trainable::{StepOutput, Trainable, TrainableFactory};

/// Completion events delivered to the runner.
#[derive(Debug)]
pub enum ExecEvent {
    Stepped { trial: TrialId, out: StepOutput },
    Failed { trial: TrialId, error: String },
}

pub trait Executor: Send {
    /// Seconds since experiment start (virtual or wall).
    fn now(&self) -> f64;

    /// Instantiate the trial's trainable (optionally restoring).
    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String>;

    /// Ask for one asynchronous training iteration.
    fn request_step(&mut self, id: TrialId);

    /// Next completion event; None when nothing is in flight.
    fn next_event(&mut self) -> Option<ExecEvent>;

    /// Synchronous state snapshot (trainable is idle between steps).
    fn save(&mut self, id: TrialId) -> Option<Vec<u8>>;

    /// Restore state in place (PBT exploit).
    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String>;

    /// Runtime hyperparameter mutation.
    fn update_config(&mut self, id: TrialId, config: &Config);

    /// Tear down the trial's trainable.
    fn halt(&mut self, id: TrialId);

    fn num_live(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Discrete-event executor
// ---------------------------------------------------------------------------

/// f64 ordered for the heap (times are finite by construction).
#[derive(PartialEq, PartialOrd)]
struct F64Ord(f64);
impl Eq for F64Ord {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

pub struct SimExecutor {
    factory: TrainableFactory,
    now: f64,
    seq: u64,
    queue: BinaryHeap<Reverse<(F64Ord, u64, TrialId)>>,
    live: HashMap<TrialId, Box<dyn Trainable>>,
}

impl SimExecutor {
    pub fn new(factory: TrainableFactory) -> Self {
        SimExecutor { factory, now: 0.0, seq: 0, queue: BinaryHeap::new(), live: HashMap::new() }
    }
}

impl Executor for SimExecutor {
    fn now(&self) -> f64 {
        self.now
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String> {
        let mut t = (self.factory)(&trial.config, trial.seed);
        if let Some(blob) = restore {
            t.restore(&blob)?;
        }
        self.live.insert(trial.id, t);
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        if let Some(t) = self.live.get(&id) {
            let done_at = self.now + t.step_cost().max(1e-9);
            self.seq += 1;
            self.queue.push(Reverse((F64Ord(done_at), self.seq, id)));
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        while let Some(Reverse((F64Ord(at), _, id))) = self.queue.pop() {
            // Halted trials may leave stale queue entries; skip them.
            let Some(t) = self.live.get_mut(&id) else { continue };
            self.now = self.now.max(at);
            return Some(match t.step() {
                Ok(out) => ExecEvent::Stepped { trial: id, out },
                Err(error) => ExecEvent::Failed { trial: id, error },
            });
        }
        None
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        self.live.get_mut(&id).map(|t| t.save())
    }

    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String> {
        self.live.get_mut(&id).ok_or("trial not live")?.restore(blob)
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        if let Some(t) = self.live.get_mut(&id) {
            t.update_config(config);
        }
    }

    fn halt(&mut self, id: TrialId) {
        self.live.remove(&id);
    }

    fn num_live(&self) -> usize {
        self.live.len()
    }
}

// ---------------------------------------------------------------------------
// Threaded executor
// ---------------------------------------------------------------------------

enum WorkerCmd {
    Step,
    Save(Sender<Vec<u8>>),
    Restore(Vec<u8>, Sender<Result<(), String>>),
    Update(Config),
    Halt,
}

struct Worker {
    tx: Sender<WorkerCmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

pub struct ThreadExecutor {
    factory: TrainableFactory,
    workers: HashMap<TrialId, Worker>,
    event_tx: Sender<ExecEvent>,
    event_rx: Receiver<ExecEvent>,
    started: Instant,
}

impl ThreadExecutor {
    pub fn new(factory: TrainableFactory) -> Self {
        let (event_tx, event_rx) = mpsc::channel();
        ThreadExecutor {
            factory,
            workers: HashMap::new(),
            event_tx,
            event_rx,
            started: Instant::now(),
        }
    }
}

impl Executor for ThreadExecutor {
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String> {
        let (tx, rx) = mpsc::channel::<WorkerCmd>();
        let factory = Arc::clone(&self.factory);
        let config = trial.config.clone();
        let seed = trial.seed;
        let id = trial.id;
        let events = self.event_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("trial-{id}"))
            .spawn(move || {
                let mut t = factory(&config, seed);
                if let Some(blob) = restore {
                    if let Err(e) = t.restore(&blob) {
                        let _ = events.send(ExecEvent::Failed { trial: id, error: e });
                        return;
                    }
                }
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        WorkerCmd::Step => {
                            let ev = match t.step() {
                                Ok(out) => ExecEvent::Stepped { trial: id, out },
                                Err(error) => ExecEvent::Failed { trial: id, error },
                            };
                            if events.send(ev).is_err() {
                                return;
                            }
                        }
                        WorkerCmd::Save(reply) => {
                            let _ = reply.send(t.save());
                        }
                        WorkerCmd::Restore(blob, reply) => {
                            let _ = reply.send(t.restore(&blob));
                        }
                        WorkerCmd::Update(cfg) => t.update_config(&cfg),
                        WorkerCmd::Halt => return,
                    }
                }
            })
            .map_err(|e| e.to_string())?;
        self.workers.insert(id, Worker { tx, handle: Some(handle) });
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(WorkerCmd::Step);
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        if self.workers.is_empty() {
            return None;
        }
        // In-flight events from just-halted workers are still valid to
        // receive; the runner filters by trial status.
        self.event_rx.recv().ok()
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        let w = self.workers.get(&id)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(WorkerCmd::Save(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String> {
        let w = self.workers.get(&id).ok_or("trial not live")?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(WorkerCmd::Restore(blob.to_vec(), reply_tx))
            .map_err(|e| e.to_string())?;
        reply_rx.recv().map_err(|e| e.to_string())?
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(WorkerCmd::Update(config.clone()));
        }
    }

    fn halt(&mut self, id: TrialId) {
        if let Some(mut w) = self.workers.remove(&id) {
            let _ = w.tx.send(WorkerCmd::Halt);
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }

    fn num_live(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadExecutor {
    fn drop(&mut self) {
        let ids: Vec<TrialId> = self.workers.keys().copied().collect();
        for id in ids {
            self.halt(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::ParamValue;
    use crate::ray::Resources;
    use crate::trainable::factory;
    use crate::trainable::synthetic::ConstTrainable;

    fn mk_trial(id: TrialId, cost: f64) -> Trial {
        let mut c = Config::new();
        c.insert("step_cost".into(), ParamValue::F64(cost));
        Trial::new(id, c, Resources::cpu(1.0), id)
    }

    fn const_factory() -> TrainableFactory {
        factory(|c, s| Box::new(ConstTrainable::new(c, s)))
    }

    #[test]
    fn sim_orders_by_virtual_time() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 5.0), None).unwrap();
        ex.launch(&mk_trial(2, 1.0), None).unwrap();
        ex.request_step(1);
        ex.request_step(2);
        // Trial 2 (cost 1) completes before trial 1 (cost 5).
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 2),
            e => panic!("{e:?}"),
        }
        assert!((ex.now() - 1.0).abs() < 1e-9);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 1),
            e => panic!("{e:?}"),
        }
        assert!((ex.now() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sim_halt_discards_stale_events() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        assert!(ex.next_event().is_none());
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn sim_save_restore() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.next_event();
        let blob = ex.save(1).unwrap();
        ex.launch(&mk_trial(2, 1.0), Some(blob)).unwrap();
        ex.request_step(2);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 2.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn threaded_steps_flow() {
        let mut ex = ThreadExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, out } => {
                assert_eq!(trial, 1);
                assert_eq!(out.metrics["iters"], 1.0);
            }
            e => panic!("{e:?}"),
        }
        let blob = ex.save(1).unwrap();
        assert_eq!(u64::from_le_bytes(blob.try_into().unwrap()), 1);
        ex.halt(1);
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn threaded_parallel_trials() {
        let mut ex = ThreadExecutor::new(const_factory());
        for id in 0..8 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
            ex.request_step(id);
        }
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..8 {
            match ex.next_event().unwrap() {
                ExecEvent::Stepped { trial, .. } => {
                    seen.insert(trial);
                }
                e => panic!("{e:?}"),
            }
        }
        assert_eq!(seen.len(), 8);
    }

    #[test]
    fn threaded_restore_in_place() {
        let mut ex = ThreadExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        for _ in 0..3 {
            ex.request_step(1);
            ex.next_event();
        }
        ex.restore(1, &0u64.to_le_bytes()).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }
}
