//! Trial executors: where trainables actually run.
//!
//! Three implementations behind one interface, so every scheduler/search
//! algorithm is oblivious to the execution substrate (§3's requirement
//! to "handle irregular computations" lives here):
//!
//! * [`SimExecutor`] — discrete-event, virtual clock. Each step costs
//!   `Trainable::step_cost()` virtual seconds; a binary heap orders
//!   completions. Runs thousand-trial experiments in milliseconds of
//!   wall time; the scheduler benches (C1–C3) use it.
//! * [`ThreadExecutor`] — one worker thread per live trial, command
//!   channels in, one shared event channel out. Wall-clock time. The
//!   end-to-end PJRT workloads run here, mirroring Ray's
//!   process-per-trial model in-process.
//! * [`PoolExecutor`] — a bounded pool of N worker threads servicing
//!   M ≫ N live trials through a shared injector queue, so concurrency
//!   is decoupled from trial count. Wall-clock time. This is the
//!   production substrate: thousand-trial experiments no longer burn a
//!   thread per trial.
//!
//! On top of the pool machinery sits the [`SharedPool`]: ONE bounded
//! worker pool multiplexed across many *experiments*. Each experiment
//! gets its own [`SharedPoolHandle`] (an [`Executor`]), trial ids are
//! namespaced per experiment, and completion events are routed back to
//! the owning experiment — the substrate under
//! [`crate::coordinator::hub::ExperimentHub`].
//!
//! All wall-clock substrates contain trainable panics: a panicking
//! `step()` (or constructor/restore) surfaces as [`ExecEvent::Failed`]
//! so the runner's `max_failures` recovery applies, instead of
//! poisoning shared state and cascading `lock().unwrap()` panics
//! through the coordinator.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

// Two lint.toml file-level exemptions apply here, justified once for
// the whole file:
//
// lint:allow(clock): the ThreadExecutor/PoolExecutor/SharedPool halves
// of this file ARE the wall-clock substrate — `started: Instant` and
// recv deadlines are their contract. SimExecutor never reads a clock.
//
// lint:allow(hash_container): the remaining HashMaps (SimExecutor
// live/epoch/hints/speed, PoolState slots/epochs, WorkerFleet assigned)
// are keyed lookups that are never iterated on fingerprint-bearing
// paths; the generic pool key is `Hash`, not `Ord`, so BTreeMap cannot
// replace them. Everything iterated (ThreadExecutor workers, Router
// buffers) is a BTreeMap.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::trial::{Config, Trial, TrialId};
use crate::ray::Resources;
use crate::trainable::{StepOutput, Trainable, TrainableFactory};

/// Completion events delivered to the runner.
#[derive(Debug)]
pub enum ExecEvent {
    /// One training iteration finished and reported metrics.
    Stepped {
        /// Trial that stepped.
        trial: TrialId,
        /// Metrics (and done flag) the trainable reported.
        out: StepOutput,
    },
    /// The trial's step raised an error (crash, injected fault, panic).
    Failed {
        /// Trial that failed.
        trial: TrialId,
        /// Human-readable failure cause.
        error: String,
    },
}

/// Outcome of executor-side capacity admission ([`Executor::admit`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Capacity reserved; the launch may proceed. The reservation is
    /// released by [`Executor::halt`].
    Granted,
    /// Every worker that could hold this demand is currently full; the
    /// trial should park as Pending and retry when capacity frees.
    Exhausted,
    /// No worker could *ever* hold this demand — the trial can never
    /// run on this executor and should fail fast.
    Infeasible,
}

/// The execution substrate interface the runner drives. Implementations
/// differ in clock (virtual vs wall) and concurrency model, not
/// semantics: launch, request asynchronous steps, collect completion
/// events, and snapshot/restore/mutate idle trainables synchronously.
pub trait Executor: Send {
    /// Seconds since experiment start (virtual or wall).
    fn now(&self) -> f64;

    /// Capacity-aware admission: reserve executor-side room for a
    /// trial's resource demand before launching it. The default grants
    /// everything — the sim and thread executors model capacity purely
    /// through the cluster substrate; pool executors built with
    /// per-worker capacity vectors do a real vector fit (see
    /// [`PoolExecutor::with_capacities`]). A granted reservation is
    /// released by [`Executor::halt`].
    fn admit(&mut self, _id: TrialId, _demand: &Resources) -> Admission {
        Admission::Granted
    }

    /// Tell the executor which node shape the trial was placed on,
    /// called by the runner after placement and before
    /// [`Executor::launch`]. Wall-clock executors ignore it — real
    /// hardware is its own speed. The sim executor uses it to apply
    /// shape-dependent step times ([`SimExecutor::with_shape_factors`]),
    /// which is what makes hardware-aware scheduling testable on the
    /// virtual clock.
    fn place_hint(&mut self, _id: TrialId, _shape: &Resources) {}

    /// Instantiate the trial's trainable (optionally restoring). The
    /// blob is a shared checkpoint handle: passing it costs a refcount
    /// bump, not a byte copy.
    fn launch(&mut self, trial: &Trial, restore: Option<Arc<[u8]>>) -> Result<(), String>;

    /// Ask for one asynchronous training iteration.
    fn request_step(&mut self, id: TrialId);

    /// Next completion event; None when nothing is in flight.
    fn next_event(&mut self) -> Option<ExecEvent>;

    /// Synchronous state snapshot (trainable is idle between steps).
    fn save(&mut self, id: TrialId) -> Option<Vec<u8>>;

    /// Restore state in place (PBT exploit). Shared blob handle, same
    /// zero-copy contract as [`Executor::launch`].
    fn restore(&mut self, id: TrialId, blob: Arc<[u8]>) -> Result<(), String>;

    /// Runtime hyperparameter mutation.
    fn update_config(&mut self, id: TrialId, config: &Config);

    /// Tear down the trial's trainable and release any capacity
    /// reservation made by [`Executor::admit`]. Safe to call for a
    /// trial that was admitted but never launched (placement failed).
    fn halt(&mut self, id: TrialId);

    /// Number of trials currently holding a live trainable.
    fn num_live(&self) -> usize;
}

/// Render a caught panic payload for an [`ExecEvent::Failed`] message.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic payload".into())
}

/// Build (and optionally restore) a trainable, converting panics into
/// launch errors so one bad constructor cannot take down the
/// coordinator thread — the runner marks the trial Errored and moves on.
fn build_trainable(
    factory: &TrainableFactory,
    trial: &Trial,
    restore: Option<Arc<[u8]>>,
) -> Result<Box<dyn Trainable>, String> {
    let config = &trial.config;
    let seed = trial.seed;
    let mut t = catch_unwind(AssertUnwindSafe(|| (factory)(config, seed)))
        .map_err(|p| format!("trainable construction panicked: {}", panic_msg(&*p)))?;
    if let Some(blob) = restore {
        catch_unwind(AssertUnwindSafe(|| t.restore(&blob)))
            .map_err(|p| format!("trainable restore panicked: {}", panic_msg(&*p)))??;
    }
    Ok(t)
}

/// Run one step with panic containment: a panicking trainable becomes a
/// step error (→ [`ExecEvent::Failed`] → `max_failures` recovery), not
/// a dead worker thread or a poisoned mutex.
fn step_contained(t: &mut Box<dyn Trainable>) -> Result<StepOutput, String> {
    catch_unwind(AssertUnwindSafe(|| t.step()))
        .unwrap_or_else(|p| Err(format!("trainable panicked: {}", panic_msg(&*p))))
}

// ---------------------------------------------------------------------------
// Discrete-event executor
// ---------------------------------------------------------------------------

// Completion times are ordered with `util::order::OrdF64` — finite by
// construction (step costs are clamped positive), but the order is
// total anyway, NaN sorting first, so a pathological `step_cost` can
// never panic the queue. One lawful float Ord lives in this codebase;
// tune-lint's `nan` rule keeps it that way.
use crate::util::order::OrdF64;

/// Discrete-event executor: virtual clock ordered by `step_cost`.
pub struct SimExecutor {
    factory: TrainableFactory,
    now: f64,
    seq: u64,
    /// (completion time, seq, trial, launch epoch).
    queue: BinaryHeap<Reverse<(OrdF64, u64, TrialId, u64)>>,
    live: HashMap<TrialId, Box<dyn Trainable>>,
    /// Launch generation per trial id. A halt + relaunch of the same id
    /// bumps it, so stale queue entries from a previous incarnation are
    /// discarded instead of stepping the new trainable (fault recovery
    /// relaunches ids while their old entries may still be queued).
    epoch: HashMap<TrialId, u64>,
    /// Planted (workload, shape) step-time multipliers — empty means
    /// every shape steps at 1x, the pre-hardware-aware behavior.
    factors: crate::ray::ShapeFactors,
    /// Shape key of the node each trial was last placed on
    /// ([`Executor::place_hint`]).
    hints: HashMap<TrialId, String>,
    /// Step-time multiplier frozen at launch from `factors` x the
    /// placement hint; relaunching on a different shape recomputes it.
    speed: HashMap<TrialId, f64>,
}

impl SimExecutor {
    /// Create a simulator over `factory`-built trainables.
    pub fn new(factory: TrainableFactory) -> Self {
        SimExecutor {
            factory,
            now: 0.0,
            seq: 0,
            queue: BinaryHeap::new(),
            live: HashMap::new(),
            epoch: HashMap::new(),
            factors: crate::ray::ShapeFactors::default(),
            hints: HashMap::new(),
            speed: HashMap::new(),
        }
    }

    /// Plant shape-dependent step times: a trial's virtual step cost is
    /// multiplied by `factors.factor(workload_class, placed shape key)`.
    /// Deterministic on the virtual clock — the offline stand-in for
    /// heterogeneous hardware.
    pub fn with_shape_factors(mut self, factors: crate::ray::ShapeFactors) -> Self {
        self.factors = factors;
        self
    }
}

impl Executor for SimExecutor {
    fn now(&self) -> f64 {
        self.now
    }

    fn place_hint(&mut self, id: TrialId, shape: &Resources) {
        self.hints.insert(id, crate::ray::shape_key(shape));
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Arc<[u8]>>) -> Result<(), String> {
        let t = build_trainable(&self.factory, trial, restore)?;
        *self.epoch.entry(trial.id).or_insert(0) += 1;
        let mult = self
            .hints
            .get(&trial.id)
            .map(|s| self.factors.factor(trial.workload_class(), s))
            .unwrap_or(1.0);
        self.speed.insert(trial.id, mult);
        self.live.insert(trial.id, t);
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        if let Some(t) = self.live.get(&id) {
            let mult = self.speed.get(&id).copied().unwrap_or(1.0);
            let done_at = self.now + (t.step_cost() * mult).max(1e-9);
            self.seq += 1;
            let epoch = self.epoch.get(&id).copied().unwrap_or(0);
            self.queue.push(Reverse((OrdF64(done_at), self.seq, id, epoch)));
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        while let Some(Reverse((OrdF64(at), _, id, epoch))) = self.queue.pop() {
            // Halted (or halted-then-relaunched) trials leave stale queue
            // entries; skip anything from a previous launch epoch.
            if self.epoch.get(&id).copied().unwrap_or(0) != epoch {
                continue;
            }
            let Some(t) = self.live.get_mut(&id) else { continue };
            self.now = self.now.max(at);
            return Some(match step_contained(t) {
                Ok(out) => ExecEvent::Stepped { trial: id, out },
                Err(error) => ExecEvent::Failed { trial: id, error },
            });
        }
        None
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        self.live.get_mut(&id).map(|t| t.save())
    }

    fn restore(&mut self, id: TrialId, blob: Arc<[u8]>) -> Result<(), String> {
        self.live.get_mut(&id).ok_or("trial not live")?.restore(&blob)
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        if let Some(t) = self.live.get_mut(&id) {
            t.update_config(config);
        }
    }

    fn halt(&mut self, id: TrialId) {
        self.live.remove(&id);
        self.hints.remove(&id);
        self.speed.remove(&id);
    }

    fn num_live(&self) -> usize {
        self.live.len()
    }
}

// ---------------------------------------------------------------------------
// Threaded executor
// ---------------------------------------------------------------------------

enum WorkerCmd {
    Step,
    Save(Sender<Vec<u8>>),
    Restore(Arc<[u8]>, Sender<Result<(), String>>),
    Update(Config),
    Halt,
}

struct Worker {
    tx: Sender<WorkerCmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Wall-clock executor: one OS thread per live trial (Ray's
/// process-per-trial model, in-process).
pub struct ThreadExecutor {
    factory: TrainableFactory,
    /// BTreeMap so the halt sweep in `Drop` walks trials in id order —
    /// shutdown is deterministic, not hash-order.
    workers: BTreeMap<TrialId, Worker>,
    event_tx: Sender<ExecEvent>,
    event_rx: Receiver<ExecEvent>,
    started: Instant,
}

impl ThreadExecutor {
    /// Create a thread-per-trial executor over `factory`-built trainables.
    pub fn new(factory: TrainableFactory) -> Self {
        let (event_tx, event_rx) = mpsc::channel();
        ThreadExecutor {
            factory,
            workers: BTreeMap::new(),
            event_tx,
            event_rx,
            started: Instant::now(),
        }
    }
}

impl Executor for ThreadExecutor {
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Arc<[u8]>>) -> Result<(), String> {
        let (tx, rx) = mpsc::channel::<WorkerCmd>();
        let factory = Arc::clone(&self.factory);
        let config = trial.config.clone();
        let seed = trial.seed;
        let id = trial.id;
        let events = self.event_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("trial-{id}"))
            .spawn(move || {
                // Construction and restore run with panic containment:
                // a dead worker thread would otherwise strand the runner
                // waiting on an event that can never arrive.
                let built = catch_unwind(AssertUnwindSafe(|| factory(&config, seed)))
                    .map_err(|p| format!("trainable construction panicked: {}", panic_msg(&*p)));
                let mut t = match built {
                    Ok(t) => t,
                    Err(error) => {
                        let _ = events.send(ExecEvent::Failed { trial: id, error });
                        return;
                    }
                };
                if let Some(blob) = restore {
                    let restored = catch_unwind(AssertUnwindSafe(|| t.restore(&blob)))
                        .unwrap_or_else(|p| {
                            Err(format!("trainable restore panicked: {}", panic_msg(&*p)))
                        });
                    if let Err(e) = restored {
                        let _ = events.send(ExecEvent::Failed { trial: id, error: e });
                        return;
                    }
                }
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        WorkerCmd::Step => {
                            let ev = match step_contained(&mut t) {
                                Ok(out) => ExecEvent::Stepped { trial: id, out },
                                Err(error) => ExecEvent::Failed { trial: id, error },
                            };
                            if events.send(ev).is_err() {
                                return;
                            }
                        }
                        WorkerCmd::Save(reply) => {
                            let _ = reply.send(t.save());
                        }
                        WorkerCmd::Restore(blob, reply) => {
                            let _ = reply.send(t.restore(&blob));
                        }
                        WorkerCmd::Update(cfg) => t.update_config(&cfg),
                        WorkerCmd::Halt => return,
                    }
                }
            })
            .map_err(|e| e.to_string())?;
        self.workers.insert(id, Worker { tx, handle: Some(handle) });
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(WorkerCmd::Step);
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        if self.workers.is_empty() {
            return None;
        }
        // In-flight events from just-halted workers are still valid to
        // receive; the runner filters by trial status.
        self.event_rx.recv().ok()
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        let w = self.workers.get(&id)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(WorkerCmd::Save(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    fn restore(&mut self, id: TrialId, blob: Arc<[u8]>) -> Result<(), String> {
        let w = self.workers.get(&id).ok_or("trial not live")?;
        let (reply_tx, reply_rx) = mpsc::channel();
        // Zero-copy: the Arc handle itself crosses the channel.
        w.tx.send(WorkerCmd::Restore(blob, reply_tx)).map_err(|e| e.to_string())?;
        reply_rx.recv().map_err(|e| e.to_string())?
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(WorkerCmd::Update(config.clone()));
        }
    }

    fn halt(&mut self, id: TrialId) {
        if let Some(mut w) = self.workers.remove(&id) {
            let _ = w.tx.send(WorkerCmd::Halt);
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }

    fn num_live(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadExecutor {
    fn drop(&mut self) {
        let ids: Vec<TrialId> = self.workers.keys().copied().collect();
        for id in ids {
            self.halt(id);
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded work-stealing pool machinery (shared by PoolExecutor and
// SharedPool, generic over the work key)
// ---------------------------------------------------------------------------

/// Key identifying one unit of poolable work: a plain [`TrialId`] for
/// the single-experiment [`PoolExecutor`], an `(ExpId, TrialId)` pair
/// for the hub-shared pool.
trait PoolKey: Copy + Eq + std::hash::Hash + Send + 'static {}
impl<T: Copy + Eq + std::hash::Hash + Send + 'static> PoolKey for T {}

/// Per-worker capacity vectors plus current reservations — the
/// executor-side half of resource admission. The cluster substrate
/// models the *nodes* trials lease; this models the *worker processes*
/// their trainables actually step on (e.g. 4 workers, two of them
/// holding a GPU). Admission is a first-fit vector fit reusing
/// [`Resources::fits`]; trainables still step on whichever thread
/// steals the request — the fleet bounds how many live trainables of
/// which shape coexist, not which thread runs them.
struct WorkerFleet<K> {
    /// Full capacity per worker (distinguishes Exhausted/Infeasible).
    total: Vec<Resources>,
    /// Unreserved remainder per worker.
    free: Vec<Resources>,
    /// Reservations: key -> (worker index, reserved demand).
    assigned: HashMap<K, (usize, Resources)>,
}

impl<K: PoolKey> WorkerFleet<K> {
    fn new(caps: Vec<Resources>) -> Self {
        WorkerFleet { free: caps.clone(), total: caps, assigned: HashMap::new() }
    }

    /// Scarce dimensions `total` offers that `demand` leaves idle:
    /// admission prefers the fitting worker that wastes the fewest (a
    /// CPU-only trial must not occupy the GPU worker's CPUs while a
    /// CPU-only worker has room — it would starve later GPU trials).
    fn scarce_waste(total: &Resources, demand: &Resources) -> usize {
        let mut waste = 0;
        if total.gpu > 0.0 && demand.gpu <= 0.0 {
            waste += 1;
        }
        for (k, v) in &total.custom {
            if *v > 0.0 && demand.custom.get(k).map_or(true, |d| *d <= 0.0) {
                waste += 1;
            }
        }
        waste
    }

    /// Reserve `demand` under `key` on the fitting worker that wastes
    /// the least scarce capacity (ties break to the lowest index —
    /// deterministic).
    fn admit(&mut self, key: K, demand: &Resources) -> Admission {
        if self.assigned.contains_key(&key) {
            // A re-launch without an intervening halt would double-book;
            // treat the existing reservation as authoritative.
            return Admission::Granted;
        }
        let mut best: Option<(usize, usize)> = None; // (waste, worker)
        for (w, f) in self.free.iter().enumerate() {
            if !f.fits(demand) {
                continue;
            }
            let waste = Self::scarce_waste(&self.total[w], demand);
            if best.map_or(true, |(b, _)| waste < b) {
                best = Some((waste, w));
            }
        }
        match best.map(|(_, w)| w) {
            Some(w) => {
                self.free[w].acquire(demand);
                self.assigned.insert(key, (w, demand.clone()));
                Admission::Granted
            }
            None if self.total.iter().any(|t| t.fits(demand)) => Admission::Exhausted,
            None => Admission::Infeasible,
        }
    }

    /// Release the reservation held under `key` (no-op if none).
    fn release(&mut self, key: &K) {
        if let Some((w, demand)) = self.assigned.remove(key) {
            self.free[w].release(&demand);
        }
    }
}

/// Per-trial mailbox state inside a pool.
enum Slot {
    /// Trainable parked between steps; synchronous ops may touch it.
    Idle(Box<dyn Trainable>),
    /// A worker checked the trainable out and is stepping it.
    Busy,
    /// Halted while a worker was mid-step; the worker drops the
    /// trainable (and removes this marker) at check-in.
    Halted,
}

/// Mailboxes + launch generations, guarded by one lock.
struct PoolState<K> {
    slots: HashMap<K, Slot>,
    /// Launch generation per key, bumped on every `launch`. Step
    /// requests carry the epoch they were issued under; a request from a
    /// previous incarnation of a relaunched key resolves as a skip
    /// instead of stepping the new trainable (fault recovery relaunches
    /// ids while their old requests may still sit in the injector).
    epochs: HashMap<K, u64>,
}

impl<K> Default for PoolState<K> {
    fn default() -> Self {
        PoolState { slots: HashMap::new(), epochs: HashMap::new() }
    }
}

/// State shared between the coordinator thread(s) and the pool workers.
struct PoolShared<K> {
    state: Mutex<PoolState<K>>,
    /// Signalled whenever a slot transitions out of `Busy` (check-in or
    /// halted-drop), waking synchronous ops parked in `with_idle` and
    /// relaunches parked in `launch_slot`.
    idle_cv: Condvar,
}

impl<K: PoolKey> PoolShared<K> {
    fn new() -> Self {
        PoolShared { state: Mutex::new(PoolState::default()), idle_cv: Condvar::new() }
    }

    /// Park a freshly built trainable in the key's mailbox, bumping the
    /// launch epoch. A relaunch can race a halted-mid-step worker; wait
    /// for the stale slot to clear so the worker cannot drop the new
    /// trainable.
    fn launch_slot(&self, key: K, t: Box<dyn Trainable>) {
        let mut st = self.state.lock().unwrap();
        while st.slots.contains_key(&key) {
            st = self.idle_cv.wait(st).unwrap();
        }
        *st.epochs.entry(key).or_insert(0) += 1;
        st.slots.insert(key, Slot::Idle(t));
    }

    /// The key's current launch epoch (0 if never launched).
    fn epoch_of(&self, key: K) -> u64 {
        self.state.lock().unwrap().epochs.get(&key).copied().unwrap_or(0)
    }

    /// Run `f` on the key's parked trainable, waiting out an in-flight
    /// step first. `None` if the key is not live.
    fn with_idle<R>(&self, key: K, f: impl FnOnce(&mut Box<dyn Trainable>) -> R) -> Option<R> {
        let mut st = self.state.lock().unwrap();
        loop {
            if matches!(st.slots.get(&key), Some(Slot::Busy)) {
                st = self.idle_cv.wait(st).unwrap();
                continue;
            }
            return match st.slots.get_mut(&key) {
                Some(Slot::Idle(t)) => Some(f(t)),
                _ => None,
            };
        }
    }

    /// Tear the key's trainable down (deferred to the worker's check-in
    /// when a step is in flight).
    fn halt_slot(&self, key: K) {
        let mut st = self.state.lock().unwrap();
        if matches!(st.slots.get(&key), Some(Slot::Busy)) {
            // Mid-step: leave a marker; the worker drops the trainable
            // and clears the slot at check-in.
            st.slots.insert(key, Slot::Halted);
        } else if !matches!(st.slots.get(&key), Some(Slot::Halted)) {
            st.slots.remove(&key);
            self.idle_cv.notify_all();
        }
    }

    /// Live (non-halted) slots satisfying `pred`.
    fn count_live(&self, pred: impl Fn(&K) -> bool) -> usize {
        self.state
            .lock()
            .unwrap()
            .slots
            // lint:allow(hash_iteration): order-insensitive count; PoolKey is Hash, not Ord
            .iter()
            .filter(|&(k, s)| pred(k) && !matches!(s, Slot::Halted))
            .count()
    }
}

/// Internal event stream: every queued step request produces exactly one
/// entry, so receivers can count in-flight work without timeouts.
enum RawEvent<K> {
    /// The checked-out trainable ran one step (success or error).
    Done { key: K, result: Result<StepOutput, String> },
    /// The request targeted a halted/stale key; no runner event.
    Skipped { key: K },
}

/// One pool worker: steal a key from the injector, check its trainable
/// out, step it (with panic containment), check it back in, emit the
/// event. The state lock is never held across a step, so a panicking
/// trainable cannot poison it.
fn pool_worker<K: PoolKey>(
    injector_rx: &Mutex<Receiver<(K, u64)>>,
    event_tx: &Sender<RawEvent<K>>,
    shared: &PoolShared<K>,
) {
    loop {
        // Holding the lock across recv is fine: at most one idle worker
        // parks inside recv; the rest park on the mutex and rotate in as
        // work arrives.
        let (key, epoch) = match injector_rx.lock().unwrap().recv() {
            Ok(req) => req,
            Err(_) => return, // injector closed: executor dropped
        };
        // Check out: Idle -> Busy. Requests from a previous launch epoch
        // and halted/missing keys are answered with a Skipped marker so
        // in-flight accounting stays exact.
        let taken = {
            let mut st = shared.state.lock().unwrap();
            if st.epochs.get(&key).copied().unwrap_or(0) != epoch {
                None
            } else {
                match st.slots.remove(&key) {
                    Some(Slot::Idle(t)) => {
                        st.slots.insert(key, Slot::Busy);
                        Some(t)
                    }
                    Some(other) => {
                        st.slots.insert(key, other);
                        None
                    }
                    None => None,
                }
            }
        };
        let Some(mut t) = taken else {
            if event_tx.send(RawEvent::Skipped { key }).is_err() {
                return;
            }
            continue;
        };

        let result = step_contained(&mut t);

        // Check in: Busy -> Idle, unless halted mid-step (drop it). A
        // panicked trainable checks in too — the Failed event routes
        // through handle_failure, which halts and relaunches it from
        // its last checkpoint.
        let halted = {
            let mut st = shared.state.lock().unwrap();
            match st.slots.remove(&key) {
                Some(Slot::Busy) => {
                    st.slots.insert(key, Slot::Idle(t));
                    false
                }
                _ => true,
            }
        };
        shared.idle_cv.notify_all();

        let event = if halted {
            RawEvent::Skipped { key }
        } else {
            RawEvent::Done { key, result }
        };
        if event_tx.send(event).is_err() {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Single-experiment bounded pool executor
// ---------------------------------------------------------------------------

/// Wall-clock executor with a **bounded** worker pool: N workers service
/// M ≫ N live trials. Step requests go through a shared injector queue
/// that idle workers steal from; each trial's trainable lives in a
/// mailbox slot that is checked out for the duration of one step.
/// Synchronous operations (`save`/`restore`/`update_config`) briefly wait
/// for an in-flight step to park, preserving the "idle between steps"
/// contract the runner relies on.
///
/// This decouples concurrency from trial count: a 10 000-trial experiment
/// runs on `num_cpus` threads instead of 10 000.
pub struct PoolExecutor {
    factory: TrainableFactory,
    shared: Arc<PoolShared<TrialId>>,
    /// Work queue of (trial, launch epoch) feeding the workers; dropped
    /// first on teardown so the workers observe a closed channel and
    /// exit.
    injector_tx: Option<Sender<(TrialId, u64)>>,
    event_rx: Receiver<RawEvent<TrialId>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Step requests queued but not yet answered by a [`RawEvent`].
    queued: usize,
    started: Instant,
    /// Per-worker capacity vectors (None = capacity-oblivious: live
    /// trials are bounded only by the cluster substrate, the original
    /// M ≫ N pool contract).
    fleet: Option<WorkerFleet<TrialId>>,
}

impl PoolExecutor {
    /// Spawn a pool of `workers` (min 1) threads over `factory`-built
    /// trainables, capacity-oblivious (admission always granted).
    pub fn new(factory: TrainableFactory, workers: usize) -> Self {
        Self::build(factory, workers.max(1), None)
    }

    /// Spawn one worker per capacity vector in `caps`; admission
    /// becomes a first-fit vector fit against those capacities, so e.g.
    /// `[{cpu:8, gpu:1}, {cpu:8}]` holds at most two 0.5-GPU trials
    /// (both on worker 0) however many CPU trials sit alongside them.
    pub fn with_capacities(factory: TrainableFactory, caps: Vec<Resources>) -> Self {
        let caps = if caps.is_empty() { vec![Resources::cpu(1.0)] } else { caps };
        let workers = caps.len();
        Self::build(factory, workers, Some(WorkerFleet::new(caps)))
    }

    fn build(
        factory: TrainableFactory,
        workers: usize,
        fleet: Option<WorkerFleet<TrialId>>,
    ) -> Self {
        let (injector_tx, injector_rx) = mpsc::channel::<(TrialId, u64)>();
        let injector_rx = Arc::new(Mutex::new(injector_rx));
        let (event_tx, event_rx) = mpsc::channel::<RawEvent<TrialId>>();
        let shared = Arc::new(PoolShared::new());

        let handles = (0..workers)
            .map(|w| {
                let injector_rx = Arc::clone(&injector_rx);
                let event_tx = event_tx.clone();
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tune-pool-{w}"))
                    .spawn(move || pool_worker(&injector_rx, &event_tx, &shared))
                    .expect("spawn pool worker")
            })
            .collect();

        PoolExecutor {
            factory,
            shared,
            injector_tx: Some(injector_tx),
            event_rx,
            workers: handles,
            queued: 0,
            started: Instant::now(),
            fleet,
        }
    }

    /// Number of worker threads in the pool.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Executor for PoolExecutor {
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn admit(&mut self, id: TrialId, demand: &Resources) -> Admission {
        match &mut self.fleet {
            Some(f) => f.admit(id, demand),
            None => Admission::Granted,
        }
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Arc<[u8]>>) -> Result<(), String> {
        let t = build_trainable(&self.factory, trial, restore)?;
        self.shared.launch_slot(trial.id, t);
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        let epoch = self.shared.epoch_of(id);
        if let Some(tx) = &self.injector_tx {
            if tx.send((id, epoch)).is_ok() {
                self.queued += 1;
            }
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        while self.queued > 0 {
            match self.event_rx.recv() {
                Ok(RawEvent::Done { key, result }) => {
                    self.queued -= 1;
                    return Some(match result {
                        Ok(out) => ExecEvent::Stepped { trial: key, out },
                        Err(error) => ExecEvent::Failed { trial: key, error },
                    });
                }
                Ok(RawEvent::Skipped { .. }) => self.queued -= 1,
                Err(_) => return None,
            }
        }
        None
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        self.shared.with_idle(id, |t| t.save())
    }

    fn restore(&mut self, id: TrialId, blob: Arc<[u8]>) -> Result<(), String> {
        self.shared
            .with_idle(id, |t| t.restore(&blob))
            .unwrap_or_else(|| Err("trial not live".into()))
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        self.shared.with_idle(id, |t| t.update_config(config));
    }

    fn halt(&mut self, id: TrialId) {
        if let Some(f) = &mut self.fleet {
            f.release(&id);
        }
        self.shared.halt_slot(id);
    }

    fn num_live(&self) -> usize {
        self.shared.count_live(|_| true)
    }
}

impl Drop for PoolExecutor {
    fn drop(&mut self) {
        // Close the injector; workers drain and exit on the closed
        // channel. Trainables still parked in slots drop with the map.
        self.injector_tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Shared multi-experiment pool
// ---------------------------------------------------------------------------

/// Identifies one experiment multiplexed onto a [`SharedPool`].
pub type ExpId = u32;

/// Work key on the shared pool: (experiment, trial).
type SharedKey = (ExpId, TrialId);

/// Outcome of one [`SharedPool`] / [`SharedPoolClient`] poll by a hub.
#[derive(Debug)]
pub enum PoolPoll {
    /// A completion event for the given experiment.
    Event(ExpId, ExecEvent),
    /// No step request is in flight for any polled experiment.
    Idle,
    /// In-flight work exists but nothing completed within the timeout.
    Timeout,
}

/// Per-experiment routing state: events received on the single shared
/// channel are credited to the owning experiment, and those destined
/// for a handle other than the caller are buffered until that
/// experiment is driven.
/// Both maps are BTreeMaps: `pop_any` scans buffers in key order, so
/// which experiment's event a `drive_any` wakes on is a deterministic
/// function of the buffered state, not of sip hashing. (Per-experiment
/// fingerprints never see this order, but hub-level traces do.)
struct Router {
    buffers: BTreeMap<ExpId, VecDeque<ExecEvent>>,
    queued: BTreeMap<ExpId, usize>,
    total_queued: usize,
}

impl Router {
    fn inc(&mut self, exp: ExpId) {
        *self.queued.entry(exp).or_insert(0) += 1;
        self.total_queued += 1;
    }
    fn dec(&mut self, exp: ExpId) {
        if let Some(n) = self.queued.get_mut(&exp) {
            *n = n.saturating_sub(1);
        }
        self.total_queued = self.total_queued.saturating_sub(1);
    }
    fn pop_any(&mut self) -> Option<(ExpId, ExecEvent)> {
        for (exp, q) in self.buffers.iter_mut() {
            if let Some(ev) = q.pop_front() {
                return Some((*exp, ev));
            }
        }
        None
    }
    /// `pop_any` restricted to a client's owned experiments (same
    /// key-order determinism, scoped to one shard).
    fn pop_owned(&mut self, owned: &BTreeSet<ExpId>) -> Option<(ExpId, ExecEvent)> {
        for exp in owned {
            if let Some(ev) = self.buffers.get_mut(exp).and_then(|q| q.pop_front()) {
                return Some((*exp, ev));
            }
        }
        None
    }
    /// In-flight request count across a client's owned experiments.
    fn queued_for(&self, owned: &BTreeSet<ExpId>) -> usize {
        owned.iter().map(|e| self.queued.get(e).copied().unwrap_or(0)).sum()
    }
}

struct SharedPoolInner {
    shared: PoolShared<SharedKey>,
    /// `None` after shutdown: late `request_step`s are dropped silently,
    /// matching a closed single-experiment pool.
    injector_tx: Mutex<Option<Sender<(SharedKey, u64)>>>,
    event_rx: Mutex<Receiver<RawEvent<SharedKey>>>,
    router: Mutex<Router>,
    /// Shared per-worker capacity vectors; every experiment's handle
    /// admits against the same fleet (None = capacity-oblivious).
    fleet: Mutex<Option<WorkerFleet<SharedKey>>>,
    /// Pool-wide experiment-id allocator, shared so every
    /// [`SharedPoolClient`] hands out ids from one namespace.
    next_exp: Mutex<ExpId>,
}

impl SharedPoolInner {
    /// Settle a raw event under ONE router lock: decrement the owning
    /// experiment's in-flight count and, for `Done` events, buffer the
    /// runner-visible [`ExecEvent`] for that experiment. Accounting and
    /// buffering must be atomic — were they split, a sibling handle
    /// could observe `queued == 0` with an empty buffer in the window
    /// between them and wrongly conclude its experiment is idle.
    fn route(&self, raw: RawEvent<SharedKey>) {
        let mut r = self.router.lock().unwrap();
        match raw {
            RawEvent::Skipped { key: (exp, _) } => r.dec(exp),
            RawEvent::Done { key: (exp, trial), result } => {
                r.dec(exp);
                let ev = match result {
                    Ok(out) => ExecEvent::Stepped { trial, out },
                    Err(error) => ExecEvent::Failed { trial, error },
                };
                r.buffers.entry(exp).or_default().push_back(ev);
            }
        }
    }

    /// Allocate the next experiment id from the pool-wide namespace and
    /// register its router entries, then wrap it in an executor handle.
    /// Shared by [`SharedPool::handle`] and [`SharedPoolClient::handle`]
    /// so two shards can never mint the same id.
    fn new_handle(self: &Arc<Self>, factory: TrainableFactory) -> SharedPoolHandle {
        let exp = {
            let mut next = self.next_exp.lock().unwrap();
            let exp = *next;
            *next += 1;
            exp
        };
        {
            let mut r = self.router.lock().unwrap();
            r.buffers.entry(exp).or_default();
            r.queued.entry(exp).or_insert(0);
        }
        SharedPoolHandle {
            inner: Arc::clone(self),
            factory,
            exp,
            started: Instant::now(),
        }
    }
}

/// ONE bounded worker pool multiplexed across many experiments — the
/// substrate under [`crate::coordinator::hub::ExperimentHub`]. Every
/// experiment gets its own [`SharedPoolHandle`] (an [`Executor`] with a
/// private trial-id namespace, clock and trainable factory); the pool
/// fans all of their step requests into the same injector queue and
/// routes completions back to the owning experiment.
///
/// Drop order: drop (or finish) the handles' owners before the pool —
/// the pool's `Drop` closes the injector and joins its workers.
pub struct SharedPool {
    inner: Arc<SharedPoolInner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl SharedPool {
    /// Spawn a shared pool of `workers` (min 1) threads,
    /// capacity-oblivious (admission always granted).
    pub fn new(workers: usize) -> Self {
        Self::build(workers.max(1), None)
    }

    /// Spawn one shared worker per capacity vector in `caps`; every
    /// experiment's handle admits against the same fleet, so resource
    /// admission is global across the multiplexed experiments.
    pub fn with_capacities(caps: Vec<Resources>) -> Self {
        let caps = if caps.is_empty() { vec![Resources::cpu(1.0)] } else { caps };
        let workers = caps.len();
        Self::build(workers, Some(WorkerFleet::new(caps)))
    }

    /// Sum of worker capacities (None when capacity-oblivious) — what
    /// the hub splits into per-experiment resource shares.
    pub fn total_capacity(&self) -> Option<Resources> {
        self.inner.fleet.lock().unwrap().as_ref().map(|f| {
            let mut sum = Resources::default();
            for cap in &f.total {
                sum.release(cap);
            }
            sum
        })
    }

    fn build(workers: usize, fleet: Option<WorkerFleet<SharedKey>>) -> Self {
        let (injector_tx, injector_rx) = mpsc::channel::<(SharedKey, u64)>();
        let injector_rx = Arc::new(Mutex::new(injector_rx));
        let (event_tx, event_rx) = mpsc::channel::<RawEvent<SharedKey>>();
        let inner = Arc::new(SharedPoolInner {
            shared: PoolShared::new(),
            injector_tx: Mutex::new(Some(injector_tx)),
            event_rx: Mutex::new(event_rx),
            router: Mutex::new(Router {
                buffers: BTreeMap::new(),
                queued: BTreeMap::new(),
                total_queued: 0,
            }),
            fleet: Mutex::new(fleet),
            next_exp: Mutex::new(0),
        });
        let handles = (0..workers)
            .map(|w| {
                let injector_rx = Arc::clone(&injector_rx);
                let event_tx = event_tx.clone();
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("tune-hub-pool-{w}"))
                    .spawn(move || pool_worker(&injector_rx, &event_tx, &inner.shared))
                    .expect("spawn shared pool worker")
            })
            .collect();
        SharedPool { inner, workers: handles }
    }

    /// Number of worker threads in the pool.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Create the executor handle for one experiment. `factory` is
    /// per-experiment: different experiments can run entirely different
    /// workloads on the same pool.
    pub fn handle(&mut self, factory: TrainableFactory) -> SharedPoolHandle {
        self.inner.new_handle(factory)
    }

    /// Create a shard-scoped view of this pool. The client allocates
    /// experiment ids from the same pool-wide namespace, but its
    /// [`SharedPoolClient::poll`] only ever *returns* events for
    /// experiments registered through it — a sharded hub gives each
    /// shard one client so N shards can drive one worker fleet
    /// concurrently without stealing each other's completions.
    /// `capacity_frac` scales the capacity total the shard's fair-share
    /// math sees (1/N for N equal shards; 1.0 for a sole owner).
    pub fn client(&self, capacity_frac: f64) -> SharedPoolClient {
        SharedPoolClient {
            inner: Arc::clone(&self.inner),
            owned: BTreeSet::new(),
            workers: self.workers.len(),
            capacity_frac: if capacity_frac.is_finite() && capacity_frac > 0.0 {
                capacity_frac.min(1.0)
            } else {
                1.0
            },
        }
    }

    /// Sole-owner event pump: the next completion event from *any*
    /// experiment. Returns [`PoolPoll::Idle`] when no request is in
    /// flight anywhere (every experiment is quiescent) and
    /// [`PoolPoll::Timeout`] when in-flight work exists but nothing
    /// completed within `timeout`. Sharded callers use
    /// [`SharedPoolClient::poll`] instead.
    pub fn poll(&self, timeout: Duration) -> PoolPoll {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let mut r = self.inner.router.lock().unwrap();
                if let Some((exp, ev)) = r.pop_any() {
                    return PoolPoll::Event(exp, ev);
                }
                if r.total_queued == 0 {
                    return PoolPoll::Idle;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return PoolPoll::Timeout;
            }
            let raw = {
                let rx = self.inner.event_rx.lock().unwrap();
                match rx.recv_timeout(deadline - now) {
                    Ok(raw) => raw,
                    Err(RecvTimeoutError::Timeout) => return PoolPoll::Timeout,
                    Err(RecvTimeoutError::Disconnected) => return PoolPoll::Idle,
                }
            };
            // Settled into the router; the loop top pops it (or reports
            // Idle if it was a skip that drained the last request).
            self.inner.route(raw);
        }
    }
}

impl Drop for SharedPool {
    fn drop(&mut self) {
        // Close the injector; workers drain and exit on the closed
        // channel. Handles that outlive the pool see their sends fail
        // silently (same contract as a halted trial).
        self.inner.injector_tx.lock().unwrap().take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// One shard's view of a [`SharedPool`]: hands out experiment handles
/// from the pool-wide id namespace and pumps events for exactly the
/// experiments it created. Many clients can poll the same pool
/// concurrently — the single raw-event channel is drained
/// cooperatively: whichever client receives a raw event settles it
/// into the router's per-experiment buffer (under the same lock as the
/// in-flight accounting), where the owning client's next buffer scan
/// picks it up. A client therefore never drops or steals a sibling
/// shard's completion; at worst it does the routing work for it.
///
/// Drop order mirrors the pool's: finish the client's experiment
/// owners before dropping the [`SharedPool`] that spawned it.
pub struct SharedPoolClient {
    inner: Arc<SharedPoolInner>,
    owned: BTreeSet<ExpId>,
    workers: usize,
    capacity_frac: f64,
}

impl SharedPoolClient {
    /// Create the executor handle for one experiment and take ownership
    /// of its event stream (this client's `poll` is now the only pump
    /// that returns the experiment's events).
    pub fn handle(&mut self, factory: TrainableFactory) -> SharedPoolHandle {
        let handle = self.inner.new_handle(factory);
        self.owned.insert(handle.exp_id());
        handle
    }

    /// Number of worker threads in the underlying pool (the whole
    /// fleet — shards share workers, not split them).
    pub fn num_workers(&self) -> usize {
        self.workers
    }

    /// This shard's slice of the fleet capacity: the pool total scaled
    /// by `capacity_frac` (None when capacity-oblivious). Keeps N
    /// shards' independent fair-share splits from collectively
    /// oversubscribing one fleet.
    pub fn total_capacity(&self) -> Option<Resources> {
        self.inner.fleet.lock().unwrap().as_ref().map(|f| {
            let mut sum = Resources::default();
            for cap in &f.total {
                sum.release(cap);
            }
            sum.scaled(self.capacity_frac)
        })
    }

    /// Shard-scoped event pump: the next completion event for an
    /// experiment created through this client. [`PoolPoll::Idle`] when
    /// none of the owned experiments has a request in flight (other
    /// shards' traffic does not keep this shard awake);
    /// [`PoolPoll::Timeout`] when owned work exists but nothing owned
    /// completed within `timeout`. Receives in short slices so one
    /// shard blocked on the channel cannot strand a sibling whose
    /// event it has already drained into the router.
    pub fn poll(&self, timeout: Duration) -> PoolPoll {
        let deadline = Instant::now() + timeout;
        loop {
            {
                let mut r = self.inner.router.lock().unwrap();
                if let Some((exp, ev)) = r.pop_owned(&self.owned) {
                    return PoolPoll::Event(exp, ev);
                }
                if r.queued_for(&self.owned) == 0 {
                    return PoolPoll::Idle;
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return PoolPoll::Timeout;
            }
            let slice = (deadline - now).min(Duration::from_millis(5));
            let recv = {
                let rx = self.inner.event_rx.lock().unwrap();
                rx.recv_timeout(slice)
            };
            match recv {
                // Settle into the router: if it is ours the loop top
                // pops it; a sibling's event lands in their buffer.
                Ok(raw) => self.inner.route(raw),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return PoolPoll::Idle,
            }
        }
    }
}

/// One experiment's view of a [`SharedPool`]: a full [`Executor`] whose
/// trial ids live in a private namespace, with a wall clock starting at
/// handle creation (so a later-submitted experiment's `now()` starts at
/// zero, keeping `max_experiment_time_s` per-experiment).
pub struct SharedPoolHandle {
    inner: Arc<SharedPoolInner>,
    factory: TrainableFactory,
    exp: ExpId,
    started: Instant,
}

impl SharedPoolHandle {
    /// The experiment id this handle routes under.
    pub fn exp_id(&self) -> ExpId {
        self.exp
    }
}

impl Executor for SharedPoolHandle {
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn admit(&mut self, id: TrialId, demand: &Resources) -> Admission {
        match self.inner.fleet.lock().unwrap().as_mut() {
            Some(f) => f.admit((self.exp, id), demand),
            None => Admission::Granted,
        }
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Arc<[u8]>>) -> Result<(), String> {
        let t = build_trainable(&self.factory, trial, restore)?;
        self.inner.shared.launch_slot((self.exp, trial.id), t);
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        let key = (self.exp, id);
        let epoch = self.inner.shared.epoch_of(key);
        let guard = self.inner.injector_tx.lock().unwrap();
        if let Some(tx) = guard.as_ref() {
            if tx.send((key, epoch)).is_ok() {
                self.inner.router.lock().unwrap().inc(self.exp);
            }
        }
    }

    /// Standalone event wait (a hub uses [`SharedPool::poll`] or
    /// [`SharedPoolClient::poll`] instead and feeds events in). Every received event is settled into the
    /// router's per-experiment buffers under one lock, and the loop top
    /// pops this handle's buffer — with a short receive timeout so a
    /// sibling handle draining the channel concurrently cannot strand
    /// this one.
    fn next_event(&mut self) -> Option<ExecEvent> {
        loop {
            {
                let mut r = self.inner.router.lock().unwrap();
                if let Some(ev) =
                    r.buffers.get_mut(&self.exp).and_then(|q| q.pop_front())
                {
                    return Some(ev);
                }
                if r.queued.get(&self.exp).copied().unwrap_or(0) == 0 {
                    return None;
                }
            }
            let recv = {
                let rx = self.inner.event_rx.lock().unwrap();
                rx.recv_timeout(Duration::from_millis(10))
            };
            match recv {
                Ok(raw) => self.inner.route(raw),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return None,
            }
        }
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        self.inner.shared.with_idle((self.exp, id), |t| t.save())
    }

    fn restore(&mut self, id: TrialId, blob: Arc<[u8]>) -> Result<(), String> {
        self.inner
            .shared
            .with_idle((self.exp, id), |t| t.restore(&blob))
            .unwrap_or_else(|| Err("trial not live".into()))
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        self.inner.shared.with_idle((self.exp, id), |t| t.update_config(config));
    }

    fn halt(&mut self, id: TrialId) {
        if let Some(f) = self.inner.fleet.lock().unwrap().as_mut() {
            f.release(&(self.exp, id));
        }
        self.inner.shared.halt_slot((self.exp, id));
    }

    fn num_live(&self) -> usize {
        let exp = self.exp;
        self.inner.shared.count_live(|(e, _)| *e == exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::ParamValue;
    use crate::ray::Resources;
    use crate::trainable::factory;
    use crate::trainable::synthetic::ConstTrainable;

    fn mk_trial(id: TrialId, cost: f64) -> Trial {
        let mut c = Config::new();
        c.insert("step_cost".into(), ParamValue::F64(cost));
        Trial::new(id, c, Resources::cpu(1.0), id)
    }

    fn const_factory() -> TrainableFactory {
        factory(|c, s| Box::new(ConstTrainable::new(c, s)))
    }

    #[test]
    fn sim_orders_by_virtual_time() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 5.0), None).unwrap();
        ex.launch(&mk_trial(2, 1.0), None).unwrap();
        ex.request_step(1);
        ex.request_step(2);
        // Trial 2 (cost 1) completes before trial 1 (cost 5).
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 2),
            e => panic!("{e:?}"),
        }
        assert!((ex.now() - 1.0).abs() < 1e-9);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 1),
            e => panic!("{e:?}"),
        }
        assert!((ex.now() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sim_halt_discards_stale_events() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        assert!(ex.next_event().is_none());
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn sim_relaunch_does_not_consume_stale_entry() {
        // Fault recovery halts and relaunches the same trial id while the
        // old step entry is still queued: the stale entry must NOT step
        // the new incarnation (it would double the trial's step stream).
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        assert!(ex.next_event().is_none(), "stale pre-relaunch entry was executed");
        // The relaunched trial still works normally.
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn sim_save_restore() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.next_event();
        let blob = ex.save(1).unwrap();
        ex.launch(&mk_trial(2, 1.0), Some(blob.into())).unwrap();
        ex.request_step(2);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 2.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn threaded_steps_flow() {
        let mut ex = ThreadExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, out } => {
                assert_eq!(trial, 1);
                assert_eq!(out.metrics["iters"], 1.0);
            }
            e => panic!("{e:?}"),
        }
        let blob = ex.save(1).unwrap();
        assert_eq!(u64::from_le_bytes(blob.try_into().unwrap()), 1);
        ex.halt(1);
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn threaded_parallel_trials() {
        let mut ex = ThreadExecutor::new(const_factory());
        for id in 0..8 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
            ex.request_step(id);
        }
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..8 {
            match ex.next_event().unwrap() {
                ExecEvent::Stepped { trial, .. } => {
                    seen.insert(trial);
                }
                e => panic!("{e:?}"),
            }
        }
        assert_eq!(seen.len(), 8);
    }

    #[test]
    fn threaded_restore_in_place() {
        let mut ex = ThreadExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        for _ in 0..3 {
            ex.request_step(1);
            ex.next_event();
        }
        ex.restore(1, Arc::from(&0u64.to_le_bytes()[..])).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }

    /// Panics on every `step`; used by the containment tests.
    struct PanicTrainable;
    impl Trainable for PanicTrainable {
        fn step(&mut self) -> Result<StepOutput, String> {
            panic!("kaboom");
        }
        fn save(&mut self) -> Vec<u8> {
            Vec::new()
        }
        fn restore(&mut self, _blob: &[u8]) -> Result<(), String> {
            Ok(())
        }
    }

    fn panicky_factory() -> TrainableFactory {
        // Config key "panic" selects the panicking trainable.
        factory(|c, s| {
            if c.contains_key("panic") {
                Box::new(PanicTrainable)
            } else {
                Box::new(ConstTrainable::new(c, s))
            }
        })
    }

    fn mk_panic_trial(id: TrialId) -> Trial {
        let mut c = Config::new();
        c.insert("panic".into(), ParamValue::Bool(true));
        Trial::new(id, c, Resources::cpu(1.0), id)
    }

    #[test]
    fn pool_step_panic_surfaces_as_failed_and_pool_survives() {
        // Regression: a panicking trainable used to kill the worker (or
        // poison the shared mutex); now it must surface as Failed and
        // leave the pool fully operational for other trials.
        let mut ex = PoolExecutor::new(panicky_factory(), 1);
        ex.launch(&mk_panic_trial(7), None).unwrap();
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(7);
        ex.request_step(1);
        let mut failed = false;
        let mut stepped = false;
        for _ in 0..2 {
            match ex.next_event().unwrap() {
                ExecEvent::Failed { trial, error } => {
                    assert_eq!(trial, 7);
                    assert!(error.contains("panicked"), "{error}");
                    assert!(error.contains("kaboom"), "{error}");
                    failed = true;
                }
                ExecEvent::Stepped { trial, .. } => {
                    assert_eq!(trial, 1);
                    stepped = true;
                }
            }
        }
        assert!(failed && stepped);
        // The shared state is not poisoned: synchronous ops still work.
        assert!(ex.save(1).is_some());
        ex.halt(7);
        ex.halt(1);
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn threaded_step_panic_surfaces_as_failed() {
        let mut ex = ThreadExecutor::new(panicky_factory());
        ex.launch(&mk_panic_trial(3), None).unwrap();
        ex.request_step(3);
        match ex.next_event().unwrap() {
            ExecEvent::Failed { trial, error } => {
                assert_eq!(trial, 3);
                assert!(error.contains("panicked"), "{error}");
            }
            e => panic!("{e:?}"),
        }
        ex.halt(3);
    }

    #[test]
    fn pool_completes_64_trials_with_4_workers() {
        // M = 64 live trials over N = 4 workers: every trial must step to
        // completion without a dedicated thread.
        let mut ex = PoolExecutor::new(const_factory(), 4);
        assert_eq!(ex.num_workers(), 4);
        for id in 0..64 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
        }
        assert_eq!(ex.num_live(), 64);
        let steps_per_trial = 3u64;
        let mut counts = std::collections::BTreeMap::new();
        for round in 0..steps_per_trial {
            for id in 0..64 {
                ex.request_step(id);
            }
            for _ in 0..64 {
                match ex.next_event().unwrap() {
                    ExecEvent::Stepped { trial, out } => {
                        assert!(out.metrics["iters"] >= (round + 1) as f64);
                        *counts.entry(trial).or_insert(0u64) += 1;
                    }
                    e => panic!("{e:?}"),
                }
            }
        }
        assert_eq!(counts.len(), 64);
        assert!(counts.values().all(|&c| c == steps_per_trial));
        for id in 0..64 {
            ex.halt(id);
        }
        assert_eq!(ex.num_live(), 0);
        assert!(ex.next_event().is_none());
    }

    #[test]
    fn pool_save_restore_update_matches_threaded() {
        // The same command sequence must be observationally identical on
        // the pool and the thread-per-trial executor.
        fn drive(ex: &mut dyn Executor) -> (Vec<f64>, Vec<u8>, f64) {
            ex.launch(&mk_trial(1, 0.0), None).unwrap();
            let mut iters = Vec::new();
            for _ in 0..3 {
                ex.request_step(1);
                match ex.next_event().unwrap() {
                    ExecEvent::Stepped { out, .. } => iters.push(out.metrics["iters"]),
                    e => panic!("{e:?}"),
                }
            }
            let blob = ex.save(1).unwrap();
            // Roll back to iteration 1 and mutate the config in place.
            ex.restore(1, Arc::from(&1u64.to_le_bytes()[..])).unwrap();
            let mut cfg = Config::new();
            cfg.insert("step_cost".into(), ParamValue::F64(2.0));
            ex.update_config(1, &cfg);
            ex.request_step(1);
            let after = match ex.next_event().unwrap() {
                ExecEvent::Stepped { out, .. } => out.metrics["iters"],
                e => panic!("{e:?}"),
            };
            ex.halt(1);
            (iters, blob, after)
        }
        let mut pool = PoolExecutor::new(const_factory(), 2);
        let mut threads = ThreadExecutor::new(const_factory());
        assert_eq!(drive(&mut pool), drive(&mut threads));
    }

    #[test]
    fn pool_halt_discards_pending_requests() {
        let mut ex = PoolExecutor::new(const_factory(), 1);
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        // The queued request resolves as a skip, never a runner event.
        assert!(ex.next_event().is_none());
        assert_eq!(ex.num_live(), 0);
        // Relaunching the same trial id afterwards is clean.
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn pool_relaunch_does_not_consume_stale_request() {
        // A trainable slow enough to pin the single worker while we
        // halt + relaunch another trial whose request is still queued.
        struct Slow(u64);
        impl Trainable for Slow {
            fn step(&mut self) -> Result<StepOutput, String> {
                std::thread::sleep(std::time::Duration::from_millis(100));
                self.0 += 1;
                Ok(StepOutput::of(&[("iters", self.0 as f64)]))
            }
            fn save(&mut self) -> Vec<u8> {
                self.0.to_le_bytes().to_vec()
            }
            fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
                self.0 = u64::from_le_bytes(blob.try_into().map_err(|_| "bad blob")?);
                Ok(())
            }
        }
        let factory: TrainableFactory = factory(|c, s| {
            if c.contains_key("slow") {
                Box::new(Slow(0))
            } else {
                Box::new(ConstTrainable::new(c, s))
            }
        });
        let mut ex = PoolExecutor::new(factory, 1);
        let mut slow_cfg = Config::new();
        slow_cfg.insert("slow".into(), ParamValue::Bool(true));
        let blocker = Trial::new(99, slow_cfg, Resources::cpu(1.0), 0);
        ex.launch(&blocker, None).unwrap();
        ex.request_step(99); // pins the only worker for ~100ms

        // Victim: request queued behind the blocker, then halt + relaunch
        // (the fault-recovery sequence) before the worker reaches it.
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        ex.launch(&mk_trial(1, 0.0), None).unwrap();

        // Blocker's event arrives; the victim's stale request must
        // resolve as a skip, never as a step of the new incarnation.
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 99),
            e => panic!("{e:?}"),
        }
        assert!(ex.next_event().is_none(), "stale pre-relaunch request was executed");
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, out } => {
                assert_eq!(trial, 1);
                assert_eq!(out.metrics["iters"], 1.0);
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn pool_single_worker_serializes_m_trials() {
        let mut ex = PoolExecutor::new(const_factory(), 1);
        for id in 0..16 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
            ex.request_step(id);
        }
        let mut seen = std::collections::BTreeSet::new();
        while let Some(ev) = ex.next_event() {
            match ev {
                ExecEvent::Stepped { trial, .. } => {
                    seen.insert(trial);
                }
                e => panic!("{e:?}"),
            }
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn pool_capacity_admission_is_a_vector_fit() {
        // Workers: one GPU-bearing, one CPU-only.
        let mut ex = PoolExecutor::with_capacities(
            const_factory(),
            vec![Resources::cpu_gpu(2.0, 1.0), Resources::cpu(2.0)],
        );
        assert_eq!(ex.num_workers(), 2);
        let half_gpu = Resources::cpu_gpu(1.0, 0.5);
        // Two half-GPU trials fit (both on worker 0), a third is
        // Exhausted (worker 0 full, worker 1 has no GPU), and a
        // 2-GPU demand can never run here.
        assert_eq!(ex.admit(1, &half_gpu), Admission::Granted);
        assert_eq!(ex.admit(2, &half_gpu), Admission::Granted);
        assert_eq!(ex.admit(3, &half_gpu), Admission::Exhausted);
        assert_eq!(ex.admit(4, &Resources::cpu_gpu(1.0, 2.0)), Admission::Infeasible);
        // CPU-only demands still land on worker 1.
        assert_eq!(ex.admit(5, &Resources::cpu(2.0)), Admission::Granted);
        // Halt releases the reservation; the parked demand fits again.
        ex.halt(1);
        assert_eq!(ex.admit(3, &half_gpu), Admission::Granted);
        // Re-admitting an already-admitted trial is idempotent.
        assert_eq!(ex.admit(3, &half_gpu), Admission::Granted);
    }

    #[test]
    fn pool_capacity_prefers_workers_without_scarce_dimensions() {
        // CPU-only demands must not squat on the GPU worker while the
        // CPU-only worker has room — that would starve later GPU trials.
        let mut ex = PoolExecutor::with_capacities(
            const_factory(),
            vec![Resources::cpu_gpu(2.0, 1.0), Resources::cpu(2.0)],
        );
        assert_eq!(ex.admit(1, &Resources::cpu(1.0)), Admission::Granted);
        assert_eq!(ex.admit(2, &Resources::cpu(1.0)), Admission::Granted);
        // Worker 1 (CPU-only) absorbed both; the GPU worker is intact.
        assert_eq!(ex.admit(3, &Resources::cpu_gpu(2.0, 1.0)), Admission::Granted);
        // CPU demands overflow onto the GPU worker only when forced.
        assert_eq!(ex.admit(4, &Resources::cpu(1.0)), Admission::Exhausted);
        ex.halt(3);
        assert_eq!(ex.admit(4, &Resources::cpu(1.0)), Admission::Granted);
    }

    #[test]
    fn pool_without_capacities_admits_everything() {
        let mut ex = PoolExecutor::new(const_factory(), 2);
        for id in 0..100 {
            assert_eq!(ex.admit(id, &Resources::cpu_gpu(64.0, 64.0)), Admission::Granted);
        }
    }

    #[test]
    fn shared_pool_capacity_is_global_across_experiments() {
        let mut pool = SharedPool::with_capacities(vec![Resources::cpu(2.0)]);
        assert_eq!(pool.total_capacity(), Some(Resources::cpu(2.0)));
        let mut a = pool.handle(const_factory());
        let mut b = pool.handle(const_factory());
        let one = Resources::cpu(1.0);
        assert_eq!(a.admit(0, &one), Admission::Granted);
        assert_eq!(b.admit(0, &one), Admission::Granted);
        // Same trial id, different experiment: namespaced, and the
        // shared fleet is now full for either experiment.
        assert_eq!(a.admit(1, &one), Admission::Exhausted);
        assert_eq!(b.admit(1, &one), Admission::Exhausted);
        assert_eq!(b.admit(2, &Resources::cpu(3.0)), Admission::Infeasible);
        // One experiment's halt frees capacity for the other.
        a.halt(0);
        assert_eq!(b.admit(1, &one), Admission::Granted);
    }

    #[test]
    fn shared_pool_routes_events_to_owning_experiment() {
        // Two experiments, overlapping trial ids, one pool: each
        // handle must only ever observe its own trials' events.
        let mut pool = SharedPool::new(2);
        let mut a = pool.handle(const_factory());
        let mut b = pool.handle(const_factory());
        assert_ne!(a.exp_id(), b.exp_id());
        for id in 0..4 {
            a.launch(&mk_trial(id, 0.0), None).unwrap();
            b.launch(&mk_trial(id, 0.0), None).unwrap();
            a.request_step(id);
            b.request_step(id);
        }
        assert_eq!(a.num_live(), 4);
        assert_eq!(b.num_live(), 4);
        let drain = |h: &mut SharedPoolHandle| -> std::collections::BTreeSet<TrialId> {
            let mut seen = std::collections::BTreeSet::new();
            while let Some(ev) = h.next_event() {
                match ev {
                    ExecEvent::Stepped { trial, .. } => {
                        seen.insert(trial);
                    }
                    e => panic!("{e:?}"),
                }
            }
            seen
        };
        let seen_a = drain(&mut a);
        let seen_b = drain(&mut b);
        assert_eq!(seen_a, (0..4).collect());
        assert_eq!(seen_b, (0..4).collect());
        for id in 0..4 {
            a.halt(id);
        }
        assert_eq!(a.num_live(), 0);
        assert_eq!(b.num_live(), 4); // sibling untouched
    }

    #[test]
    fn shared_pool_poll_reports_idle_and_events() {
        let mut pool = SharedPool::new(1);
        let mut a = pool.handle(const_factory());
        assert!(matches!(pool.poll(Duration::from_millis(10)), PoolPoll::Idle));
        a.launch(&mk_trial(0, 0.0), None).unwrap();
        a.request_step(0);
        match pool.poll(Duration::from_secs(5)) {
            PoolPoll::Event(exp, ExecEvent::Stepped { trial, .. }) => {
                assert_eq!(exp, a.exp_id());
                assert_eq!(trial, 0);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(pool.poll(Duration::from_millis(10)), PoolPoll::Idle));
    }

    #[test]
    fn shared_pool_clients_poll_only_owned_experiments() {
        let pool = SharedPool::new(2);
        let mut ca = pool.client(0.5);
        let mut cb = pool.client(0.5);
        let mut a = ca.handle(const_factory());
        let mut b = cb.handle(const_factory());
        assert_ne!(a.exp_id(), b.exp_id());
        // A shard with no in-flight work is Idle even while the
        // sibling is busy.
        assert!(matches!(ca.poll(Duration::from_millis(5)), PoolPoll::Idle));
        a.launch(&mk_trial(0, 0.0), None).unwrap();
        b.launch(&mk_trial(7, 0.0), None).unwrap();
        a.request_step(0);
        b.request_step(7);
        // Each client returns exactly its own experiment's completion,
        // even when the sibling drains the raw channel first.
        match ca.poll(Duration::from_secs(5)) {
            PoolPoll::Event(exp, ExecEvent::Stepped { trial, .. }) => {
                assert_eq!(exp, a.exp_id());
                assert_eq!(trial, 0);
            }
            other => panic!("{other:?}"),
        }
        match cb.poll(Duration::from_secs(5)) {
            PoolPoll::Event(exp, ExecEvent::Stepped { trial, .. }) => {
                assert_eq!(exp, b.exp_id());
                assert_eq!(trial, 7);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(ca.poll(Duration::from_millis(5)), PoolPoll::Idle));
        assert!(matches!(cb.poll(Duration::from_millis(5)), PoolPoll::Idle));
    }

    #[test]
    fn shared_pool_halted_requests_settle_as_skips() {
        let mut pool = SharedPool::new(1);
        let mut a = pool.handle(const_factory());
        a.launch(&mk_trial(0, 0.0), None).unwrap();
        a.request_step(0);
        a.halt(0);
        // The stale request settles internally; poll reports Idle
        // (possibly after consuming the skip), never a phantom event.
        match pool.poll(Duration::from_secs(5)) {
            PoolPoll::Idle => {}
            other => panic!("{other:?}"),
        }
        assert!(a.next_event().is_none());
    }
}
