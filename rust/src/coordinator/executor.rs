//! Trial executors: where trainables actually run.
//!
//! Three implementations behind one interface, so every scheduler/search
//! algorithm is oblivious to the execution substrate (§3's requirement
//! to "handle irregular computations" lives here):
//!
//! * [`SimExecutor`] — discrete-event, virtual clock. Each step costs
//!   `Trainable::step_cost()` virtual seconds; a binary heap orders
//!   completions. Runs thousand-trial experiments in milliseconds of
//!   wall time; the scheduler benches (C1–C3) use it.
//! * [`ThreadExecutor`] — one worker thread per live trial, command
//!   channels in, one shared event channel out. Wall-clock time. The
//!   end-to-end PJRT workloads run here, mirroring Ray's
//!   process-per-trial model in-process.
//! * [`PoolExecutor`] — a bounded pool of N worker threads servicing
//!   M ≫ N live trials through a shared injector queue, so concurrency
//!   is decoupled from trial count. Wall-clock time. This is the
//!   production substrate: thousand-trial experiments no longer burn a
//!   thread per trial.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::trial::{Config, Trial, TrialId};
use crate::trainable::{StepOutput, Trainable, TrainableFactory};

/// Completion events delivered to the runner.
#[derive(Debug)]
pub enum ExecEvent {
    /// One training iteration finished and reported metrics.
    Stepped {
        /// Trial that stepped.
        trial: TrialId,
        /// Metrics (and done flag) the trainable reported.
        out: StepOutput,
    },
    /// The trial's step raised an error (crash, injected fault, ...).
    Failed {
        /// Trial that failed.
        trial: TrialId,
        /// Human-readable failure cause.
        error: String,
    },
}

/// The execution substrate interface the runner drives. Implementations
/// differ in clock (virtual vs wall) and concurrency model, not
/// semantics: launch, request asynchronous steps, collect completion
/// events, and snapshot/restore/mutate idle trainables synchronously.
pub trait Executor: Send {
    /// Seconds since experiment start (virtual or wall).
    fn now(&self) -> f64;

    /// Instantiate the trial's trainable (optionally restoring).
    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String>;

    /// Ask for one asynchronous training iteration.
    fn request_step(&mut self, id: TrialId);

    /// Next completion event; None when nothing is in flight.
    fn next_event(&mut self) -> Option<ExecEvent>;

    /// Synchronous state snapshot (trainable is idle between steps).
    fn save(&mut self, id: TrialId) -> Option<Vec<u8>>;

    /// Restore state in place (PBT exploit).
    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String>;

    /// Runtime hyperparameter mutation.
    fn update_config(&mut self, id: TrialId, config: &Config);

    /// Tear down the trial's trainable.
    fn halt(&mut self, id: TrialId);

    /// Number of trials currently holding a live trainable.
    fn num_live(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Discrete-event executor
// ---------------------------------------------------------------------------

/// f64 ordered for the heap (times are finite by construction).
#[derive(PartialEq, PartialOrd)]
struct F64Ord(f64);
impl Eq for F64Ord {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

/// Discrete-event executor: virtual clock ordered by `step_cost`.
pub struct SimExecutor {
    factory: TrainableFactory,
    now: f64,
    seq: u64,
    /// (completion time, seq, trial, launch epoch).
    queue: BinaryHeap<Reverse<(F64Ord, u64, TrialId, u64)>>,
    live: HashMap<TrialId, Box<dyn Trainable>>,
    /// Launch generation per trial id. A halt + relaunch of the same id
    /// bumps it, so stale queue entries from a previous incarnation are
    /// discarded instead of stepping the new trainable (fault recovery
    /// relaunches ids while their old entries may still be queued).
    epoch: HashMap<TrialId, u64>,
}

impl SimExecutor {
    /// Create a simulator over `factory`-built trainables.
    pub fn new(factory: TrainableFactory) -> Self {
        SimExecutor {
            factory,
            now: 0.0,
            seq: 0,
            queue: BinaryHeap::new(),
            live: HashMap::new(),
            epoch: HashMap::new(),
        }
    }
}

impl Executor for SimExecutor {
    fn now(&self) -> f64 {
        self.now
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String> {
        let mut t = (self.factory)(&trial.config, trial.seed);
        if let Some(blob) = restore {
            t.restore(&blob)?;
        }
        *self.epoch.entry(trial.id).or_insert(0) += 1;
        self.live.insert(trial.id, t);
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        if let Some(t) = self.live.get(&id) {
            let done_at = self.now + t.step_cost().max(1e-9);
            self.seq += 1;
            let epoch = self.epoch.get(&id).copied().unwrap_or(0);
            self.queue.push(Reverse((F64Ord(done_at), self.seq, id, epoch)));
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        while let Some(Reverse((F64Ord(at), _, id, epoch))) = self.queue.pop() {
            // Halted (or halted-then-relaunched) trials leave stale queue
            // entries; skip anything from a previous launch epoch.
            if self.epoch.get(&id).copied().unwrap_or(0) != epoch {
                continue;
            }
            let Some(t) = self.live.get_mut(&id) else { continue };
            self.now = self.now.max(at);
            return Some(match t.step() {
                Ok(out) => ExecEvent::Stepped { trial: id, out },
                Err(error) => ExecEvent::Failed { trial: id, error },
            });
        }
        None
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        self.live.get_mut(&id).map(|t| t.save())
    }

    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String> {
        self.live.get_mut(&id).ok_or("trial not live")?.restore(blob)
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        if let Some(t) = self.live.get_mut(&id) {
            t.update_config(config);
        }
    }

    fn halt(&mut self, id: TrialId) {
        self.live.remove(&id);
    }

    fn num_live(&self) -> usize {
        self.live.len()
    }
}

// ---------------------------------------------------------------------------
// Threaded executor
// ---------------------------------------------------------------------------

enum WorkerCmd {
    Step,
    Save(Sender<Vec<u8>>),
    Restore(Vec<u8>, Sender<Result<(), String>>),
    Update(Config),
    Halt,
}

struct Worker {
    tx: Sender<WorkerCmd>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Wall-clock executor: one OS thread per live trial (Ray's
/// process-per-trial model, in-process).
pub struct ThreadExecutor {
    factory: TrainableFactory,
    workers: HashMap<TrialId, Worker>,
    event_tx: Sender<ExecEvent>,
    event_rx: Receiver<ExecEvent>,
    started: Instant,
}

impl ThreadExecutor {
    /// Create a thread-per-trial executor over `factory`-built trainables.
    pub fn new(factory: TrainableFactory) -> Self {
        let (event_tx, event_rx) = mpsc::channel();
        ThreadExecutor {
            factory,
            workers: HashMap::new(),
            event_tx,
            event_rx,
            started: Instant::now(),
        }
    }
}

impl Executor for ThreadExecutor {
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String> {
        let (tx, rx) = mpsc::channel::<WorkerCmd>();
        let factory = Arc::clone(&self.factory);
        let config = trial.config.clone();
        let seed = trial.seed;
        let id = trial.id;
        let events = self.event_tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("trial-{id}"))
            .spawn(move || {
                let mut t = factory(&config, seed);
                if let Some(blob) = restore {
                    if let Err(e) = t.restore(&blob) {
                        let _ = events.send(ExecEvent::Failed { trial: id, error: e });
                        return;
                    }
                }
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        WorkerCmd::Step => {
                            let ev = match t.step() {
                                Ok(out) => ExecEvent::Stepped { trial: id, out },
                                Err(error) => ExecEvent::Failed { trial: id, error },
                            };
                            if events.send(ev).is_err() {
                                return;
                            }
                        }
                        WorkerCmd::Save(reply) => {
                            let _ = reply.send(t.save());
                        }
                        WorkerCmd::Restore(blob, reply) => {
                            let _ = reply.send(t.restore(&blob));
                        }
                        WorkerCmd::Update(cfg) => t.update_config(&cfg),
                        WorkerCmd::Halt => return,
                    }
                }
            })
            .map_err(|e| e.to_string())?;
        self.workers.insert(id, Worker { tx, handle: Some(handle) });
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(WorkerCmd::Step);
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        if self.workers.is_empty() {
            return None;
        }
        // In-flight events from just-halted workers are still valid to
        // receive; the runner filters by trial status.
        self.event_rx.recv().ok()
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        let w = self.workers.get(&id)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(WorkerCmd::Save(reply_tx)).ok()?;
        reply_rx.recv().ok()
    }

    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String> {
        let w = self.workers.get(&id).ok_or("trial not live")?;
        let (reply_tx, reply_rx) = mpsc::channel();
        w.tx.send(WorkerCmd::Restore(blob.to_vec(), reply_tx))
            .map_err(|e| e.to_string())?;
        reply_rx.recv().map_err(|e| e.to_string())?
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        if let Some(w) = self.workers.get(&id) {
            let _ = w.tx.send(WorkerCmd::Update(config.clone()));
        }
    }

    fn halt(&mut self, id: TrialId) {
        if let Some(mut w) = self.workers.remove(&id) {
            let _ = w.tx.send(WorkerCmd::Halt);
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }

    fn num_live(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadExecutor {
    fn drop(&mut self) {
        let ids: Vec<TrialId> = self.workers.keys().copied().collect();
        for id in ids {
            self.halt(id);
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded work-stealing pool executor
// ---------------------------------------------------------------------------

/// Per-trial mailbox state inside the pool.
enum Slot {
    /// Trainable parked between steps; synchronous ops may touch it.
    Idle(Box<dyn Trainable>),
    /// A worker checked the trainable out and is stepping it.
    Busy,
    /// Halted while a worker was mid-step; the worker drops the
    /// trainable (and removes this marker) at check-in.
    Halted,
}

/// Mailboxes + launch generations, guarded by one lock.
#[derive(Default)]
struct PoolState {
    slots: HashMap<TrialId, Slot>,
    /// Launch generation per trial id, bumped on every `launch`. Step
    /// requests carry the epoch they were issued under; a request from a
    /// previous incarnation of a relaunched id resolves as a skip
    /// instead of stepping the new trainable (fault recovery relaunches
    /// ids while their old requests may still sit in the injector).
    epochs: HashMap<TrialId, u64>,
}

/// State shared between the coordinator thread and the pool workers.
struct PoolShared {
    state: Mutex<PoolState>,
    /// Signalled whenever a slot transitions out of `Busy` (check-in or
    /// halted-drop), waking synchronous ops parked in `with_idle` and
    /// relaunches parked in `launch`.
    idle_cv: Condvar,
}

/// Internal event stream: every queued step request produces exactly one
/// entry, so `next_event` can count in-flight work without timeouts.
enum PoolEvent {
    Exec(ExecEvent),
    /// The request targeted a halted/missing trial; no runner event.
    Skipped,
}

/// Wall-clock executor with a **bounded** worker pool: N workers service
/// M ≫ N live trials. Step requests go through a shared injector queue
/// that idle workers steal from; each trial's trainable lives in a
/// mailbox [`Slot`] that is checked out for the duration of one step.
/// Synchronous operations (`save`/`restore`/`update_config`) briefly wait
/// for an in-flight step to park, preserving the "idle between steps"
/// contract the runner relies on.
///
/// This decouples concurrency from trial count: a 10 000-trial experiment
/// runs on `num_cpus` threads instead of 10 000.
pub struct PoolExecutor {
    factory: TrainableFactory,
    shared: Arc<PoolShared>,
    /// Work queue of (trial, launch epoch) feeding the workers; dropped
    /// first on teardown so the workers observe a closed channel and
    /// exit.
    injector_tx: Option<Sender<(TrialId, u64)>>,
    event_rx: Receiver<PoolEvent>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Step requests queued but not yet answered by a `PoolEvent`.
    queued: usize,
    started: Instant,
}

impl PoolExecutor {
    /// Spawn a pool of `workers` (min 1) threads over `factory`-built
    /// trainables.
    pub fn new(factory: TrainableFactory, workers: usize) -> Self {
        let workers = workers.max(1);
        let (injector_tx, injector_rx) = mpsc::channel::<(TrialId, u64)>();
        let injector_rx = Arc::new(Mutex::new(injector_rx));
        let (event_tx, event_rx) = mpsc::channel::<PoolEvent>();
        let shared =
            Arc::new(PoolShared { state: Mutex::new(PoolState::default()), idle_cv: Condvar::new() });

        let handles = (0..workers)
            .map(|w| {
                let injector_rx = Arc::clone(&injector_rx);
                let event_tx = event_tx.clone();
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tune-pool-{w}"))
                    .spawn(move || pool_worker(&injector_rx, &event_tx, &shared))
                    .expect("spawn pool worker")
            })
            .collect();

        PoolExecutor {
            factory,
            shared,
            injector_tx: Some(injector_tx),
            event_rx,
            workers: handles,
            queued: 0,
            started: Instant::now(),
        }
    }

    /// Number of worker threads in the pool.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `f` on the trial's parked trainable, waiting out an in-flight
    /// step first. `None` if the trial is not live.
    fn with_idle<R>(&self, id: TrialId, f: impl FnOnce(&mut Box<dyn Trainable>) -> R) -> Option<R> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if matches!(st.slots.get(&id), Some(Slot::Busy)) {
                st = self.shared.idle_cv.wait(st).unwrap();
                continue;
            }
            return match st.slots.get_mut(&id) {
                Some(Slot::Idle(t)) => Some(f(t)),
                _ => None,
            };
        }
    }
}

/// One pool worker: steal a trial id from the injector, check its
/// trainable out, step it, check it back in, emit the event.
fn pool_worker(
    injector_rx: &Mutex<Receiver<(TrialId, u64)>>,
    event_tx: &Sender<PoolEvent>,
    shared: &PoolShared,
) {
    loop {
        // Holding the lock across recv is fine: at most one idle worker
        // parks inside recv; the rest park on the mutex and rotate in as
        // work arrives.
        let (id, epoch) = match injector_rx.lock().unwrap().recv() {
            Ok(req) => req,
            Err(_) => return, // injector closed: executor dropped
        };
        // Check out: Idle -> Busy. Requests from a previous launch epoch
        // and halted/missing trials are answered with a Skipped marker so
        // next_event's accounting stays exact.
        let taken = {
            let mut st = shared.state.lock().unwrap();
            if st.epochs.get(&id).copied().unwrap_or(0) != epoch {
                None
            } else {
                match st.slots.remove(&id) {
                    Some(Slot::Idle(t)) => {
                        st.slots.insert(id, Slot::Busy);
                        Some(t)
                    }
                    Some(other) => {
                        st.slots.insert(id, other);
                        None
                    }
                    None => None,
                }
            }
        };
        let Some(mut t) = taken else {
            if event_tx.send(PoolEvent::Skipped).is_err() {
                return;
            }
            continue;
        };

        let result = t.step();

        // Check in: Busy -> Idle, unless halted mid-step (drop it).
        let halted = {
            let mut st = shared.state.lock().unwrap();
            match st.slots.remove(&id) {
                Some(Slot::Busy) => {
                    st.slots.insert(id, Slot::Idle(t));
                    false
                }
                _ => true,
            }
        };
        shared.idle_cv.notify_all();

        let event = if halted {
            PoolEvent::Skipped
        } else {
            PoolEvent::Exec(match result {
                Ok(out) => ExecEvent::Stepped { trial: id, out },
                Err(error) => ExecEvent::Failed { trial: id, error },
            })
        };
        if event_tx.send(event).is_err() {
            return;
        }
    }
}

impl Executor for PoolExecutor {
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn launch(&mut self, trial: &Trial, restore: Option<Vec<u8>>) -> Result<(), String> {
        let mut t = (self.factory)(&trial.config, trial.seed);
        if let Some(blob) = restore {
            t.restore(&blob)?;
        }
        let mut st = self.shared.state.lock().unwrap();
        // A relaunch can race a halted-mid-step worker; wait for the
        // stale slot to clear so the worker cannot drop the new trainable.
        while st.slots.contains_key(&trial.id) {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
        *st.epochs.entry(trial.id).or_insert(0) += 1;
        st.slots.insert(trial.id, Slot::Idle(t));
        Ok(())
    }

    fn request_step(&mut self, id: TrialId) {
        let epoch = self.shared.state.lock().unwrap().epochs.get(&id).copied().unwrap_or(0);
        if let Some(tx) = &self.injector_tx {
            if tx.send((id, epoch)).is_ok() {
                self.queued += 1;
            }
        }
    }

    fn next_event(&mut self) -> Option<ExecEvent> {
        while self.queued > 0 {
            match self.event_rx.recv() {
                Ok(PoolEvent::Exec(ev)) => {
                    self.queued -= 1;
                    return Some(ev);
                }
                Ok(PoolEvent::Skipped) => self.queued -= 1,
                Err(_) => return None,
            }
        }
        None
    }

    fn save(&mut self, id: TrialId) -> Option<Vec<u8>> {
        self.with_idle(id, |t| t.save())
    }

    fn restore(&mut self, id: TrialId, blob: &[u8]) -> Result<(), String> {
        self.with_idle(id, |t| t.restore(blob)).unwrap_or_else(|| Err("trial not live".into()))
    }

    fn update_config(&mut self, id: TrialId, config: &Config) {
        self.with_idle(id, |t| t.update_config(config));
    }

    fn halt(&mut self, id: TrialId) {
        let mut st = self.shared.state.lock().unwrap();
        if matches!(st.slots.get(&id), Some(Slot::Busy)) {
            // Mid-step: leave a marker; the worker drops the trainable
            // and clears the slot at check-in.
            st.slots.insert(id, Slot::Halted);
        } else if !matches!(st.slots.get(&id), Some(Slot::Halted)) {
            st.slots.remove(&id);
            self.shared.idle_cv.notify_all();
        }
    }

    fn num_live(&self) -> usize {
        self.shared
            .state
            .lock()
            .unwrap()
            .slots
            .values()
            .filter(|s| !matches!(s, Slot::Halted))
            .count()
    }
}

impl Drop for PoolExecutor {
    fn drop(&mut self) {
        // Close the injector; workers drain and exit on the closed
        // channel. Trainables still parked in slots drop with the map.
        self.injector_tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::ParamValue;
    use crate::ray::Resources;
    use crate::trainable::factory;
    use crate::trainable::synthetic::ConstTrainable;

    fn mk_trial(id: TrialId, cost: f64) -> Trial {
        let mut c = Config::new();
        c.insert("step_cost".into(), ParamValue::F64(cost));
        Trial::new(id, c, Resources::cpu(1.0), id)
    }

    fn const_factory() -> TrainableFactory {
        factory(|c, s| Box::new(ConstTrainable::new(c, s)))
    }

    #[test]
    fn sim_orders_by_virtual_time() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 5.0), None).unwrap();
        ex.launch(&mk_trial(2, 1.0), None).unwrap();
        ex.request_step(1);
        ex.request_step(2);
        // Trial 2 (cost 1) completes before trial 1 (cost 5).
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 2),
            e => panic!("{e:?}"),
        }
        assert!((ex.now() - 1.0).abs() < 1e-9);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 1),
            e => panic!("{e:?}"),
        }
        assert!((ex.now() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sim_halt_discards_stale_events() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        assert!(ex.next_event().is_none());
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn sim_relaunch_does_not_consume_stale_entry() {
        // Fault recovery halts and relaunches the same trial id while the
        // old step entry is still queued: the stale entry must NOT step
        // the new incarnation (it would double the trial's step stream).
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        assert!(ex.next_event().is_none(), "stale pre-relaunch entry was executed");
        // The relaunched trial still works normally.
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn sim_save_restore() {
        let mut ex = SimExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 1.0), None).unwrap();
        ex.request_step(1);
        ex.next_event();
        let blob = ex.save(1).unwrap();
        ex.launch(&mk_trial(2, 1.0), Some(blob)).unwrap();
        ex.request_step(2);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 2.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn threaded_steps_flow() {
        let mut ex = ThreadExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, out } => {
                assert_eq!(trial, 1);
                assert_eq!(out.metrics["iters"], 1.0);
            }
            e => panic!("{e:?}"),
        }
        let blob = ex.save(1).unwrap();
        assert_eq!(u64::from_le_bytes(blob.try_into().unwrap()), 1);
        ex.halt(1);
        assert_eq!(ex.num_live(), 0);
    }

    #[test]
    fn threaded_parallel_trials() {
        let mut ex = ThreadExecutor::new(const_factory());
        for id in 0..8 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
            ex.request_step(id);
        }
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..8 {
            match ex.next_event().unwrap() {
                ExecEvent::Stepped { trial, .. } => {
                    seen.insert(trial);
                }
                e => panic!("{e:?}"),
            }
        }
        assert_eq!(seen.len(), 8);
    }

    #[test]
    fn threaded_restore_in_place() {
        let mut ex = ThreadExecutor::new(const_factory());
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        for _ in 0..3 {
            ex.request_step(1);
            ex.next_event();
        }
        ex.restore(1, &0u64.to_le_bytes()).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn pool_completes_64_trials_with_4_workers() {
        // M = 64 live trials over N = 4 workers: every trial must step to
        // completion without a dedicated thread.
        let mut ex = PoolExecutor::new(const_factory(), 4);
        assert_eq!(ex.num_workers(), 4);
        for id in 0..64 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
        }
        assert_eq!(ex.num_live(), 64);
        let steps_per_trial = 3u64;
        let mut counts = std::collections::BTreeMap::new();
        for round in 0..steps_per_trial {
            for id in 0..64 {
                ex.request_step(id);
            }
            for _ in 0..64 {
                match ex.next_event().unwrap() {
                    ExecEvent::Stepped { trial, out } => {
                        assert!(out.metrics["iters"] >= (round + 1) as f64);
                        *counts.entry(trial).or_insert(0u64) += 1;
                    }
                    e => panic!("{e:?}"),
                }
            }
        }
        assert_eq!(counts.len(), 64);
        assert!(counts.values().all(|&c| c == steps_per_trial));
        for id in 0..64 {
            ex.halt(id);
        }
        assert_eq!(ex.num_live(), 0);
        assert!(ex.next_event().is_none());
    }

    #[test]
    fn pool_save_restore_update_matches_threaded() {
        // The same command sequence must be observationally identical on
        // the pool and the thread-per-trial executor.
        fn drive(ex: &mut dyn Executor) -> (Vec<f64>, Vec<u8>, f64) {
            ex.launch(&mk_trial(1, 0.0), None).unwrap();
            let mut iters = Vec::new();
            for _ in 0..3 {
                ex.request_step(1);
                match ex.next_event().unwrap() {
                    ExecEvent::Stepped { out, .. } => iters.push(out.metrics["iters"]),
                    e => panic!("{e:?}"),
                }
            }
            let blob = ex.save(1).unwrap();
            // Roll back to iteration 1 and mutate the config in place.
            ex.restore(1, &1u64.to_le_bytes()).unwrap();
            let mut cfg = Config::new();
            cfg.insert("step_cost".into(), ParamValue::F64(2.0));
            ex.update_config(1, &cfg);
            ex.request_step(1);
            let after = match ex.next_event().unwrap() {
                ExecEvent::Stepped { out, .. } => out.metrics["iters"],
                e => panic!("{e:?}"),
            };
            ex.halt(1);
            (iters, blob, after)
        }
        let mut pool = PoolExecutor::new(const_factory(), 2);
        let mut threads = ThreadExecutor::new(const_factory());
        assert_eq!(drive(&mut pool), drive(&mut threads));
    }

    #[test]
    fn pool_halt_discards_pending_requests() {
        let mut ex = PoolExecutor::new(const_factory(), 1);
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        // The queued request resolves as a skip, never a runner event.
        assert!(ex.next_event().is_none());
        assert_eq!(ex.num_live(), 0);
        // Relaunching the same trial id afterwards is clean.
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { out, .. } => assert_eq!(out.metrics["iters"], 1.0),
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn pool_relaunch_does_not_consume_stale_request() {
        // A trainable slow enough to pin the single worker while we
        // halt + relaunch another trial whose request is still queued.
        struct Slow(u64);
        impl Trainable for Slow {
            fn step(&mut self) -> Result<StepOutput, String> {
                std::thread::sleep(std::time::Duration::from_millis(100));
                self.0 += 1;
                Ok(StepOutput::of(&[("iters", self.0 as f64)]))
            }
            fn save(&mut self) -> Vec<u8> {
                self.0.to_le_bytes().to_vec()
            }
            fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
                self.0 = u64::from_le_bytes(blob.try_into().map_err(|_| "bad blob")?);
                Ok(())
            }
        }
        let factory: TrainableFactory = factory(|c, s| {
            if c.contains_key("slow") {
                Box::new(Slow(0))
            } else {
                Box::new(ConstTrainable::new(c, s))
            }
        });
        let mut ex = PoolExecutor::new(factory, 1);
        let mut slow_cfg = Config::new();
        slow_cfg.insert("slow".into(), ParamValue::Bool(true));
        let blocker = Trial::new(99, slow_cfg, Resources::cpu(1.0), 0);
        ex.launch(&blocker, None).unwrap();
        ex.request_step(99); // pins the only worker for ~100ms

        // Victim: request queued behind the blocker, then halt + relaunch
        // (the fault-recovery sequence) before the worker reaches it.
        ex.launch(&mk_trial(1, 0.0), None).unwrap();
        ex.request_step(1);
        ex.halt(1);
        ex.launch(&mk_trial(1, 0.0), None).unwrap();

        // Blocker's event arrives; the victim's stale request must
        // resolve as a skip, never as a step of the new incarnation.
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, .. } => assert_eq!(trial, 99),
            e => panic!("{e:?}"),
        }
        assert!(ex.next_event().is_none(), "stale pre-relaunch request was executed");
        ex.request_step(1);
        match ex.next_event().unwrap() {
            ExecEvent::Stepped { trial, out } => {
                assert_eq!(trial, 1);
                assert_eq!(out.metrics["iters"], 1.0);
            }
            e => panic!("{e:?}"),
        }
    }

    #[test]
    fn pool_single_worker_serializes_m_trials() {
        let mut ex = PoolExecutor::new(const_factory(), 1);
        for id in 0..16 {
            ex.launch(&mk_trial(id, 0.0), None).unwrap();
            ex.request_step(id);
        }
        let mut seen = std::collections::BTreeSet::new();
        while let Some(ev) = ex.next_event() {
            match ev {
                ExecEvent::Stepped { trial, .. } => {
                    seen.insert(trial);
                }
                e => panic!("{e:?}"),
            }
        }
        assert_eq!(seen.len(), 16);
    }
}
