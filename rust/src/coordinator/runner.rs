//! The trial runner: Tune's central event loop.
//!
//! Owns the trial table and drives the narrow-waist protocol of §4.2:
//! when resources free up it asks the scheduler `choose_trial_to_run`
//! (pulling fresh configs from the search algorithm as needed), places
//! the trial on the Ray-like substrate, and launches it on an executor;
//! as intermediate results arrive it invokes `scheduler.on_result` and
//! applies the returned decision — continue, checkpoint, pause, stop,
//! or restart-with-mutated-config. Checkpoints provide fault tolerance
//! (trial metadata itself stays in memory, per the paper).

use std::collections::BTreeMap;

use crate::checkpoint::CheckpointStore;
use crate::logger::ResultLogger;
use crate::ray::{Cluster, FaultInjector, LeaseId, NodeId, PlacementStats, TwoLevelScheduler};
use crate::util::rng::Rng;

use super::executor::{ExecEvent, Executor};
use super::experiment::ExperimentSpec;
use super::schedulers::{Decision, SchedulerCtx, TrialScheduler};
use super::search::SearchAlgorithm;
use super::trial::{ResultRow, Trial, TrialId, TrialStatus};

/// Counters the benches and EXPERIMENTS.md report.
#[derive(Clone, Debug, Default)]
pub struct RunnerStats {
    /// Intermediate results processed.
    pub results: u64,
    /// Checkpoints written to the store.
    pub checkpoints: u64,
    /// Restores from checkpoints (relaunches + PBT exploits).
    pub restores: u64,
    /// PBT exploit operations applied.
    pub exploits: u64,
    /// Trials stopped early by a scheduler.
    pub stopped_early: u64,
    /// Trials that reached their stopping criterion.
    pub completed: u64,
    /// Trials that exhausted `max_failures`.
    pub errored: u64,
    /// Failures recovered via checkpoint relaunch.
    pub failures_recovered: u64,
    /// Trainable launches (initial + relaunches).
    pub launches: u64,
    /// Nanoseconds spent inside scheduler callbacks (decision latency).
    pub decision_ns: u64,
    /// Nanoseconds spent in the whole handling path (runner overhead).
    pub handling_ns: u64,
}

/// Everything an experiment run produced.
pub struct ExperimentResult {
    /// Final state of every trial, by id.
    pub trials: BTreeMap<TrialId, Trial>,
    /// Trial with the best metric value observed, if any metric was.
    pub best: Option<TrialId>,
    /// Total (virtual or wall) seconds the experiment spanned.
    pub duration_s: f64,
    /// Sum over trials of consumed training seconds (the search budget).
    pub budget_used_s: f64,
    /// Runner-level counters.
    pub stats: RunnerStats,
    /// Placement counters from the two-level scheduler.
    pub placement: PlacementStats,
    /// (experiment time, best raw metric so far) — per-result samples.
    pub best_curve: Vec<(f64, f64)>,
}

impl ExperimentResult {
    /// Best metric value observed across the experiment.
    pub fn best_metric(&self) -> Option<f64> {
        self.best.and_then(|id| self.trials[&id].best_metric)
    }
    /// Config of the best trial.
    pub fn best_config(&self) -> Option<&super::trial::Config> {
        self.best.map(|id| &self.trials[&id].config)
    }
    /// Total training iterations across all trials.
    pub fn total_iterations(&self) -> u64 {
        self.trials.values().map(|t| t.iteration).sum()
    }
    /// Number of trials that ended in `status`.
    pub fn count(&self, status: TrialStatus) -> usize {
        self.trials.values().filter(|t| t.status == status).count()
    }
}

/// Tune's central event loop: owns the trial table and drives the
/// scheduler/search/executor/substrate quartet to completion.
pub struct TrialRunner {
    /// The experiment being run.
    pub spec: ExperimentSpec,
    scheduler: Box<dyn TrialScheduler>,
    search: Box<dyn SearchAlgorithm>,
    executor: Box<dyn Executor>,
    cluster: Cluster,
    placer: TwoLevelScheduler,
    /// Checkpoint store (exposed for post-hoc restore tooling).
    pub checkpoints: CheckpointStore,
    fault: FaultInjector,
    trials: BTreeMap<TrialId, Trial>,
    leases: BTreeMap<TrialId, (NodeId, LeaseId)>,
    /// Wall/virtual time at which each running trial was (re)launched,
    /// plus previously accumulated training seconds.
    run_clock: BTreeMap<TrialId, (f64, f64)>,
    loggers: Vec<Box<dyn ResultLogger>>,
    rng: Rng,
    next_id: TrialId,
    search_exhausted: bool,
    stats: RunnerStats,
    best_curve: Vec<(f64, f64)>,
    best_so_far: Option<f64>,
}

impl TrialRunner {
    /// Assemble a runner from its four pluggable parts plus a cluster.
    pub fn new(
        spec: ExperimentSpec,
        scheduler: Box<dyn TrialScheduler>,
        search: Box<dyn SearchAlgorithm>,
        executor: Box<dyn Executor>,
        cluster: Cluster,
    ) -> Self {
        let rng = Rng::new(spec.seed);
        let fault = FaultInjector::new(spec.fault_plan.clone(), spec.seed ^ 0xFA17);
        TrialRunner {
            spec,
            scheduler,
            search,
            executor,
            cluster,
            placer: TwoLevelScheduler::new(),
            checkpoints: CheckpointStore::new(),
            fault,
            trials: BTreeMap::new(),
            leases: BTreeMap::new(),
            run_clock: BTreeMap::new(),
            loggers: Vec::new(),
            rng,
            next_id: 0,
            search_exhausted: false,
            stats: RunnerStats::default(),
            best_curve: Vec::new(),
            best_so_far: None,
        }
    }

    /// Attach a result logger (fan-out on every intermediate result).
    pub fn add_logger(&mut self, logger: Box<dyn ResultLogger>) {
        self.loggers.push(logger);
    }

    /// Read-only view of the trial table.
    pub fn trials(&self) -> &BTreeMap<TrialId, Trial> {
        &self.trials
    }

    /// Pull one fresh config from the search algorithm into the pool.
    fn create_trial(&mut self) -> Option<TrialId> {
        if self.search_exhausted {
            return None;
        }
        let Some(config) = self.search.next_config(&mut self.rng) else {
            self.search_exhausted = true;
            return None;
        };
        let id = self.next_id;
        self.next_id += 1;
        let seed = self.rng.fork(id).next_u64();
        let trial = Trial::new(id, config, self.spec.resources_per_trial.clone(), seed);
        self.scheduler.on_trial_add(
            &SchedulerCtx {
                trials: &self.trials,
                metric: &self.spec.metric,
                mode: self.spec.mode,
            },
            &trial,
        );
        self.trials.insert(id, trial);
        Some(id)
    }

    fn num_running(&self) -> usize {
        self.trials.values().filter(|t| t.status == TrialStatus::Running).count()
    }

    /// Admission: launch trials while the scheduler has candidates and
    /// the cluster has room.
    fn admit(&mut self) {
        loop {
            if self.spec.max_concurrent > 0 && self.num_running() >= self.spec.max_concurrent {
                return;
            }
            // Ask the scheduler first (it may resume paused trials);
            // otherwise try to create a fresh trial.
            let mut choice = {
                let ctx = SchedulerCtx {
                    trials: &self.trials,
                    metric: &self.spec.metric,
                    mode: self.spec.mode,
                };
                self.scheduler.choose_trial_to_run(&ctx)
            };
            if choice.is_none() {
                if self.create_trial().is_none() {
                    return;
                }
                let ctx = SchedulerCtx {
                    trials: &self.trials,
                    metric: &self.spec.metric,
                    mode: self.spec.mode,
                };
                choice = self.scheduler.choose_trial_to_run(&ctx);
            }
            let Some(id) = choice else { return };
            if !self.launch(id) {
                return; // no resources (or broken trial): stop admitting
            }
        }
    }

    /// Place + start one trial. Returns false when out of resources.
    fn launch(&mut self, id: TrialId) -> bool {
        let demand = self.trials[&id].resources.clone();
        // Trial drivers originate on the head node (node 0), matching
        // Tune-on-Ray's driver placement; children would spill.
        let Some(p) = self.placer.place(&mut self.cluster, 0, &demand) else {
            return false;
        };
        let restore = self.trials[&id]
            .checkpoint
            .and_then(|c| self.checkpoints.get(c).map(|b| b.to_vec()));
        let restored = restore.is_some();
        let trial = self.trials.get_mut(&id).unwrap();
        trial.node = Some(p.node);
        match self.executor.launch(trial, restore) {
            Ok(()) => {
                trial.status = TrialStatus::Running;
                self.leases.insert(id, (p.node, p.lease));
                self.run_clock.insert(id, (self.executor.now(), trial.time_total_s));
                self.stats.launches += 1;
                if restored {
                    self.stats.restores += 1;
                }
                self.executor.request_step(id);
                true
            }
            Err(e) => {
                self.cluster.release(p.node, p.lease);
                eprintln!("trial {id} failed to launch: {e}");
                self.finish(id, TrialStatus::Errored);
                true // keep admitting others
            }
        }
    }

    fn release(&mut self, id: TrialId) {
        if let Some((node, lease)) = self.leases.remove(&id) {
            self.cluster.release(node, lease);
        }
        self.run_clock.remove(&id);
    }

    fn finish(&mut self, id: TrialId, status: TrialStatus) {
        self.executor.halt(id);
        self.release(id);
        let (config, last_metric);
        {
            let t = self.trials.get_mut(&id).unwrap();
            t.status = status;
            config = t.config.clone();
            last_metric = t.last_result.as_ref().and_then(|r| r.metric(&self.spec.metric));
        }
        match status {
            TrialStatus::Completed => self.stats.completed += 1,
            TrialStatus::Stopped => self.stats.stopped_early += 1,
            TrialStatus::Errored => self.stats.errored += 1,
            _ => {}
        }
        let ctx = SchedulerCtx {
            trials: &self.trials,
            metric: &self.spec.metric,
            mode: self.spec.mode,
        };
        self.scheduler.on_trial_remove(&ctx, id);
        self.search.on_complete(&config, last_metric, self.spec.mode);
        let t = self.trials[&id].clone();
        for l in &mut self.loggers {
            l.on_trial_end(&t);
        }
    }

    fn save_checkpoint(&mut self, id: TrialId) {
        if let Some(blob) = self.executor.save(id) {
            let iter = self.trials[&id].iteration;
            let cid = self.checkpoints.save(id, iter, blob);
            self.trials.get_mut(&id).unwrap().checkpoint = Some(cid);
            self.stats.checkpoints += 1;
        }
    }

    fn handle_failure(&mut self, id: TrialId, error: &str) {
        self.executor.halt(id);
        self.release(id);
        let max_failures = self.spec.max_failures;
        let t = self.trials.get_mut(&id).unwrap();
        t.num_failures += 1;
        if t.num_failures <= max_failures {
            // Recover: back to Pending; relaunch restores the latest
            // checkpoint (possibly iteration 0 if none exists).
            t.status = TrialStatus::Pending;
            if t.checkpoint.is_none() {
                t.iteration = 0;
                t.time_total_s = 0.0;
            } else if let Some(c) = t.checkpoint {
                // Roll visible progress back to the checkpoint.
                if let Some(m) = self.checkpoints.meta(c) {
                    t.iteration = m.iteration;
                }
            }
            self.stats.failures_recovered += 1;
        } else {
            eprintln!("trial {id} errored permanently: {error}");
            self.finish(id, TrialStatus::Errored);
        }
    }

    fn apply_decision(&mut self, id: TrialId, decision: Decision) {
        match decision {
            Decision::Continue => self.executor.request_step(id),
            Decision::Checkpoint => {
                self.save_checkpoint(id);
                self.executor.request_step(id);
            }
            Decision::Pause => {
                self.save_checkpoint(id);
                self.executor.halt(id);
                self.release(id);
                self.trials.get_mut(&id).unwrap().status = TrialStatus::Paused;
            }
            Decision::Stop => self.finish(id, TrialStatus::Stopped),
            Decision::Exploit { source, config } => {
                let donor = self
                    .trials
                    .get(&source)
                    .and_then(|t| t.checkpoint)
                    .or_else(|| self.checkpoints.latest_for(source));
                match donor.and_then(|c| self.checkpoints.get(c).map(|b| b.to_vec())) {
                    Some(blob) => {
                        if self.executor.restore(id, &blob).is_ok() {
                            let iter = self.trials[&id].iteration;
                            let cid = self.checkpoints.save(id, iter, blob);
                            let t = self.trials.get_mut(&id).unwrap();
                            t.config = config.clone();
                            t.checkpoint = Some(cid);
                            t.mutations += 1;
                            self.executor.update_config(id, &config);
                            self.stats.exploits += 1;
                            self.stats.restores += 1;
                        }
                        self.executor.request_step(id);
                    }
                    None => {
                        // No donor checkpoint yet: mutate config only.
                        let t = self.trials.get_mut(&id).unwrap();
                        t.config = config.clone();
                        t.mutations += 1;
                        self.executor.update_config(id, &config);
                        self.executor.request_step(id);
                    }
                }
            }
        }
    }

    fn handle_stepped(&mut self, id: TrialId, out: crate::trainable::StepOutput) {
        if self.trials.get(&id).map(|t| t.status) != Some(TrialStatus::Running) {
            return; // stale event from a halted worker
        }
        if self.fault.step_fails() {
            self.handle_failure(id, "injected step failure");
            return;
        }
        if out.done {
            self.finish(id, TrialStatus::Completed);
            return;
        }
        let now = self.executor.now();
        let (iteration, row) = {
            let (started, acc) = self.run_clock[&id];
            let t = self.trials.get_mut(&id).unwrap();
            let iteration = t.iteration + 1;
            let mut row = ResultRow::new(iteration, acc + (now - started));
            row.metrics = out.metrics;
            t.record(row.clone(), &self.spec.metric, self.spec.mode);
            (iteration, row)
        };
        self.stats.results += 1;

        // Best-so-far curve (experiment time axis).
        if let Some(v) = row.metric(&self.spec.metric) {
            let better = self.best_so_far.map_or(true, |b| self.spec.mode.better(v, b));
            if better {
                self.best_so_far = Some(v);
                self.best_curve.push((now, v));
            }
        }

        // Hot path: no Trial clone — loggers/search/scheduler live in
        // disjoint fields, so shared borrows of `trials` coexist with
        // mutable borrows of each consumer (perf iteration 1, §Perf).
        {
            let t = &self.trials[&id];
            for l in &mut self.loggers {
                l.on_result(t, &row);
            }
            self.search.on_result(&t.config, &row);
        }

        // Runner-level stopping criteria outrank the scheduler.
        let target_hit = match (self.spec.metric_target, row.metric(&self.spec.metric)) {
            (Some(tgt), Some(v)) => self.spec.mode.better(v, tgt) || v == tgt,
            _ => false,
        };
        if iteration >= self.spec.max_iterations_per_trial || target_hit {
            // Final checkpoint so results are restorable post-hoc.
            if self.spec.checkpoint_at_end {
                self.save_checkpoint(id);
            }
            self.finish(id, TrialStatus::Completed);
            return;
        }
        // Periodic checkpointing orthogonal to scheduler decisions.
        if self.spec.checkpoint_freq > 0 && iteration % self.spec.checkpoint_freq == 0 {
            self.save_checkpoint(id);
        }

        let decision = {
            let t0 = std::time::Instant::now();
            let ctx = SchedulerCtx {
                trials: &self.trials,
                metric: &self.spec.metric,
                mode: self.spec.mode,
            };
            let d = self.scheduler.on_result(&ctx, &self.trials[&id], &row);
            self.stats.decision_ns += t0.elapsed().as_nanos() as u64;
            d
        };
        self.apply_decision(id, decision);

        // Out-of-band terminations (HyperBand rung cuts).
        for victim in self.scheduler.drain_stops() {
            if !self.trials[&victim].status.is_terminal() {
                self.finish(victim, TrialStatus::Stopped);
            }
        }
    }

    fn fault_tick(&mut self) {
        if self.fault.plan.node_failure_prob == 0.0 {
            return;
        }
        let alive: Vec<NodeId> = self.cluster.alive_nodes().map(|n| n.id).collect();
        let (kill, restarts) = self.fault.tick(&alive);
        for n in restarts {
            self.cluster.restart_node(n);
        }
        if let Some(victim) = kill {
            let dead_leases = self.cluster.kill_node(victim);
            let victims: Vec<TrialId> = self
                .leases
                .iter()
                .filter(|(_, (node, lease))| *node == victim && dead_leases.contains(lease))
                .map(|(id, _)| *id)
                .collect();
            for id in victims {
                self.handle_failure(id, "node failure");
            }
        }
    }

    /// Drive the experiment to completion; returns the result summary.
    pub fn run(&mut self) -> ExperimentResult {
        loop {
            self.admit();
            if self.executor.now() >= self.spec.max_experiment_time_s {
                break;
            }
            let event = self.executor.next_event();
            let t0 = std::time::Instant::now();
            match event {
                Some(ExecEvent::Stepped { trial, out }) => self.handle_stepped(trial, out),
                Some(ExecEvent::Failed { trial, error }) => self.handle_failure(trial, &error),
                None => {
                    // Nothing in flight. If nothing can ever run again,
                    // we are done; otherwise admit more.
                    let can_progress = {
                        let ctx = SchedulerCtx {
                            trials: &self.trials,
                            metric: &self.spec.metric,
                            mode: self.spec.mode,
                        };
                        self.scheduler.choose_trial_to_run(&ctx).is_some()
                    };
                    if !can_progress && self.search_exhausted {
                        break;
                    }
                    if !can_progress && self.create_trial().is_none() {
                        break;
                    }
                }
            }
            self.stats.handling_ns += t0.elapsed().as_nanos() as u64;
            self.fault_tick();
        }
        // Endgame: terminate whatever is still live (budget exhausted or
        // orphaned paused trials).
        let leftovers: Vec<TrialId> = self
            .trials
            .values()
            .filter(|t| !t.status.is_terminal())
            .map(|t| t.id)
            .collect();
        for id in leftovers {
            self.finish(id, TrialStatus::Stopped);
        }
        for l in &mut self.loggers {
            l.on_experiment_end(&self.trials);
        }

        let best = self
            .trials
            .values()
            .filter(|t| t.best_metric.is_some())
            .max_by(|a, b| {
                let am = self.spec.mode.ascending(a.best_metric.unwrap());
                let bm = self.spec.mode.ascending(b.best_metric.unwrap());
                am.partial_cmp(&bm).unwrap()
            })
            .map(|t| t.id);
        ExperimentResult {
            best,
            duration_s: self.executor.now(),
            budget_used_s: self.trials.values().map(|t| t.time_total_s).sum(),
            trials: std::mem::take(&mut self.trials),
            stats: self.stats.clone(),
            placement: self.placer.stats,
            best_curve: std::mem::take(&mut self.best_curve),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::SimExecutor;
    use crate::coordinator::schedulers::FifoScheduler;
    use crate::coordinator::search::RandomSearch;
    use crate::coordinator::spec::SpaceBuilder;
    use crate::coordinator::trial::Mode;
    use crate::ray::{FaultPlan, Resources};
    use crate::trainable::factory;
    use crate::trainable::synthetic::CurveTrainable;

    fn quick_spec(n: usize, iters: u64) -> ExperimentSpec {
        let mut spec = ExperimentSpec::named("test");
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.num_samples = n;
        spec.max_iterations_per_trial = iters;
        spec
    }

    fn runner(spec: ExperimentSpec, nodes: usize) -> TrialRunner {
        let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
        let search = Box::new(RandomSearch::new(space, spec.num_samples));
        let executor = Box::new(SimExecutor::new(factory(|c, s| {
            Box::new(CurveTrainable::new(c, s))
        })));
        let cluster = Cluster::uniform(nodes, Resources::cpu(4.0));
        TrialRunner::new(spec, Box::new(FifoScheduler::new()), search, executor, cluster)
    }

    #[test]
    fn fifo_runs_all_trials_to_completion() {
        let mut r = runner(quick_spec(10, 20), 2);
        let res = r.run();
        assert_eq!(res.trials.len(), 10);
        assert_eq!(res.count(TrialStatus::Completed), 10);
        assert_eq!(res.total_iterations(), 200);
        assert!(res.best.is_some());
        assert!(res.duration_s > 0.0);
    }

    #[test]
    fn resource_limits_bound_parallelism() {
        // 1 node x 4 cpus, 1 cpu per trial -> <= 4 concurrent; virtual
        // duration must reflect queueing: 8 trials x 20 steps x ~[0.5,2]s
        // over 4 slots.
        let mut r = runner(quick_spec(8, 20), 1);
        let res = r.run();
        assert_eq!(res.count(TrialStatus::Completed), 8);
        // With 4-way parallelism, duration >= total/4.
        assert!(res.duration_s >= res.budget_used_s / 4.0 - 1e-6);
        assert!(res.placement.failed > 0); // admission hit the limit
    }

    #[test]
    fn max_concurrent_is_respected() {
        let mut spec = quick_spec(6, 10);
        spec.max_concurrent = 1;
        let mut r = runner(spec, 4);
        let res = r.run();
        // Serial execution: duration == total budget.
        assert!((res.duration_s - res.budget_used_s).abs() < 1e-6);
    }

    #[test]
    fn metric_target_completes_early() {
        let mut spec = quick_spec(4, 10_000);
        spec.metric_target = Some(0.5); // accuracy >= 0.5 stops a trial
        let mut r = runner(spec, 2);
        let res = r.run();
        assert!(res.total_iterations() < 4 * 10_000);
    }

    #[test]
    fn experiment_time_budget_halts() {
        let mut spec = quick_spec(100, 1_000);
        spec.max_experiment_time_s = 50.0;
        let mut r = runner(spec, 1);
        let res = r.run();
        assert!(res.duration_s <= 55.0, "{}", res.duration_s);
        assert!(res.count(TrialStatus::Stopped) > 0);
    }

    #[test]
    fn step_failures_recover_from_checkpoints() {
        let mut spec = quick_spec(6, 30);
        spec.fault_plan = FaultPlan::flaky_steps(0.02);
        spec.checkpoint_freq = 5;
        spec.max_failures = 10;
        let mut r = runner(spec, 2);
        let res = r.run();
        assert!(res.stats.failures_recovered > 0);
        assert_eq!(res.count(TrialStatus::Completed), 6);
    }

    #[test]
    fn node_failures_reschedule_trials() {
        let mut spec = quick_spec(8, 40);
        spec.fault_plan = FaultPlan { node_failure_prob: 0.02, ..Default::default() };
        spec.checkpoint_freq = 5;
        spec.max_failures = 50;
        let mut r = runner(spec, 4);
        let res = r.run();
        let done = res.count(TrialStatus::Completed);
        assert_eq!(done, 8, "{:?}", res.stats);
    }

    #[test]
    fn best_curve_is_monotone() {
        let mut r = runner(quick_spec(20, 30), 2);
        let res = r.run();
        for w in res.best_curve.windows(2) {
            assert!(w[1].1 >= w[0].1); // Max mode: improving
            assert!(w[1].0 >= w[0].0);
        }
    }
}
