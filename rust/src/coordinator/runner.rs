//! The trial runner: Tune's central event loop.
//!
//! Owns the trial table and drives the narrow-waist protocol of §4.2:
//! when resources free up it asks the scheduler `choose_trial_to_run`
//! (pulling fresh configs from the search algorithm as needed), places
//! the trial on the Ray-like substrate, and launches it on an executor;
//! as intermediate results arrive it invokes `scheduler.on_result` and
//! applies the returned decision — continue, checkpoint, pause, stop,
//! or restart-with-mutated-config. Checkpoints provide fault tolerance
//! (trial metadata itself stays in memory, per the paper).

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::checkpoint::{CheckpointStore, CkptStoreStats};
use crate::logger::ResultLogger;
use crate::ray::{
    AutoscaleAction, AutoscalePolicy, Autoscaler, Cluster, FaultInjector, HwInputs, LeaseId,
    NodeId, PlacementStats, Resources, ThroughputProfiler, TwoLevelScheduler, Utilization,
};
use crate::util::intern::{MetricId, MetricSchema};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::executor::{Admission, ExecEvent, Executor};
use super::experiment::ExperimentSpec;
use super::persist::{
    id_map_from_json, id_map_to_json, u64_from_json, u64_to_json, ExperimentDir, FORMAT_VERSION,
};
use super::schedulers::{Decision, SchedulerCtx, TrialScheduler};
use super::search::SearchAlgorithm;
use super::trial::{Trial, TrialId, TrialStatus};

/// Counters the benches and EXPERIMENTS.md report.
#[derive(Clone, Debug, Default)]
pub struct RunnerStats {
    /// Intermediate results processed.
    pub results: u64,
    /// Checkpoints written to the store.
    pub checkpoints: u64,
    /// Restores from checkpoints (relaunches + PBT exploits).
    pub restores: u64,
    /// PBT exploit operations applied.
    pub exploits: u64,
    /// Trials stopped early by a scheduler.
    pub stopped_early: u64,
    /// Trials that reached their stopping criterion.
    pub completed: u64,
    /// Trials that exhausted `max_failures`.
    pub errored: u64,
    /// Failures recovered via checkpoint relaunch.
    pub failures_recovered: u64,
    /// Trainable launches (initial + relaunches).
    pub launches: u64,
    /// Nanoseconds spent inside scheduler callbacks (decision latency).
    pub decision_ns: u64,
    /// Nanoseconds spent in the whole handling path (runner overhead).
    pub handling_ns: u64,
    /// Experiment snapshots written to the experiment directory.
    pub snapshots: u64,
    /// Results re-executed (and suppressed) while replaying after resume.
    pub replayed: u64,
    /// Trials checkpointed and requeued off a draining node (autoscale
    /// shrink preemption — never a lost trial).
    pub preemptions: u64,
    /// Nodes added by the elastic autoscaler.
    pub scale_ups: u64,
    /// Nodes retired by the elastic autoscaler.
    pub scale_downs: u64,
    /// Sum of per-result cluster CPU-utilization samples (divide by
    /// `results` for the mean; reported by `tune run`/`analyze`).
    pub util_cpu_sum: f64,
    /// Sum of per-result cluster GPU-utilization samples.
    pub util_gpu_sum: f64,
    /// Total training iterations across all trials: the incrementally
    /// maintained mirror of summing `Trial::iteration` over the table
    /// (updated on every step and failure rollback), so finalize never
    /// rescans.
    pub total_iterations: u64,
    /// Training seconds consumed across all trials: the incrementally
    /// maintained mirror of summing `Trial::time_total_s`, same
    /// contract as `total_iterations`.
    pub budget_used_s: f64,
    /// Trials failed by node-kill handling — exactly the victims found
    /// through the per-node lease index. Scale tests assert this (and
    /// the table touches around it) stays proportional to the victim
    /// node's leases, never the trial population.
    pub kill_touched: u64,
    /// Virtual dollars accrued: the integral of the cluster's alive
    /// $/hour rate over experiment time. Stays 0.0 while every node is
    /// free (the default), so cost-blind runs report nothing new.
    pub cost_accrued: f64,
}

impl RunnerStats {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("results", Json::Num(self.results as f64)),
            ("checkpoints", Json::Num(self.checkpoints as f64)),
            ("restores", Json::Num(self.restores as f64)),
            ("exploits", Json::Num(self.exploits as f64)),
            ("stopped_early", Json::Num(self.stopped_early as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("errored", Json::Num(self.errored as f64)),
            ("failures_recovered", Json::Num(self.failures_recovered as f64)),
            ("launches", Json::Num(self.launches as f64)),
            ("decision_ns", Json::Num(self.decision_ns as f64)),
            ("handling_ns", Json::Num(self.handling_ns as f64)),
            ("snapshots", Json::Num(self.snapshots as f64)),
            ("replayed", Json::Num(self.replayed as f64)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("scale_ups", Json::Num(self.scale_ups as f64)),
            ("scale_downs", Json::Num(self.scale_downs as f64)),
            ("util_cpu_sum", Json::Num(self.util_cpu_sum)),
            ("util_gpu_sum", Json::Num(self.util_gpu_sum)),
            ("total_iterations", Json::Num(self.total_iterations as f64)),
            ("budget_used_s", Json::Num(self.budget_used_s)),
            ("kill_touched", Json::Num(self.kill_touched as f64)),
            ("cost_accrued", Json::Num(self.cost_accrued)),
        ])
    }

    fn from_json(j: &Json) -> RunnerStats {
        let g = |k: &str| j.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
        RunnerStats {
            results: g("results"),
            checkpoints: g("checkpoints"),
            restores: g("restores"),
            exploits: g("exploits"),
            stopped_early: g("stopped_early"),
            completed: g("completed"),
            errored: g("errored"),
            failures_recovered: g("failures_recovered"),
            launches: g("launches"),
            decision_ns: g("decision_ns"),
            handling_ns: g("handling_ns"),
            snapshots: g("snapshots"),
            replayed: g("replayed"),
            preemptions: g("preemptions"),
            scale_ups: g("scale_ups"),
            scale_downs: g("scale_downs"),
            total_iterations: g("total_iterations"),
            kill_touched: g("kill_touched"),
            // f64 sums (older snapshots simply lack the keys: default 0).
            util_cpu_sum: j.get("util_cpu_sum").and_then(|v| v.as_f64()).unwrap_or(0.0),
            util_gpu_sum: j.get("util_gpu_sum").and_then(|v| v.as_f64()).unwrap_or(0.0),
            budget_used_s: j.get("budget_used_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
            cost_accrued: j.get("cost_accrued").and_then(|v| v.as_f64()).unwrap_or(0.0),
        }
    }
}

/// Write a fresh base snapshot every this many delta records: bounds
/// both the delta file's size and the fold work a resume must do, while
/// keeping the common periodic snapshot O(changed).
const DELTAS_PER_BASE: u64 = 32;

/// Durable-experiment sink attached via [`TrialRunner::enable_persistence`].
struct Persist {
    dir: ExperimentDir,
    /// Snapshot every N processed results (0 = only the final snapshot).
    every: u64,
    /// `stats.results` at the last snapshot (dedup guard).
    last_snap_results: u64,
    /// Monotone id of the current base snapshot (0 = none written yet).
    /// Deltas carry it, so a crash between writing a new base and
    /// clearing the delta file can never fold stale records onto it.
    epoch: u64,
    /// Delta records appended since the current base.
    deltas_since_base: u64,
}

/// Everything an experiment run produced.
pub struct ExperimentResult {
    /// Final state of every trial, by id.
    pub trials: BTreeMap<TrialId, Trial>,
    /// Trial with the best metric value observed, if any metric was.
    pub best: Option<TrialId>,
    /// Total (virtual or wall) seconds the experiment spanned.
    pub duration_s: f64,
    /// Sum over trials of consumed training seconds (the search budget).
    pub budget_used_s: f64,
    /// Runner-level counters.
    pub stats: RunnerStats,
    /// Placement counters from the two-level scheduler.
    pub placement: PlacementStats,
    /// (experiment time, best raw metric so far) — per-result samples.
    pub best_curve: Vec<(f64, f64)>,
    /// The experiment's metric-name table: resolves the interned ids in
    /// each trial's `last_result` back to names.
    pub schema: MetricSchema,
    /// Set when `resources_per_trial` could never fit any node (current
    /// or autoscalable): the experiment failed fast with this message,
    /// launching zero trials.
    pub infeasible: Option<String>,
    /// Cluster utilization snapshot at experiment end — after an
    /// autoscaled run, `nodes_alive`/totals reflect the cluster the run
    /// actually ended on.
    pub final_utilization: Utilization,
    /// Checkpoint-store counters at experiment end: dedup ratio, tier
    /// residency, spill traffic (see [`CkptStoreStats`]).
    pub ckpt: CkptStoreStats,
}

impl ExperimentResult {
    /// Best metric value observed across the experiment.
    pub fn best_metric(&self) -> Option<f64> {
        self.best.and_then(|id| self.trials[&id].best_metric)
    }
    /// Mean cluster CPU utilization sampled at every processed result.
    pub fn mean_cpu_utilization(&self) -> f64 {
        if self.stats.results == 0 {
            0.0
        } else {
            self.stats.util_cpu_sum / self.stats.results as f64
        }
    }
    /// Mean cluster GPU utilization sampled at every processed result.
    pub fn mean_gpu_utilization(&self) -> f64 {
        if self.stats.results == 0 {
            0.0
        } else {
            self.stats.util_gpu_sum / self.stats.results as f64
        }
    }
    /// Config of the best trial.
    pub fn best_config(&self) -> Option<&super::trial::Config> {
        self.best.map(|id| &self.trials[&id].config)
    }
    /// Total training iterations across all trials.
    pub fn total_iterations(&self) -> u64 {
        self.trials.values().map(|t| t.iteration).sum()
    }
    /// Number of trials that ended in `status`.
    pub fn count(&self, status: TrialStatus) -> usize {
        self.trials.values().filter(|t| t.status == status).count()
    }
}

/// The trial table, instrumented: every keyed access bumps a touch
/// counter (a `Cell`, so shared reads count too) that the scale tests
/// read to prove per-event work stays O(log n) in the population — an
/// allocation counter cannot see a BTreeMap walk, this can. Whole-table
/// iteration is only reachable through [`TrialTable::scan`] (counted as
/// one touch per row) and [`TrialTable::map`] (uncounted, for read-only
/// context views whose consumers do their own keyed reads), which keeps
/// an accidentally reintroduced O(n) rescan grep- and test-visible.
#[derive(Default)]
struct TrialTable {
    map: BTreeMap<TrialId, Trial>,
    touches: std::cell::Cell<u64>,
}

impl TrialTable {
    fn touch(&self, n: u64) {
        self.touches.set(self.touches.get() + n);
    }
    fn get(&self, id: &TrialId) -> Option<&Trial> {
        self.touch(1);
        self.map.get(id)
    }
    fn get_mut(&mut self, id: &TrialId) -> Option<&mut Trial> {
        self.touch(1);
        self.map.get_mut(id)
    }
    fn insert(&mut self, id: TrialId, t: Trial) {
        self.touch(1);
        self.map.insert(id, t);
    }
    fn remove(&mut self, id: &TrialId) -> Option<Trial> {
        self.touch(1);
        self.map.remove(id)
    }
    fn contains_key(&self, id: &TrialId) -> bool {
        self.touch(1);
        self.map.contains_key(id)
    }
    fn clear(&mut self) {
        self.map.clear();
    }
    /// Full-table walk, counted as one touch per row: snapshot, restore
    /// and finalize only — never the per-event path.
    fn scan(&self) -> impl Iterator<Item = &Trial> + '_ {
        self.touch(self.map.len() as u64);
        self.map.values()
    }
    /// Uncounted read-only view (scheduler contexts, public accessors).
    fn map(&self) -> &BTreeMap<TrialId, Trial> {
        &self.map
    }
    /// Surrender the table (finalize moves it into the result).
    fn into_map(self) -> BTreeMap<TrialId, Trial> {
        self.map
    }
    fn touches(&self) -> u64 {
        self.touches.get()
    }
}

impl std::ops::Index<&TrialId> for TrialTable {
    type Output = Trial;
    fn index(&self, id: &TrialId) -> &Trial {
        self.touch(1);
        &self.map[id]
    }
}

/// Dense index of a [`TrialStatus`] into the runner's per-status
/// counters.
fn sidx(s: TrialStatus) -> usize {
    match s {
        TrialStatus::Pending => 0,
        TrialStatus::Running => 1,
        TrialStatus::Paused => 2,
        TrialStatus::Completed => 3,
        TrialStatus::Stopped => 4,
        TrialStatus::Errored => 5,
    }
}

/// Tune's central event loop: owns the trial table and drives the
/// scheduler/search/executor/substrate quartet to completion.
pub struct TrialRunner {
    /// The experiment being run.
    pub spec: ExperimentSpec,
    scheduler: Box<dyn TrialScheduler>,
    search: Box<dyn SearchAlgorithm>,
    executor: Box<dyn Executor>,
    cluster: Cluster,
    placer: TwoLevelScheduler,
    /// Checkpoint store (exposed for post-hoc restore tooling).
    pub checkpoints: CheckpointStore,
    fault: FaultInjector,
    trials: TrialTable,
    /// Per-status trial counts (indexed by [`sidx`]), kept in lockstep
    /// with the table by `set_status` — `num_running` and the
    /// live-budget checks are O(1) reads, never scans.
    status_counts: [usize; 6],
    /// Pending trials in ascending id (= creation) order: the explicit
    /// FIFO queue behind `SchedulerCtx::first_pending`, maintained by
    /// `set_status` so admission never rescans the table.
    pending: BTreeSet<TrialId>,
    /// Node -> trials currently leased on it: node-kill handling walks
    /// only the victim's entry, not the whole lease map.
    node_trials: BTreeMap<NodeId, BTreeSet<TrialId>>,
    leases: BTreeMap<TrialId, (NodeId, LeaseId)>,
    /// Wall/virtual time at which each running trial was (re)launched,
    /// plus previously accumulated training seconds.
    run_clock: BTreeMap<TrialId, (f64, f64)>,
    loggers: Vec<Box<dyn ResultLogger>>,
    rng: Rng,
    next_id: TrialId,
    search_exhausted: bool,
    stats: RunnerStats,
    best_curve: Vec<(f64, f64)>,
    best_so_far: Option<f64>,
    /// Experiment clock at the resumed-from snapshot; added to the fresh
    /// executor clock so experiment time is continuous across restarts.
    time_offset: f64,
    /// Per trial: highest iteration the resumed-from snapshot had
    /// already accounted for. Re-executed iterations at or below this
    /// rebuild trainable state but are suppressed from schedulers,
    /// search, loggers and stats — they already happened.
    replay_until: BTreeMap<TrialId, u64>,
    persist: Option<Persist>,
    /// The experiment's metric-name interner (ids are process-ephemeral;
    /// snapshots and logs always write names).
    schema: MetricSchema,
    /// `spec.metric` interned once — per-result metric lookups are
    /// integer compares from here on.
    metric_id: MetricId,
    /// Trials mutated since the last persisted snapshot/delta (what the
    /// next delta record carries).
    dirty: BTreeSet<TrialId>,
    /// `best_curve` length already persisted (delta cursor).
    curve_flushed: usize,
    /// Epoch and delta count of the snapshot this runner was restored
    /// from (0/0 for a fresh runner); seeds `Persist` so a resumed run
    /// keeps appending to the same delta epoch.
    restored_epoch: u64,
    restored_deltas: u64,
    /// Additional live-trial cap imposed by the hub's fair-share policy
    /// (0 = none). Orthogonal to `spec.max_concurrent`: the effective
    /// limit is the stricter of the two.
    hub_slots: usize,
    /// Resource-weighted fair share granted by the hub (None = no
    /// quota): the sum of running trials' demands must fit inside it,
    /// except that one running trial is always allowed — the vector
    /// generalization of the slot-quota's ≥1 guarantee.
    hub_share: Option<Resources>,
    /// Sum of the demands of currently Running trials (share checks).
    running_demand: Resources,
    /// Elastic autoscaler, if enabled for this experiment.
    autoscaler: Option<Autoscaler>,
    /// Cached cluster utilization, refreshed on every lease change and
    /// handed to every `SchedulerCtx`.
    util: Utilization,
    /// A pending trial failed *cluster* placement since the last
    /// autoscale tick (the scale-up pressure signal).
    unplaceable: bool,
    /// A launch was refused by *executor* capacity (shared-pool worker
    /// fleet full). Transient by construction — every reservation
    /// belongs to a running trial whose halt frees it — so the hub must
    /// keep the experiment alive rather than finalize it; and unlike
    /// `unplaceable` it must NOT feed cluster scale-up pressure (new
    /// nodes cannot relieve a full worker fleet).
    exec_exhausted: bool,
    /// Set by `preflight` when `resources_per_trial` can never fit.
    infeasible: Option<String>,
    /// Feasibility verified (caches the preflight on the happy path).
    preflight_ok: bool,
    /// Positive `demand_feasible` memo, valid while the cluster's shape
    /// epoch is unchanged (feasibility reads *total* node shapes, which
    /// only add/retire can alter) — the per-launch fail-fast check
    /// stops iterating nodes in the steady state.
    feasible_cache: Option<(Resources, u64)>,
    /// Learned (workload class, node shape) throughput profiles, fed
    /// from every non-replayed step when `spec.hw_aware` is on. Runner
    /// state like the autoscaler: snapshots and restores with the run.
    profiler: ThroughputProfiler,
    /// Experiment time up to which `stats.cost_accrued` has integrated
    /// the cluster's price rate. Advanced by `accrue_cost` — always
    /// *before* any node add/kill/restart/retire changes the rate.
    cost_clock: f64,
}

impl TrialRunner {
    /// Assemble a runner from its four pluggable parts plus a cluster.
    pub fn new(
        spec: ExperimentSpec,
        scheduler: Box<dyn TrialScheduler>,
        search: Box<dyn SearchAlgorithm>,
        executor: Box<dyn Executor>,
        cluster: Cluster,
    ) -> Self {
        let rng = Rng::new(spec.seed);
        let fault = FaultInjector::new(spec.fault_plan.clone(), spec.seed ^ 0xFA17);
        let mut schema = MetricSchema::new();
        let metric_id = schema.intern(&spec.metric);
        let util = cluster.utilization();
        TrialRunner {
            spec,
            scheduler,
            search,
            executor,
            cluster,
            placer: TwoLevelScheduler::new(),
            checkpoints: CheckpointStore::new(),
            fault,
            trials: TrialTable::default(),
            status_counts: [0; 6],
            pending: BTreeSet::new(),
            node_trials: BTreeMap::new(),
            leases: BTreeMap::new(),
            run_clock: BTreeMap::new(),
            loggers: Vec::new(),
            rng,
            next_id: 0,
            search_exhausted: false,
            stats: RunnerStats::default(),
            best_curve: Vec::new(),
            best_so_far: None,
            time_offset: 0.0,
            replay_until: BTreeMap::new(),
            persist: None,
            schema,
            metric_id,
            dirty: BTreeSet::new(),
            curve_flushed: 0,
            restored_epoch: 0,
            restored_deltas: 0,
            hub_slots: 0,
            hub_share: None,
            running_demand: Resources::default(),
            autoscaler: None,
            util,
            unplaceable: false,
            exec_exhausted: false,
            infeasible: None,
            preflight_ok: false,
            feasible_cache: None,
            profiler: ThroughputProfiler::new(),
            cost_clock: 0.0,
        }
    }

    /// Enable elastic autoscaling of this experiment's cluster.
    pub fn set_autoscaler(&mut self, policy: AutoscalePolicy) {
        self.autoscaler = Some(Autoscaler::new(policy));
    }

    /// Current cluster utilization snapshot (what `tune status` shows).
    pub fn utilization(&self) -> Utilization {
        self.util
    }

    fn refresh_util(&mut self) {
        self.util = self.cluster.utilization();
    }

    /// The experiment's metric-name table (interned ids <-> names).
    pub fn schema(&self) -> &MetricSchema {
        &self.schema
    }

    /// Experiment time: the executor clock plus the offset carried over
    /// from the snapshot a resumed run restarted from.
    fn clock(&self) -> f64 {
        self.time_offset + self.executor.now()
    }

    /// Attach a result logger (fan-out on every intermediate result).
    pub fn add_logger(&mut self, logger: Box<dyn ResultLogger>) {
        self.loggers.push(logger);
    }

    /// Read-only view of the trial table.
    pub fn trials(&self) -> &BTreeMap<TrialId, Trial> {
        self.trials.map()
    }

    /// Pull one fresh config from the search algorithm into the pool.
    fn create_trial(&mut self) -> Option<TrialId> {
        if self.search_exhausted {
            return None;
        }
        let Some(config) = self.search.next_config(&mut self.rng) else {
            self.search_exhausted = true;
            return None;
        };
        let id = self.next_id;
        self.next_id += 1;
        let seed = self.rng.fork(id).next_u64();
        let trial = Trial::new(id, config, self.spec.resources_per_trial.clone(), seed);
        self.scheduler.on_trial_add(
            &SchedulerCtx {
                trials: self.trials.map(),
                pending: &self.pending,
                metric_id: self.metric_id,
                mode: self.spec.mode,
                utilization: self.util,
            },
            &trial,
        );
        self.trials.insert(id, trial);
        // A fresh trial is born Pending: index it directly (set_status
        // handles every transition after this point).
        self.status_counts[sidx(TrialStatus::Pending)] += 1;
        self.pending.insert(id);
        self.dirty.insert(id);
        Some(id)
    }

    pub(crate) fn num_running(&self) -> usize {
        self.status_counts[sidx(TrialStatus::Running)]
    }

    /// The single choke point for status transitions after creation:
    /// mutates the trial and keeps the per-status counters and the
    /// Pending queue in lockstep — O(log n) keyed work, no scans.
    fn set_status(&mut self, id: TrialId, to: TrialStatus) {
        let t = self.trials.get_mut(&id).expect("status change on unknown trial");
        let from = t.status;
        if from == to {
            return;
        }
        t.status = to;
        self.status_counts[sidx(from)] -= 1;
        self.status_counts[sidx(to)] += 1;
        if from == TrialStatus::Pending {
            self.pending.remove(&id);
        }
        if to == TrialStatus::Pending {
            self.pending.insert(id);
        }
    }

    /// Cap the number of live trials from outside (the hub's fair-share
    /// admission). 0 lifts the cap. Takes effect at the next admission
    /// pass; already-running trials above a shrunk cap finish their
    /// current steps normally and are simply not topped up.
    pub(crate) fn set_slot_limit(&mut self, slots: usize) {
        self.hub_slots = slots;
    }

    /// Resource-weighted fair share (the vector generalization of
    /// [`TrialRunner::set_slot_limit`]): the sum of running trials'
    /// demands must fit inside `share`, except that one running trial
    /// is always allowed — so fault recovery can never deadlock behind
    /// a shrunken quota. `None` lifts the quota.
    pub(crate) fn set_resource_share(&mut self, share: Option<Resources>) {
        self.hub_share = share;
    }

    /// Admission: launch trials while the scheduler has candidates and
    /// the cluster has room.
    fn admit(&mut self) {
        loop {
            let running = self.num_running();
            if self.spec.max_concurrent > 0 && running >= self.spec.max_concurrent {
                return;
            }
            if self.hub_slots > 0 && running >= self.hub_slots {
                return;
            }
            // Ask the scheduler first (it may resume paused trials);
            // otherwise try to create a fresh trial.
            let mut choice = {
                let ctx = SchedulerCtx {
                    trials: self.trials.map(),
                    pending: &self.pending,
                    metric_id: self.metric_id,
                    mode: self.spec.mode,
                    utilization: self.util,
                };
                self.scheduler.choose_trial_to_run(&ctx)
            };
            if choice.is_none() {
                if self.create_trial().is_none() {
                    return;
                }
                let ctx = SchedulerCtx {
                    trials: self.trials.map(),
                    pending: &self.pending,
                    metric_id: self.metric_id,
                    mode: self.spec.mode,
                    utilization: self.util,
                };
                choice = self.scheduler.choose_trial_to_run(&ctx);
            }
            let Some(id) = choice else { return };
            if !self.launch(id) {
                return; // no resources (or broken trial): stop admitting
            }
        }
    }

    /// Place + start one trial. Returns false when out of resources
    /// (cluster, executor capacity or fair share) — the trial parks as
    /// Pending; true otherwise (including a fail-fast Errored finish
    /// for a demand that can never run anywhere).
    fn launch(&mut self, id: TrialId) -> bool {
        let demand = self.trials[&id].resources.clone();
        // Fail fast: a demand that no node shape — current, restartable
        // or autoscalable — could ever hold would otherwise park as
        // Pending forever.
        if let Err(e) = self.demand_feasible(&demand) {
            eprintln!("trial {id}: demand {demand} is unsatisfiable: {e}");
            self.finish(id, TrialStatus::Errored);
            return true; // keep admitting others
        }
        // Hub fair share: the vector quota binds only past the first
        // running trial (the ≥1 guarantee).
        if let Some(share) = &self.hub_share {
            if self.num_running() > 0 {
                let mut want = self.running_demand.clone();
                want.release(&demand);
                if !share.fits(&want) {
                    return false;
                }
            }
        }
        // Executor-side capacity (pool worker vectors).
        match self.executor.admit(id, &demand) {
            Admission::Granted => {}
            Admission::Exhausted => {
                self.exec_exhausted = true;
                return false;
            }
            Admission::Infeasible => {
                eprintln!("trial {id}: demand {demand} exceeds every executor worker");
                self.finish(id, TrialStatus::Errored);
                return true;
            }
        }
        // Trial drivers originate on the head node (node 0), matching
        // Tune-on-Ray's driver placement; children would spill.
        let Some(p) = self.place_trial(id, &demand) else {
            self.executor.halt(id); // release the capacity reservation
            self.unplaceable = true;
            return false;
        };
        // Tell the executor which shape the trial landed on before it
        // builds the trainable — the sim executor derives its planted
        // step-time multiplier from this (wall-clock executors ignore
        // it; real hardware is its own speed).
        let placed_shape = self.cluster.node(p.node).total.clone();
        self.executor.place_hint(id, &placed_shape);
        // Shared checkpoint handle: a relaunch hands the executor the
        // store's own Arc, never a byte copy.
        let restore = self.trials[&id].checkpoint.and_then(|c| self.checkpoints.get(c));
        if restore.is_none() && self.trials[&id].checkpoint.is_some() {
            // The recorded checkpoint no longer loads (e.g. a spilled
            // chunk file torn after restore validated it). Degrade to
            // replay-from-scratch instead of launching a fresh
            // trainable against stale table progress: roll the trial —
            // and the incremental experiment totals, which normally
            // only `rebuild_indexes` recomputes — back to zero, and
            // suppress duplicate log rows up to the old position.
            let t = self.trials.get_mut(&id).unwrap();
            let (old_iter, old_time) = (t.iteration, t.time_total_s);
            let until = self.replay_until.get(&id).copied().unwrap_or(0).max(old_iter);
            t.checkpoint = None;
            t.iteration = 0;
            t.time_total_s = 0.0;
            if until > 0 {
                self.replay_until.insert(id, until);
            }
            self.stats.total_iterations -= old_iter;
            self.stats.budget_used_s -= old_time;
            self.dirty.insert(id);
            eprintln!("trial {id}: checkpoint unreadable; restarting from scratch");
        }
        let restored = restore.is_some();
        let trial = self.trials.get_mut(&id).unwrap();
        trial.node = Some(p.node);
        let acc = trial.time_total_s;
        match self.executor.launch(trial, restore) {
            Ok(()) => {
                self.set_status(id, TrialStatus::Running);
                self.dirty.insert(id);
                self.leases.insert(id, (p.node, p.lease));
                self.node_trials.entry(p.node).or_default().insert(id);
                let started = self.time_offset + self.executor.now();
                self.run_clock.insert(id, (started, acc));
                self.running_demand.release(&demand); // add to the sum
                self.refresh_util();
                self.stats.launches += 1;
                if restored {
                    self.stats.restores += 1;
                }
                self.executor.request_step(id);
                true
            }
            Err(e) => {
                self.cluster.release(p.node, p.lease);
                eprintln!("trial {id} failed to launch: {e}");
                self.finish(id, TrialStatus::Errored);
                true // keep admitting others
            }
        }
    }

    /// Place one trial: the legacy two-level local-first path, or —
    /// with `spec.hw_aware` on and ≥2 warm shape profiles for the
    /// trial's workload class — a ranked scan choosing the node that
    /// maximizes predicted steps/sec divided by opportunity cost
    /// (SHADHO's routing rule: fast hardware for work that exploits
    /// it, without squatting on scarce shapes). Cold workloads stay on
    /// the legacy path, so with the flag off — or before warmup — the
    /// placement stream is byte-identical to the pre-hardware-aware
    /// runner.
    fn place_trial(&mut self, id: TrialId, demand: &Resources) -> Option<crate::ray::Placement> {
        if self.spec.hw_aware {
            let workload = self.trials[&id].workload_class().to_string();
            if self.profiler.is_warm(&workload) {
                // Score each distinct shape once (profiles are keyed by
                // shape, not node), then rank nodes through the memo —
                // deterministic and O(nodes) total.
                let mut scores: BTreeMap<String, f64> = BTreeMap::new();
                for n in self.cluster.alive_nodes() {
                    let key = crate::ray::shape_key(&n.total);
                    if !scores.contains_key(&key) {
                        let sps = self.profiler.predict_or_prior(&workload, &key);
                        let score = sps / crate::ray::opportunity_cost(demand, &n.total);
                        scores.insert(key, score);
                    }
                }
                return self.placer.place_ranked(&mut self.cluster, 0, demand, |n| {
                    scores.get(&crate::ray::shape_key(&n.total)).copied().unwrap_or(0.0)
                });
            }
        }
        self.placer.place(&mut self.cluster, 0, demand)
    }

    fn release(&mut self, id: TrialId) {
        if let Some((node, lease)) = self.leases.remove(&id) {
            self.cluster.release(node, lease);
            self.running_demand.acquire(&self.trials[&id].resources);
            if let Some(set) = self.node_trials.get_mut(&node) {
                set.remove(&id);
                if set.is_empty() {
                    // Keep the index minimal: absent == no trials, so a
                    // full-scan reference compares byte-equal.
                    self.node_trials.remove(&node);
                }
            }
            self.maybe_finish_drain(node);
            self.refresh_util();
        }
        self.run_clock.remove(&id);
    }

    /// Retire a draining node once its last lease is gone (the final
    /// step of an autoscale shrink).
    fn maybe_finish_drain(&mut self, node: NodeId) {
        let idle = {
            let n = self.cluster.node(node);
            n.alive && n.draining && n.leases.is_empty()
        };
        if idle {
            // The node billed up to this instant; settle before its
            // price leaves the cluster rate.
            self.accrue_cost();
            self.cluster.retire_node(node);
            self.stats.scale_downs += 1;
        }
    }

    /// Integrate the cluster's alive $/hour rate over experiment time
    /// since the last settlement. Must run before any action that
    /// changes the rate (add/kill/restart/retire), so each interval is
    /// billed at the rate that actually held during it. A free cluster
    /// (every price 0.0 — the default) accrues exactly 0.0.
    fn accrue_cost(&mut self) {
        let now = self.clock();
        let dt = now - self.cost_clock;
        if dt > 0.0 {
            self.stats.cost_accrued += self.cluster.price_rate() * dt / 3600.0;
            self.cost_clock = now;
        }
    }

    /// True once the accrued virtual spend has reached the spec's
    /// `budget.max_cost` hard cap (never true without a cap).
    fn cost_exhausted(&self) -> bool {
        self.spec.budget_max_cost.map_or(false, |max| self.stats.cost_accrued >= max)
    }

    fn finish(&mut self, id: TrialId, status: TrialStatus) {
        self.executor.halt(id);
        self.release(id);
        self.set_status(id, status);
        let (config, last_metric) = {
            let t = &self.trials[&id];
            (t.config.clone(), t.last_result.as_ref().and_then(|r| r.get(self.metric_id)))
        };
        self.dirty.insert(id);
        match status {
            TrialStatus::Completed => self.stats.completed += 1,
            TrialStatus::Stopped => self.stats.stopped_early += 1,
            TrialStatus::Errored => self.stats.errored += 1,
            _ => {}
        }
        let ctx = SchedulerCtx {
            trials: self.trials.map(),
            pending: &self.pending,
            metric_id: self.metric_id,
            mode: self.spec.mode,
            utilization: self.util,
        };
        self.scheduler.on_trial_remove(&ctx, id);
        self.search.on_complete(&config, last_metric, self.spec.mode);
        let t = &self.trials[&id];
        for l in &mut self.loggers {
            l.on_trial_end(t);
        }
    }

    fn save_checkpoint(&mut self, id: TrialId) {
        if let Some(blob) = self.executor.save(id) {
            let (iter, time) = {
                let t = &self.trials[&id];
                (t.iteration, t.time_total_s)
            };
            let cid = self.checkpoints.save_timed(id, iter, time, blob);
            self.trials.get_mut(&id).unwrap().checkpoint = Some(cid);
            self.dirty.insert(id);
            self.stats.checkpoints += 1;
        }
    }

    fn handle_failure(&mut self, id: TrialId, error: &str) {
        self.executor.halt(id);
        self.release(id);
        self.dirty.insert(id);
        let max_failures = self.spec.max_failures;
        let t = self.trials.get_mut(&id).unwrap();
        t.num_failures += 1;
        if t.num_failures <= max_failures {
            // Recover: back to Pending; relaunch restores the latest
            // checkpoint (possibly iteration 0 if none exists).
            let (old_iter, old_time) = (t.iteration, t.time_total_s);
            if t.checkpoint.is_none() {
                t.iteration = 0;
                t.time_total_s = 0.0;
            } else if let Some(c) = t.checkpoint {
                // Roll visible progress back to the checkpoint.
                if let Some(m) = self.checkpoints.meta(c) {
                    t.iteration = m.iteration;
                    t.time_total_s = m.time_total_s;
                }
            }
            // Roll the incremental totals back with the trial.
            let (new_iter, new_time) = (t.iteration, t.time_total_s);
            self.stats.total_iterations -= old_iter - new_iter;
            self.stats.budget_used_s -= old_time - new_time;
            self.set_status(id, TrialStatus::Pending);
            self.stats.failures_recovered += 1;
        } else {
            eprintln!("trial {id} errored permanently: {error}");
            self.finish(id, TrialStatus::Errored);
        }
    }

    fn apply_decision(&mut self, id: TrialId, decision: Decision) {
        match decision {
            Decision::Continue => self.executor.request_step(id),
            Decision::Checkpoint => {
                self.save_checkpoint(id);
                self.executor.request_step(id);
            }
            Decision::Pause => self.shed(id, TrialStatus::Paused),
            Decision::Stop => self.finish(id, TrialStatus::Stopped),
            Decision::Exploit { source, config } => {
                let donor = self
                    .trials
                    .get(&source)
                    .and_then(|t| t.checkpoint)
                    .or_else(|| self.checkpoints.latest_for(source));
                match donor.and_then(|c| self.checkpoints.get(c)) {
                    Some(blob) => {
                        // The donor blob is cloned by refcount: executor
                        // restore and the exploiter's new checkpoint all
                        // share one allocation.
                        if self.executor.restore(id, Arc::clone(&blob)).is_ok() {
                            let (iter, time) = {
                                let t = &self.trials[&id];
                                (t.iteration, t.time_total_s)
                            };
                            let cid = self.checkpoints.save_timed(id, iter, time, blob);
                            let t = self.trials.get_mut(&id).unwrap();
                            t.config = config.clone();
                            t.checkpoint = Some(cid);
                            t.mutations += 1;
                            self.dirty.insert(id);
                            self.executor.update_config(id, &config);
                            self.stats.exploits += 1;
                            self.stats.restores += 1;
                        }
                        self.executor.request_step(id);
                    }
                    None => {
                        // No donor checkpoint yet: mutate config only.
                        let t = self.trials.get_mut(&id).unwrap();
                        t.config = config.clone();
                        t.mutations += 1;
                        self.dirty.insert(id);
                        self.executor.update_config(id, &config);
                        self.executor.request_step(id);
                    }
                }
            }
        }
    }

    fn handle_stepped(&mut self, id: TrialId, out: crate::trainable::StepOutput) {
        if self.trials.get(&id).map(|t| t.status) != Some(TrialStatus::Running) {
            return; // stale event from a halted worker
        }
        if self.fault.step_fails() {
            self.handle_failure(id, "injected step failure");
            return;
        }
        if out.done {
            self.finish(id, TrialStatus::Completed);
            return;
        }
        let now = self.clock();
        let (iteration, step_dt) = {
            let (started, acc) = self.run_clock[&id];
            let t = self.trials.get_mut(&id).unwrap();
            let iteration = t.iteration + 1;
            let prev_time = t.time_total_s;
            // Build the row in place inside the trial, reusing the
            // previous `last_result` allocation: the hot path performs
            // no row clone and (steady state) no row allocation at all.
            t.record_step(
                iteration,
                acc + (now - started),
                &out.metrics,
                &mut self.schema,
                self.metric_id,
                self.spec.mode,
            );
            // The incremental totals mirror the table through every
            // step — including replayed ones, which advance the trial
            // exactly like the original execution did.
            self.stats.total_iterations += 1;
            self.stats.budget_used_s += t.time_total_s - prev_time;
            (iteration, t.time_total_s - prev_time)
        };
        self.dirty.insert(id);
        // The metric value is Copy — grab it once; the row itself is
        // re-borrowed from the trial wherever a consumer needs it.
        let metric_val = self.trials[&id].last_result.as_ref().and_then(|r| r.get(self.metric_id));

        // Crash-resume replay: iterations the snapshot had already
        // accounted for re-execute (to rebuild trainable state and the
        // durable logs) but are suppressed from scheduler/search/stats
        // and live reporters — the restored state already reflects them.
        let replaying = matches!(self.replay_until.get(&id), Some(&u) if iteration <= u);

        // Hot path: no Trial clone — loggers/search/scheduler live in
        // disjoint fields, so shared borrows of `trials` coexist with
        // mutable borrows of each consumer (perf iteration 1, §Perf).
        {
            let t = &self.trials[&id];
            let row = t.last_result.as_ref().expect("record_step just set last_result");
            for l in &mut self.loggers {
                if replaying {
                    l.on_replayed_result(&self.schema, t, row);
                } else {
                    l.on_result(&self.schema, t, row);
                }
            }
        }

        if replaying {
            if Some(&iteration) == self.replay_until.get(&id) {
                self.replay_until.remove(&id); // caught up
            }
            self.stats.replayed += 1;
            self.executor.request_step(id);
            return;
        }
        self.replay_until.remove(&id);
        self.stats.results += 1;
        self.stats.util_cpu_sum += self.util.cpu_frac();
        self.stats.util_gpu_sum += self.util.gpu_frac();

        // Feed the throughput profiler: one observed step of this
        // workload class on the shape it is leased on. Replayed steps
        // were observed by the original execution and are suppressed
        // above — restore brings the profiles back instead.
        if self.spec.hw_aware && step_dt > 0.0 {
            if let Some((node, _)) = self.leases.get(&id) {
                let key = crate::ray::shape_key(&self.cluster.node(*node).total);
                let workload = self.trials[&id].workload_class().to_string();
                self.profiler.observe(&workload, &key, step_dt);
            }
        }

        // Best-so-far curve (experiment time axis). A NaN (diverged)
        // metric never enters the curve: as a *first* result it would
        // otherwise stick — `mode.better` is false against NaN in both
        // directions — and report a NaN "best" forever.
        if let Some(v) = metric_val {
            if !v.is_nan() {
                let better = self.best_so_far.map_or(true, |b| self.spec.mode.better(v, b));
                if better {
                    self.best_so_far = Some(v);
                    self.best_curve.push((now, v));
                }
            }
        }

        {
            let t = &self.trials[&id];
            let row = t.last_result.as_ref().expect("record_step just set last_result");
            self.search.on_result(&t.config, row);
        }

        // Runner-level stopping criteria outrank the scheduler.
        let target_hit = match (self.spec.metric_target, metric_val) {
            (Some(tgt), Some(v)) => self.spec.mode.better(v, tgt) || v == tgt,
            _ => false,
        };
        if iteration >= self.spec.max_iterations_per_trial || target_hit {
            // Final checkpoint so results are restorable post-hoc.
            if self.spec.checkpoint_at_end {
                self.save_checkpoint(id);
            }
            self.finish(id, TrialStatus::Completed);
            return;
        }
        // Periodic checkpointing orthogonal to scheduler decisions.
        if self.spec.checkpoint_freq > 0 && iteration % self.spec.checkpoint_freq == 0 {
            self.save_checkpoint(id);
        }

        let decision = {
            // lint:allow(clock): perf counter (decision_ns); never feeds trial state
            let t0 = std::time::Instant::now();
            let ctx = SchedulerCtx {
                trials: self.trials.map(),
                pending: &self.pending,
                metric_id: self.metric_id,
                mode: self.spec.mode,
                utilization: self.util,
            };
            let t = &self.trials[&id];
            let row = t.last_result.as_ref().expect("record_step just set last_result");
            let d = self.scheduler.on_result(&ctx, t, row);
            self.stats.decision_ns += t0.elapsed().as_nanos() as u64;
            d
        };
        // A trial on a draining node is shed at this result boundary
        // (its trainable is idle right now): checkpoint-then-requeue
        // instead of stepping on. Terminal/pausing decisions already
        // release the node, so only keep-going decisions are
        // intercepted; an Exploit proceeds and is preempted at its next
        // result.
        let draining = self
            .leases
            .get(&id)
            .map_or(false, |(node, _)| self.cluster.node(*node).draining);
        if draining && matches!(decision, Decision::Continue | Decision::Checkpoint) {
            self.preempt(id);
        } else {
            self.apply_decision(id, decision);
        }

        // Out-of-band terminations (HyperBand rung cuts).
        for victim in self.scheduler.drain_stops() {
            if !self.trials[&victim].status.is_terminal() {
                self.finish(victim, TrialStatus::Stopped);
            }
        }
    }

    /// Attach a durable experiment directory: trainable checkpoints
    /// spill under `<dir>/checkpoints/` and the runner writes an atomic
    /// state snapshot every `snapshot_every` processed results (0 =
    /// final snapshot only). Together with a `JsonlLogger` rooted at the
    /// same directory this makes the experiment resumable after a crash
    /// — see `coordinator::persist` for the on-disk layout.
    pub fn enable_persistence(&mut self, dir: ExperimentDir, snapshot_every: u64) {
        self.checkpoints =
            std::mem::take(&mut self.checkpoints).with_disk(dir.checkpoints_dir());
        self.persist = Some(Persist {
            dir,
            every: snapshot_every,
            last_snap_results: self.stats.results,
            // A resumed runner keeps appending deltas to the epoch it
            // restored (its in-memory state equals base + folded deltas
            // exactly); a fresh runner starts at 0, forcing a base on
            // the first snapshot.
            epoch: self.restored_epoch,
            deltas_since_base: self.restored_deltas,
        });
    }

    /// Serialize the complete mutable runner state (trial table, clock,
    /// RNG, scheduler, search, checkpoint manifest, counters) as a BASE
    /// snapshot stamped with its delta `epoch`.
    fn snapshot_json(&self, finished: bool, epoch: u64) -> Json {
        Json::obj(vec![
            ("version", Json::Num(FORMAT_VERSION as f64)),
            ("finished", Json::Bool(finished)),
            ("delta_epoch", Json::Num(epoch as f64)),
            ("now", Json::Num(self.clock())),
            ("next_id", Json::Num(self.next_id as f64)),
            ("search_exhausted", Json::Bool(self.search_exhausted)),
            ("rng", u64_to_json(self.rng.state())),
            ("best_so_far", self.best_so_far.map(Json::Num).unwrap_or(Json::Null)),
            (
                "best_curve",
                Json::Arr(
                    self.best_curve
                        .iter()
                        .map(|(t, v)| Json::Arr(vec![Json::Num(*t), Json::Num(*v)]))
                        .collect(),
                ),
            ),
            ("stats", self.stats.to_json()),
            (
                "replay_until",
                id_map_to_json(&self.replay_until, |v| Json::Num(*v as f64)),
            ),
            ("fault", self.fault.snapshot()),
            // Autoscaled runs must resume on the cluster they actually
            // grew (plus the autoscaler's counters), not the initial
            // shape.
            ("cluster", self.cluster.snapshot()),
            (
                "autoscaler",
                self.autoscaler.as_ref().map(|a| a.snapshot()).unwrap_or(Json::Null),
            ),
            ("profiler", self.profiler.snapshot()),
            ("checkpoints", self.checkpoints.snapshot()),
            ("scheduler", self.scheduler.snapshot()),
            ("search", self.search.snapshot()),
            (
                "trials",
                Json::Arr(self.trials.scan().map(|t| t.to_json(&self.schema)).collect()),
            ),
        ])
    }

    /// Serialize only what changed since the last persisted record:
    /// cheap scalar state in full, plus dirty trials, appended
    /// best-curve points, scheduler/search/checkpoint deltas. Drains
    /// every delta cursor.
    fn delta_json(&mut self, finished: bool, epoch: u64) -> Json {
        let curve_append: Vec<Json> = self.best_curve[self.curve_flushed..]
            .iter()
            .map(|(t, v)| Json::Arr(vec![Json::Num(*t), Json::Num(*v)]))
            .collect();
        self.curve_flushed = self.best_curve.len();
        let trials: Vec<Json> = self
            .dirty
            .iter()
            .filter_map(|id| self.trials.get(id))
            .map(|t| t.to_json(&self.schema))
            .collect();
        self.dirty.clear();
        Json::obj(vec![
            ("epoch", Json::Num(epoch as f64)),
            ("finished", Json::Bool(finished)),
            ("now", Json::Num(self.clock())),
            ("next_id", Json::Num(self.next_id as f64)),
            ("search_exhausted", Json::Bool(self.search_exhausted)),
            ("rng", u64_to_json(self.rng.state())),
            ("best_so_far", self.best_so_far.map(Json::Num).unwrap_or(Json::Null)),
            ("best_curve_append", Json::Arr(curve_append)),
            ("stats", self.stats.to_json()),
            (
                "replay_until",
                id_map_to_json(&self.replay_until, |v| Json::Num(*v as f64)),
            ),
            ("fault", self.fault.snapshot()),
            // Small (a handful of nodes): carried in full per record.
            ("cluster", self.cluster.snapshot()),
            (
                "autoscaler",
                self.autoscaler.as_ref().map(|a| a.snapshot()).unwrap_or(Json::Null),
            ),
            // Small (one entry per warm workload x shape pair): carried
            // in full per record, like the cluster.
            ("profiler", self.profiler.snapshot()),
            ("checkpoints", self.checkpoints.snapshot_delta()),
            ("scheduler", self.scheduler.snapshot_delta()),
            ("search", self.search.snapshot_delta()),
            ("trials", Json::Arr(trials)),
        ])
    }

    /// Reset every delta cursor after a base snapshot was persisted:
    /// the base contains everything, so the next delta starts empty.
    fn reset_delta_cursors(&mut self) {
        self.scheduler.reset_delta_cursor();
        self.search.reset_delta_cursor();
        self.checkpoints.reset_delta_cursor();
        self.dirty.clear();
        self.curve_flushed = self.best_curve.len();
    }

    /// Write a snapshot if the cadence says one is due.
    fn maybe_snapshot(&mut self) -> bool {
        let due = match &self.persist {
            Some(p) => {
                p.every > 0
                    && self.stats.results != p.last_snap_results
                    && self.stats.results % p.every == 0
            }
            None => false,
        };
        if due {
            self.write_snapshot(false);
        }
        due
    }

    /// Persist current state: a compact delta in the steady state, a
    /// fresh base on the first snapshot, every [`DELTAS_PER_BASE`]
    /// deltas (compaction), and at experiment end.
    fn write_snapshot(&mut self, finished: bool) {
        let (epoch, deltas_since_base) = match &self.persist {
            Some(p) => (p.epoch, p.deltas_since_base),
            None => return,
        };
        self.stats.snapshots += 1; // counted in the snapshot itself
        if finished || epoch == 0 || deltas_since_base >= DELTAS_PER_BASE {
            self.write_base(finished);
            return;
        }
        let delta = self.delta_json(finished, epoch); // drains the cursors
        let results = self.stats.results;
        let mut append_failed = false;
        if let Some(p) = &mut self.persist {
            match p.dir.append_delta(&delta) {
                Ok(()) => {
                    p.deltas_since_base += 1;
                    p.last_snap_results = results;
                }
                Err(e) => {
                    eprintln!("experiment delta append failed: {e}");
                    append_failed = true;
                }
            }
        }
        if append_failed {
            // The drained window exists only in memory now. A later
            // delta folded over this hole would silently diverge a
            // resume, so fall back to a full base immediately — it
            // contains the whole window (and everything else).
            self.write_base(finished);
        }
    }

    /// Write a full base snapshot. On success the delta file is cleared
    /// and every delta cursor reset; on failure the old base + delta
    /// file stay untouched (still mutually consistent) and further
    /// deltas are blocked until a base succeeds — a delta chain must
    /// never span a gap in the durable record.
    fn write_base(&mut self, finished: bool) {
        let Some(epoch) = self.persist.as_ref().map(|p| p.epoch + 1) else { return };
        let snap = self.snapshot_json(finished, epoch);
        let results = self.stats.results;
        let mut base_written = false;
        if let Some(p) = &mut self.persist {
            match p.dir.write_snapshot(&snap) {
                Ok(()) => {
                    // Ordering matters: the new base (with its new
                    // epoch) is durable before the old deltas vanish; a
                    // crash in between leaves stale-epoch deltas that
                    // restore skips.
                    if let Err(e) = p.dir.clear_deltas() {
                        eprintln!("clearing experiment deltas failed: {e}");
                    }
                    p.epoch = epoch;
                    p.deltas_since_base = 0;
                    base_written = true;
                }
                Err(e) => {
                    eprintln!("experiment snapshot write failed: {e}");
                    // Retry a base (never a delta) at the NEXT cadence
                    // window; the accumulating cursors stay live and
                    // land in it.
                    p.deltas_since_base = DELTAS_PER_BASE;
                }
            }
            // Advance the dedup guard on failure too: one attempt per
            // cadence window, not one per executor event.
            p.last_snap_results = results;
        }
        if base_written {
            self.reset_delta_cursors();
        }
    }

    /// Resume fallback for a trial whose checkpoint blob did not
    /// survive: restart it from iteration 0 and replay (suppressed) up
    /// to the progress the snapshot had recorded.
    fn degrade_to_scratch(&mut self, t: &mut Trial) {
        // Never *shrink* an existing suppression window: the restore
        // path may already have recorded progress past t.iteration.
        let until = self.replay_until.get(&t.id).copied().unwrap_or(0).max(t.iteration);
        t.status = TrialStatus::Pending;
        t.checkpoint = None;
        t.iteration = 0;
        t.time_total_s = 0.0;
        if until > 0 {
            self.replay_until.insert(t.id, until);
        }
    }

    /// Apply the scalar fields shared by base snapshots and delta
    /// records (`now`, `next_id`, rng, best-so-far, stats, replay map,
    /// fault injector). Returns the record's `finished` flag.
    fn apply_scalars(&mut self, j: &Json) -> Result<bool, String> {
        let finished = j.get("finished").and_then(|v| v.as_bool()).unwrap_or(false);
        self.time_offset =
            j.get("now").and_then(|v| v.as_f64()).ok_or("snapshot: missing clock")?;
        // Cost up to the snapshot is inside the restored stats; billing
        // resumes from the snapshot's clock.
        self.cost_clock = self.time_offset;
        self.next_id =
            j.get("next_id").and_then(|v| v.as_u64()).ok_or("snapshot: missing next_id")?;
        self.search_exhausted = finished
            || j.get("search_exhausted")
                .and_then(|v| v.as_bool())
                .ok_or("snapshot: missing search_exhausted")?;
        let rng_state =
            j.get("rng").and_then(u64_from_json).ok_or("snapshot: missing rng state")?;
        self.rng.set_state(rng_state);
        self.best_so_far = j.get("best_so_far").and_then(|v| v.as_f64());
        self.stats = j.get("stats").map(RunnerStats::from_json).unwrap_or_default();
        self.replay_until = j
            .get("replay_until")
            .and_then(|m| id_map_from_json(m, |v| v.as_u64()))
            .unwrap_or_default();
        if let Some(f) = j.get("fault") {
            self.fault.restore(f)?;
        }
        // Pre-resource-aware snapshots lack these keys: keep the
        // constructor-provided cluster / a cold autoscaler then.
        if let Some(cj) = j.get("cluster") {
            self.cluster = Cluster::restore_nodes(cj)?;
        }
        if let Some(aj) = j.get("autoscaler") {
            if let (Some(a), false) = (self.autoscaler.as_mut(), matches!(aj, Json::Null)) {
                a.restore(aj)?;
            }
        }
        // Pre-hardware-aware snapshots lack the key: stay cold then.
        if let Some(pj) = j.get("profiler") {
            self.profiler.restore(pj)?;
        }
        Ok(finished)
    }

    fn parse_curve(points: &[Json]) -> Result<Vec<(f64, f64)>, String> {
        points
            .iter()
            .map(|p| {
                let a = p.as_arr()?;
                Some((a.first()?.as_f64()?, a.get(1)?.as_f64()?))
            })
            .collect::<Option<_>>()
            .ok_or_else(|| "snapshot: bad best_curve point".to_string())
    }

    /// Rebuild runner state from the snapshot in `dir`, so [`Self::run`]
    /// continues the experiment instead of starting over. The runner
    /// must have been freshly constructed with the same spec, scheduler
    /// and search selections the snapshot was written under. The base
    /// snapshot is restored first, then every delta record with a
    /// matching epoch is folded in order (dirty-trial upserts, appended
    /// curve points, incremental scheduler/checkpoint state) — a
    /// pre-delta directory (full snapshot only) folds nothing and
    /// restores exactly as before. Running trials are then rolled back
    /// to their latest durable checkpoint and their already-accounted
    /// iterations are replayed with suppression (see `replay_until`);
    /// paused and terminal trials restore as-is. Also prunes each
    /// non-terminal trial's JSONL log back to the restored state so
    /// resumed logging never duplicates rows.
    pub fn restore_from_dir(&mut self, dir: &ExperimentDir) -> Result<(), String> {
        let snap = dir.read_snapshot().ok_or("no readable snapshot in experiment dir")?;
        let version = snap
            .get("version")
            .and_then(|v| v.as_u64())
            .ok_or("snapshot: missing version")?;
        if version != FORMAT_VERSION {
            return Err(format!(
                "snapshot format v{version} is not supported (this build reads v{FORMAT_VERSION})"
            ));
        }
        // Pre-delta snapshots carry no epoch; 0 never matches a delta.
        let base_epoch = snap.get("delta_epoch").and_then(|v| v.as_u64()).unwrap_or(0);

        // ---- base ----
        self.apply_scalars(&snap)?;
        self.best_curve = Self::parse_curve(
            snap.get("best_curve")
                .and_then(|c| c.as_arr())
                .ok_or("snapshot: missing best_curve")?,
        )?;
        self.checkpoints = CheckpointStore::restore_from(
            snap.get("checkpoints").ok_or("snapshot: missing checkpoints")?,
            &dir.checkpoints_dir(),
        )?;
        self.scheduler.restore(snap.get("scheduler").unwrap_or(&Json::Null))?;
        self.search.restore(snap.get("search").unwrap_or(&Json::Null))?;
        self.trials.clear();
        for tj in snap
            .get("trials")
            .and_then(|t| t.as_arr())
            .ok_or("snapshot: missing trials")?
        {
            let t = Trial::from_json(tj, &mut self.schema).ok_or("snapshot: malformed trial")?;
            self.trials.insert(t.id, t);
        }

        // ---- fold deltas (epoch-matched, in append order) ----
        let mut folded = 0u64;
        for d in dir.read_deltas() {
            if d.get("epoch").and_then(|v| v.as_u64()) != Some(base_epoch) {
                continue; // stale record from before the current base
            }
            self.apply_scalars(&d)?;
            if let Some(points) = d.get("best_curve_append").and_then(|c| c.as_arr()) {
                self.best_curve.extend(Self::parse_curve(points)?);
            }
            if let Some(cd) = d.get("checkpoints") {
                self.checkpoints.apply_delta(cd, &dir.checkpoints_dir())?;
            }
            self.scheduler.apply_delta(d.get("scheduler").unwrap_or(&Json::Null))?;
            self.search.apply_delta(d.get("search").unwrap_or(&Json::Null))?;
            for tj in d.get("trials").and_then(|t| t.as_arr()).unwrap_or(&[]) {
                let t =
                    Trial::from_json(tj, &mut self.schema).ok_or("delta: malformed trial")?;
                self.trials.insert(t.id, t);
            }
            folded += 1;
        }
        self.restored_epoch = base_epoch;
        self.restored_deltas = folded;
        self.curve_flushed = self.best_curve.len();
        // Only now — after every delta folded — is "no live manifest
        // references this chunk" a safe verdict: sweep chunk files the
        // crashed run wrote past the last durable journal record.
        self.checkpoints.sweep_orphan_chunks();

        // ---- roll running trials back to durable state ----
        let ids: Vec<TrialId> = self.trials.map().keys().copied().collect();
        for id in ids {
            let mut t = self.trials.remove(&id).expect("id enumerated from the table");
            // Progress recorded by the trial's checkpoint, if its blob
            // survived.
            let ck = t
                .checkpoint
                .and_then(|c| self.checkpoints.meta(c).map(|m| (m.iteration, m.time_total_s)));
            match t.status {
                TrialStatus::Running => {
                    // Relaunch from the latest durable checkpoint; the
                    // iterations between it and the snapshot replay with
                    // suppression.
                    let until =
                        self.replay_until.get(&t.id).copied().unwrap_or(0).max(t.iteration);
                    t.status = TrialStatus::Pending;
                    match ck {
                        Some((iter, time)) => {
                            t.iteration = iter;
                            t.time_total_s = time;
                        }
                        None => {
                            t.checkpoint = None;
                            t.iteration = 0;
                            t.time_total_s = 0.0;
                        }
                    }
                    if until > t.iteration {
                        self.replay_until.insert(t.id, until);
                    }
                }
                // A Paused trial whose spill file was lost, or a Pending
                // trial (e.g. awaiting fault-recovery relaunch) whose
                // recorded checkpoint no longer loads: degrade to
                // replay-from-scratch instead of relaunching a fresh
                // trainable against stale table progress.
                TrialStatus::Paused if ck.is_none() => self.degrade_to_scratch(&mut t),
                TrialStatus::Pending if t.checkpoint.is_some() && ck.is_none() => {
                    self.degrade_to_scratch(&mut t)
                }
                _ => {}
            }
            self.trials.insert(id, t);
        }
        // The rollback diverges the table from disk until relaunches
        // re-mark these trials; start the resumed run with a clean
        // cursor anyway — the rollback is a deterministic function of
        // disk state, so a repeated crash-resume reapplies it.
        self.dirty.clear();

        // Align the on-disk logs with the restored state: drop rows past
        // the rollback point (the replay re-logs them identically) and
        // any half-written final line from the crash.
        for t in self.trials.scan() {
            if !t.status.is_terminal() {
                if let Err(e) = dir.prune_trial_log(t.id, t.iteration) {
                    eprintln!("pruning log of trial {}: {e}", t.id);
                }
            }
        }
        // Logs of trials the snapshot does not know about (created in
        // the window between the snapshot and the crash) are orphans:
        // the resumed run re-creates those ids from scratch and must not
        // append to — and thereby duplicate — their old rows.
        for id in dir.trial_log_ids() {
            if !self.trials.contains_key(&id) {
                std::fs::remove_file(dir.trial_log_path(id)).ok();
            }
        }
        // Derived indices are never persisted: rebuild every one from
        // the restored table. The placer's fail memo and the
        // feasibility memo are keyed on the *previous* cluster
        // instance's epochs, which the restored cluster does not share
        // — drop both.
        self.rebuild_indexes();
        self.placer.invalidate();
        self.feasible_cache = None;
        // The restored cluster (autoscaled shape, drain/retire flags)
        // replaces the constructor's; refresh the cached utilization.
        self.refresh_util();
        Ok(())
    }

    /// Recompute the per-status counters, Pending queue and incremental
    /// stat totals from the trial table — O(trials), restore path only.
    /// The rollback above requeued every formerly-Running trial, so no
    /// leases exist and the per-node index rebuilds to empty.
    fn rebuild_indexes(&mut self) {
        let mut counts = [0usize; 6];
        let mut pending = BTreeSet::new();
        let mut iters = 0u64;
        let mut budget = 0.0;
        for t in self.trials.scan() {
            counts[sidx(t.status)] += 1;
            if t.status == TrialStatus::Pending {
                pending.insert(t.id);
            }
            iters += t.iteration;
            budget += t.time_total_s;
        }
        self.status_counts = counts;
        self.pending = pending;
        self.node_trials.clear();
        self.stats.total_iterations = iters;
        self.stats.budget_used_s = budget;
    }

    /// Could `demand` ever run? Checks the demand itself (finite,
    /// non-negative), every non-retired node's total capacity, and —
    /// when autoscaling is on — the scale-up template, which only
    /// counts while there is headroom to actually add such a node
    /// (a template fit with the cluster already at `max_nodes` would
    /// otherwise pass preflight and then silently strand every trial).
    fn demand_feasible(&mut self, demand: &Resources) -> Result<(), String> {
        demand.validate_demand()?;
        // Positive memo: feasibility depends only on *total* node shapes
        // (dead nodes may restart), which only add/retire — the shape
        // epoch — can change. Negative results are not memoized: they
        // either fail the experiment outright or depend on the
        // autoscaler's live headroom.
        if let Some((d, epoch)) = &self.feasible_cache {
            if *epoch == self.cluster.shape_epoch() && d == demand {
                return Ok(());
            }
        }
        if self.cluster.any_node_fits(demand) {
            self.feasible_cache = Some((demand.clone(), self.cluster.shape_epoch()));
            return Ok(());
        }
        if let Some(a) = &self.autoscaler {
            if a.can_grow(&self.cluster, demand) {
                return Ok(());
            }
            return Err(format!(
                "no node fits it and none of the {} autoscale template(s) can help \
                 (templates too small, or already at max_nodes={})",
                a.templates().len(),
                a.policy.max_nodes
            ));
        }
        Err("no node in the cluster is large enough".into())
    }

    /// Experiment-level fail-fast: refuse to create or launch *any*
    /// trial when `resources_per_trial` is unsatisfiable — a clear
    /// error beats 64 trials parked Pending forever. Returns false
    /// (and records the error for the result summary) on infeasibility.
    fn preflight(&mut self) -> bool {
        if self.preflight_ok {
            return true;
        }
        if self.infeasible.is_some() {
            return false;
        }
        // Cost-budget fail-fast: a malformed cap, or one the (possibly
        // resumed) run has already spent, must launch zero trials — a
        // clear error beats burning money on work the budget disowns.
        if let Some(max) = self.spec.budget_max_cost {
            if !max.is_finite() || max < 0.0 {
                let msg = format!("budget.max_cost {max} must be a finite non-negative dollar amount");
                eprintln!("experiment {:?}: {msg}", self.spec.name);
                self.infeasible = Some(msg);
                return false;
            }
            if self.cost_exhausted() {
                let msg = format!(
                    "cost budget exhausted: accrued ${:.4} >= max_cost ${max}",
                    self.stats.cost_accrued
                );
                eprintln!("experiment {:?}: {msg}", self.spec.name);
                self.infeasible = Some(msg);
                return false;
            }
        }
        let demand = self.spec.resources_per_trial.clone();
        match self.demand_feasible(&demand) {
            Ok(()) => {
                self.preflight_ok = true;
                true
            }
            Err(e) => {
                let msg = format!("resources_per_trial {demand} is unsatisfiable: {e}");
                eprintln!("experiment {:?}: {msg}", self.spec.name);
                self.infeasible = Some(msg);
                false
            }
        }
    }

    /// Advance the elastic autoscaler one tick (driven per coordinator
    /// event, like `fault_tick`, so decisions are deterministic) and
    /// apply its action: grow the cluster, or start draining a node —
    /// the drained node's trials are preempted checkpoint-then-requeue
    /// as they report (see `handle_stepped`), and the node retires once
    /// empty.
    fn autoscale_tick(&mut self) {
        if self.autoscaler.is_none() {
            return;
        }
        // Settle the bill before any action changes the price rate,
        // and so the headroom handed to the autoscaler is current.
        self.accrue_cost();
        let unplaceable = std::mem::take(&mut self.unplaceable);
        // Hardware/cost context for the tick: fleet throughput scores
        // per template (hw-aware only — cost-blind ticks rank by the
        // prior, i.e. by price alone) and the remaining dollar budget.
        let template_scores = match (&self.autoscaler, self.spec.hw_aware) {
            (Some(a), true) => Some(
                a.templates()
                    .iter()
                    .map(|t| self.profiler.fleet_score(&crate::ray::shape_key(&t.shape)))
                    .collect(),
            ),
            _ => None,
        };
        let hw = HwInputs {
            template_scores,
            cost_headroom: self.spec.budget_max_cost.map(|m| m - self.stats.cost_accrued),
        };
        let action = {
            let a = self.autoscaler.as_mut().expect("checked above");
            a.tick_hw(&self.cluster, unplaceable, &self.spec.resources_per_trial, &hw)
        };
        match action {
            AutoscaleAction::None => {}
            AutoscaleAction::AddNode(t) => {
                let id = self.cluster.add_node_priced(t.shape, t.price_per_hour);
                // add_node may have reused a retired slot: the fresh
                // node must not inherit its predecessor's idle streak.
                if let Some(a) = &mut self.autoscaler {
                    a.reset_streak(id);
                }
                self.stats.scale_ups += 1;
                self.refresh_util();
            }
            AutoscaleAction::Drain(node) => {
                self.cluster.begin_drain(node);
                self.maybe_finish_drain(node); // already idle: retire now
                self.refresh_util();
            }
        }
    }

    /// Checkpoint-then-deschedule: snapshot the trial's state (it is
    /// idle between steps — callers sit at a result boundary), halt the
    /// trainable, release the lease and park it in `status`. Shared by
    /// the scheduler's Pause decision (→ Paused) and autoscale
    /// preemption (→ Pending).
    fn shed(&mut self, id: TrialId, status: TrialStatus) {
        self.save_checkpoint(id);
        self.executor.halt(id);
        self.release(id);
        self.set_status(id, status);
        self.dirty.insert(id);
    }

    /// Checkpoint-then-requeue a trial off a draining node; the next
    /// admission pass relaunches it elsewhere from that checkpoint — a
    /// shrink never loses a trial.
    fn preempt(&mut self, id: TrialId) {
        self.shed(id, TrialStatus::Pending);
        self.stats.preemptions += 1;
    }

    /// With a launchable candidate but nothing running and no event in
    /// flight, can anything still change the cluster so placement
    /// succeeds? (A fault plan that restarts killed nodes, or an
    /// autoscaler with headroom for this demand.) When not, the
    /// experiment can never advance: finalize instead of spinning.
    fn can_wait_for_capacity(&self) -> bool {
        (self.fault.plan.node_failure_prob > 0.0 && self.fault.plan.nodes_restart)
            || self
                .autoscaler
                .as_ref()
                .map_or(false, |a| a.can_grow(&self.cluster, &self.spec.resources_per_trial))
    }

    fn fault_tick(&mut self) {
        if self.fault.plan.node_failure_prob == 0.0 {
            return;
        }
        // Kills and restarts change the price rate: settle first.
        self.accrue_cost();
        let (kill, restarts) = self.fault.tick(self.cluster.alive_ids());
        for n in restarts {
            self.cluster.restart_node(n);
        }
        if let Some(victim) = kill {
            self.cluster.kill_node(victim);
            self.apply_node_kill(victim);
        }
        self.refresh_util();
    }

    /// Fail every trial the killed node was hosting. The victims come
    /// from the per-node lease index — O(victim's trials), never a walk
    /// of the lease map or the table — and each goes through the normal
    /// failure path (checkpoint rollback, retry budget).
    fn apply_node_kill(&mut self, victim: NodeId) {
        let dead: Vec<TrialId> = self
            .node_trials
            .remove(&victim)
            .map(|s| s.into_iter().collect())
            .unwrap_or_default();
        self.stats.kill_touched += dead.len() as u64;
        for id in dead {
            self.handle_failure(id, "node failure");
        }
    }

    /// Apply one completion event (the body shared by the blocking
    /// [`Self::drive`] loop and the hub's cooperative stepping).
    fn dispatch(&mut self, event: ExecEvent) {
        match event {
            ExecEvent::Stepped { trial, out } => self.handle_stepped(trial, out),
            ExecEvent::Failed { trial, error } => self.handle_failure(trial, &error),
        }
    }

    /// Nothing is in flight: try to make progress anyway. True when the
    /// scheduler already has a candidate (pending or resumable paused
    /// trial) or a fresh trial was pulled from the search algorithm;
    /// false when the experiment can never advance again.
    fn try_unblock(&mut self) -> bool {
        let can_progress = {
            let ctx = SchedulerCtx {
                trials: self.trials.map(),
                pending: &self.pending,
                metric_id: self.metric_id,
                mode: self.spec.mode,
                utilization: self.util,
            };
            self.scheduler.choose_trial_to_run(&ctx).is_some()
        };
        if can_progress {
            return true;
        }
        if self.search_exhausted {
            return false;
        }
        self.create_trial().is_some()
    }

    /// One event-loop iteration: admit, apply one completion event (or
    /// unblock an idle experiment), then the per-event fault/autoscale/
    /// snapshot ticks. Returns `None` when the experiment can make no
    /// further progress, `Some(snapped)` otherwise — extracted from
    /// [`Self::drive`] so scale and property tests can interleave
    /// invariant checks between events.
    fn step_once(&mut self) -> Option<bool> {
        self.admit();
        self.accrue_cost();
        if self.clock() >= self.spec.max_experiment_time_s || self.cost_exhausted() {
            return None;
        }
        let event = self.executor.next_event();
        // lint:allow(clock): perf counter (handling_ns); never feeds trial state
        let t0 = std::time::Instant::now();
        match event {
            Some(ev) => self.dispatch(ev),
            None => {
                // Nothing in flight. If nothing can ever run again,
                // we are done; otherwise admit more.
                if !self.try_unblock() {
                    return None;
                }
                // Try to place the candidate now; if nothing is
                // running afterwards, placement failed with every
                // lease free. Spin only while a node restart or an
                // autoscale-up can still unblock it (the
                // per-iteration ticks below drive both); otherwise
                // the backlog is permanent — finalize instead of
                // livelocking.
                self.admit();
                if self.num_running() == 0 && !self.can_wait_for_capacity() {
                    return None;
                }
            }
        }
        self.stats.handling_ns += t0.elapsed().as_nanos() as u64;
        self.fault_tick();
        self.autoscale_tick();
        Some(self.maybe_snapshot())
    }

    /// The event loop shared by [`TrialRunner::run`] and
    /// [`TrialRunner::run_to_crash`]. Returns `true` when crash
    /// injection fired (the loop was abandoned mid-flight).
    fn drive(&mut self, crash_after_snapshots: Option<u64>) -> bool {
        if !self.preflight() {
            return false; // unsatisfiable demand: zero trials launched
        }
        while let Some(snapped) = self.step_once() {
            if snapped && crash_after_snapshots.map_or(false, |n| self.stats.snapshots >= n) {
                return true;
            }
        }
        false
    }

    // -----------------------------------------------------------------
    // Cooperative stepping (the hub drives the loop, not the runner)
    // -----------------------------------------------------------------

    /// Hub-side admission pass: launch whatever the current fair-share
    /// slot cap allows. Returns `false` when the experiment can make no
    /// further progress (time budget spent, or no running trials and
    /// nothing left to launch) — the hub should finalize it then.
    ///
    /// Invariant relied on: every `Running` trial has exactly one step
    /// request in flight, so "`true`" with running trials implies a
    /// completion event for this experiment will eventually reach the
    /// hub. The one exception is an experiment stalled waiting out a
    /// node restart (fault plan with restarts): it returns `true` with
    /// nothing in flight, and the hub's idle pass re-pumps it until the
    /// node comes back.
    pub(crate) fn hub_pump(&mut self) -> bool {
        if !self.preflight() {
            return false; // unsatisfiable demand: finalize immediately
        }
        loop {
            self.accrue_cost();
            if self.clock() >= self.spec.max_experiment_time_s || self.cost_exhausted() {
                return false;
            }
            self.admit();
            if self.num_running() > 0 {
                return true;
            }
            let created_before = self.next_id;
            if !self.try_unblock() {
                return false;
            }
            if self.next_id == created_before {
                // A candidate exists but could not be placed with every
                // lease free. A shared-pool fleet refusal is transient
                // — sibling experiments hold the capacity and free it
                // as their trials halt — so stay alive and let the
                // hub's next pass retry. Under a node-failure plan with
                // restarts the cluster may just be waiting out a dead
                // node: tick the fault clock (the blocking loop does
                // this by spinning) and stay alive. Likewise an
                // autoscaler with headroom: tick it so pressure
                // accumulates into a scale-up. Otherwise the demand
                // permanently exceeds the cluster: report no progress
                // so the hub finalizes instead of livelocking.
                if std::mem::take(&mut self.exec_exhausted) {
                    return true;
                }
                if self.fault.plan.node_failure_prob > 0.0 && self.fault.plan.nodes_restart {
                    self.fault_tick();
                    return true;
                }
                if self
                    .autoscaler
                    .as_ref()
                    .map_or(false, |a| a.can_grow(&self.cluster, &self.spec.resources_per_trial))
                {
                    self.autoscale_tick();
                    return true;
                }
                return false;
            }
        }
    }

    /// Hub-side event application: everything one [`Self::drive`]
    /// iteration does after `next_event` returns (decision handling,
    /// fault ticks, snapshot cadence). The hub follows up with
    /// [`Self::hub_pump`] to re-admit and detect completion.
    pub(crate) fn hub_handle_event(&mut self, event: ExecEvent) {
        // lint:allow(clock): perf counter (handling_ns); never feeds trial state
        let t0 = std::time::Instant::now();
        self.dispatch(event);
        self.stats.handling_ns += t0.elapsed().as_nanos() as u64;
        self.fault_tick();
        self.autoscale_tick();
        self.maybe_snapshot();
    }

    /// Deterministic crash injection for durability tests: drive the
    /// event loop until `snapshots` periodic snapshots have been written
    /// to the experiment directory, then abandon the run mid-flight —
    /// no endgame, no logger finalization — exactly as a process kill at
    /// a snapshot boundary would. Returns `true` if the crash fired
    /// (`false` means the experiment finished first). Requires
    /// [`TrialRunner::enable_persistence`] with a non-zero cadence.
    pub fn run_to_crash(&mut self, snapshots: u64) -> bool {
        self.drive(Some(snapshots))
    }

    /// Endgame shared by [`TrialRunner::run`], the hub and the stepping
    /// test harnesses: terminate whatever is still live (budget
    /// exhausted or orphaned paused trials), flush loggers, write the
    /// final snapshot and assemble the result summary. The runner's
    /// trial table is consumed.
    pub fn finalize(&mut self) -> ExperimentResult {
        // Bill the tail interval so the reported spend covers the whole
        // experiment span.
        self.accrue_cost();
        let leftovers: Vec<TrialId> = self
            .trials
            .scan()
            .filter(|t| !t.status.is_terminal())
            .map(|t| t.id)
            .collect();
        for id in leftovers {
            self.finish(id, TrialStatus::Stopped);
        }
        for l in &mut self.loggers {
            l.on_experiment_end(self.trials.map());
        }
        // Final snapshot: marks the experiment finished so a later
        // `--resume` reports completion instead of re-running anything.
        if self.persist.is_some() {
            self.write_snapshot(true);
        }

        // NaN-proof best pick: `best_metric` is never NaN (see
        // `Trial::record`), but the order stays total regardless.
        let best = self
            .trials
            .scan()
            .filter(|t| t.best_metric.is_some())
            .max_by(|a, b| {
                let am = self.spec.mode.ascending(a.best_metric.unwrap());
                let bm = self.spec.mode.ascending(b.best_metric.unwrap());
                crate::util::order::asc(am, bm)
            })
            .map(|t| t.id);
        ExperimentResult {
            best,
            duration_s: self.clock(),
            // The incrementally maintained mirror of the per-trial sum
            // (see `RunnerStats::budget_used_s`): finalize reads it
            // instead of rescanning the table.
            budget_used_s: self.stats.budget_used_s,
            trials: std::mem::take(&mut self.trials).into_map(),
            stats: self.stats.clone(),
            placement: self.placer.stats,
            best_curve: std::mem::take(&mut self.best_curve),
            schema: self.schema.clone(),
            infeasible: self.infeasible.take(),
            final_utilization: self.util,
            ckpt: self.checkpoints.stats(),
        }
    }

    /// Drive the experiment to completion; returns the result summary.
    pub fn run(&mut self) -> ExperimentResult {
        self.drive(None);
        self.finalize()
    }

    // -----------------------------------------------------------------
    // Test hooks (index-equivalence and scale harnesses)
    // -----------------------------------------------------------------

    /// Drive one event-loop iteration from a test: `true` while the
    /// experiment can still make progress. Callers pair it with
    /// [`TrialRunner::finalize`] once it returns `false`.
    #[doc(hidden)]
    pub fn debug_step(&mut self) -> bool {
        if !self.preflight() {
            return false;
        }
        self.step_once().is_some()
    }

    /// Kill `node` right now (targeted fault injection for tests),
    /// routing through the same per-node index as `fault_tick`.
    #[doc(hidden)]
    pub fn debug_kill_node(&mut self, node: NodeId) {
        self.accrue_cost();
        self.cluster.kill_node(node);
        self.apply_node_kill(node);
        self.refresh_util();
    }

    /// Node currently hosting the most trials (with its count), per the
    /// incremental per-node index.
    #[doc(hidden)]
    pub fn debug_busiest_node(&self) -> Option<(NodeId, usize)> {
        self.node_trials.iter().map(|(n, s)| (*n, s.len())).max_by_key(|&(_, k)| k)
    }

    /// Cumulative keyed-access count on the trial table (see
    /// `TrialTable`): scale tests assert it grows with events, not with
    /// events x trials.
    #[doc(hidden)]
    pub fn debug_table_touches(&self) -> u64 {
        self.trials.touches()
    }

    /// Live runner counters (tests read them mid-run; `run`/`finalize`
    /// also return them in the result).
    #[doc(hidden)]
    pub fn debug_stats(&self) -> &RunnerStats {
        &self.stats
    }

    /// The learned throughput profiles (property tests assert the
    /// planted fast/slow ordering is recovered and survives resume).
    #[doc(hidden)]
    pub fn debug_profiler(&self) -> &ThroughputProfiler {
        &self.profiler
    }

    /// Direct access to the checkpoint store (crash/fault-injection
    /// tests read blobs out and verify store invariants mid-run).
    #[doc(hidden)]
    pub fn debug_ckpt_store(&mut self) -> &mut CheckpointStore {
        &mut self.checkpoints
    }

    /// Cap the checkpoint store's memory-resident bytes; cold chunks
    /// spill to the experiment directory's `chunks/` tier. No-op
    /// eviction until persistence is enabled (the disk tier is the only
    /// safe destination for the sole copy of a chunk).
    pub fn set_checkpoint_mem_budget(&mut self, budget: Option<usize>) {
        self.checkpoints.set_mem_budget(budget);
    }

    /// Compare every incrementally maintained index against a freshly
    /// computed full-scan reference — the property tests' oracle.
    /// O(trials + nodes); test-only by construction.
    #[doc(hidden)]
    pub fn debug_check_indices(&self) -> Result<(), String> {
        let mut counts = [0usize; 6];
        let mut pending = BTreeSet::new();
        let mut iters = 0u64;
        let mut budget = 0.0;
        let mut demand = Resources::default();
        for t in self.trials.scan() {
            counts[sidx(t.status)] += 1;
            if t.status == TrialStatus::Pending {
                pending.insert(t.id);
            }
            if t.status == TrialStatus::Running {
                demand.release(&t.resources); // add to the sum
            }
            iters += t.iteration;
            budget += t.time_total_s;
        }
        if counts != self.status_counts {
            return Err(format!(
                "status counts diverged: index {:?} != reference {counts:?}",
                self.status_counts
            ));
        }
        if pending != self.pending {
            return Err(format!(
                "pending queue diverged: index {:?} != reference {pending:?}",
                self.pending
            ));
        }
        let mut node_trials: BTreeMap<NodeId, BTreeSet<TrialId>> = BTreeMap::new();
        for (id, (node, _)) in &self.leases {
            node_trials.entry(*node).or_default().insert(*id);
        }
        if node_trials != self.node_trials {
            return Err(format!(
                "per-node lease index diverged: index {:?} != reference {node_trials:?}",
                self.node_trials
            ));
        }
        if iters != self.stats.total_iterations {
            return Err(format!(
                "total_iterations diverged: index {} != reference {iters}",
                self.stats.total_iterations
            ));
        }
        if (budget - self.stats.budget_used_s).abs() > 1e-6 * budget.abs().max(1.0) {
            return Err(format!(
                "budget_used_s diverged: index {} != reference {budget}",
                self.stats.budget_used_s
            ));
        }
        if demand != self.running_demand {
            return Err(format!(
                "running demand diverged: index {:?} != reference {demand:?}",
                self.running_demand
            ));
        }
        self.cluster.debug_check()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::SimExecutor;
    use crate::coordinator::schedulers::FifoScheduler;
    use crate::coordinator::search::RandomSearch;
    use crate::coordinator::spec::SpaceBuilder;
    use crate::coordinator::trial::Mode;
    use crate::ray::{FaultPlan, Resources};
    use crate::trainable::factory;
    use crate::trainable::synthetic::CurveTrainable;

    fn quick_spec(n: usize, iters: u64) -> ExperimentSpec {
        let mut spec = ExperimentSpec::named("test");
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.num_samples = n;
        spec.max_iterations_per_trial = iters;
        spec
    }

    fn runner(spec: ExperimentSpec, nodes: usize) -> TrialRunner {
        let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
        let search = Box::new(RandomSearch::new(space, spec.num_samples));
        let executor = Box::new(SimExecutor::new(factory(|c, s| {
            Box::new(CurveTrainable::new(c, s))
        })));
        let cluster = Cluster::uniform(nodes, Resources::cpu(4.0));
        TrialRunner::new(spec, Box::new(FifoScheduler::new()), search, executor, cluster)
    }

    #[test]
    fn fifo_runs_all_trials_to_completion() {
        let mut r = runner(quick_spec(10, 20), 2);
        let res = r.run();
        assert_eq!(res.trials.len(), 10);
        assert_eq!(res.count(TrialStatus::Completed), 10);
        assert_eq!(res.total_iterations(), 200);
        assert!(res.best.is_some());
        assert!(res.duration_s > 0.0);
    }

    #[test]
    fn resource_limits_bound_parallelism() {
        // 1 node x 4 cpus, 1 cpu per trial -> <= 4 concurrent; virtual
        // duration must reflect queueing: 8 trials x 20 steps x ~[0.5,2]s
        // over 4 slots.
        let mut r = runner(quick_spec(8, 20), 1);
        let res = r.run();
        assert_eq!(res.count(TrialStatus::Completed), 8);
        // With 4-way parallelism, duration >= total/4.
        assert!(res.duration_s >= res.budget_used_s / 4.0 - 1e-6);
        assert!(res.placement.failed > 0); // admission hit the limit
    }

    #[test]
    fn max_concurrent_is_respected() {
        let mut spec = quick_spec(6, 10);
        spec.max_concurrent = 1;
        let mut r = runner(spec, 4);
        let res = r.run();
        // Serial execution: duration == total budget.
        assert!((res.duration_s - res.budget_used_s).abs() < 1e-6);
    }

    #[test]
    fn metric_target_completes_early() {
        let mut spec = quick_spec(4, 10_000);
        spec.metric_target = Some(0.5); // accuracy >= 0.5 stops a trial
        let mut r = runner(spec, 2);
        let res = r.run();
        assert!(res.total_iterations() < 4 * 10_000);
    }

    #[test]
    fn experiment_time_budget_halts() {
        let mut spec = quick_spec(100, 1_000);
        spec.max_experiment_time_s = 50.0;
        let mut r = runner(spec, 1);
        let res = r.run();
        assert!(res.duration_s <= 55.0, "{}", res.duration_s);
        assert!(res.count(TrialStatus::Stopped) > 0);
    }

    #[test]
    fn step_failures_recover_from_checkpoints() {
        let mut spec = quick_spec(6, 30);
        spec.fault_plan = FaultPlan::flaky_steps(0.02);
        spec.checkpoint_freq = 5;
        spec.max_failures = 10;
        let mut r = runner(spec, 2);
        let res = r.run();
        assert!(res.stats.failures_recovered > 0);
        assert_eq!(res.count(TrialStatus::Completed), 6);
    }

    #[test]
    fn node_failures_reschedule_trials() {
        let mut spec = quick_spec(8, 40);
        spec.fault_plan = FaultPlan { node_failure_prob: 0.02, ..Default::default() };
        spec.checkpoint_freq = 5;
        spec.max_failures = 50;
        let mut r = runner(spec, 4);
        let res = r.run();
        let done = res.count(TrialStatus::Completed);
        assert_eq!(done, 8, "{:?}", res.stats);
    }

    #[test]
    fn best_curve_is_monotone() {
        let mut r = runner(quick_spec(20, 30), 2);
        let res = r.run();
        for w in res.best_curve.windows(2) {
            assert!(w[1].1 >= w[0].1); // Max mode: improving
            assert!(w[1].0 >= w[0].0);
        }
    }
}
