//! Trials: a single training run with a fixed initial hyperparameter
//! configuration (§3 of the paper), plus the result rows trainables
//! report and the lifecycle state machine the runner drives.

use std::collections::BTreeMap;
use std::fmt;

use crate::ray::{NodeId, Resources};
use crate::util::intern::{MetricId, MetricSchema};

/// Unique identifier of a trial within an experiment.
pub type TrialId = u64;

/// A hyperparameter value. Configs are ordered maps so they have a
/// canonical printable form (used in logs and by search algorithms).
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    /// Floating-point parameter.
    F64(f64),
    /// Integer parameter.
    I64(i64),
    /// Categorical string parameter.
    Str(String),
    /// Boolean flag parameter.
    Bool(bool),
}

impl ParamValue {
    /// Numeric view (`F64` directly, `I64` widened); `None` otherwise.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParamValue::F64(v) => Some(*v),
            ParamValue::I64(v) => Some(*v as f64),
            _ => None,
        }
    }
    /// String view of a categorical parameter; `None` otherwise.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParamValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::F64(v) => write!(f, "{v}"),
            ParamValue::I64(v) => write!(f, "{v}"),
            ParamValue::Str(s) => write!(f, "{s}"),
            ParamValue::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// A trial's full hyperparameter assignment: name -> value, ordered.
pub type Config = BTreeMap<String, ParamValue>;

/// Render a config compactly: `lr=0.01,momentum=0.9`.
pub fn config_str(config: &Config) -> String {
    config
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// One intermediate result reported by a trial (the unit the scheduler
/// API consumes).
///
/// Metrics are interned: the experiment's
/// [`MetricSchema`](crate::util::intern::MetricSchema) maps names to
/// dense [`MetricId`]s once, and each row is a small `Vec<(id, value)>`
/// — cloning a row is a single memcpy and looking the experiment metric
/// up is a few integer compares, the allocation-lean contract of the
/// result hot path.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResultRow {
    /// Training iteration (monotone per trial).
    pub iteration: u64,
    /// Total time this trial has consumed, in (possibly virtual) seconds.
    pub time_total_s: f64,
    /// Interned metric id -> value, in report order (the set is tiny —
    /// a linear scan beats any map at this size).
    pub metrics: Vec<(MetricId, f64)>,
}

impl ResultRow {
    /// An empty row at `iteration` after `time_total_s` seconds.
    pub fn new(iteration: u64, time_total_s: f64) -> Self {
        ResultRow { iteration, time_total_s, metrics: Vec::new() }
    }
    /// Builder-style metric insertion (replaces an existing id).
    pub fn with(mut self, id: MetricId, value: f64) -> Self {
        self.set(id, value);
        self
    }
    /// Insert or replace one metric value.
    pub fn set(&mut self, id: MetricId, value: f64) {
        match self.metrics.iter_mut().find(|(k, _)| *k == id) {
            Some((_, v)) => *v = value,
            None => self.metrics.push((id, value)),
        }
    }
    /// Look up one metric by interned id (integer compare, no hashing).
    pub fn get(&self, id: MetricId) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| *k == id).map(|(_, v)| *v)
    }
    /// Look up one metric by name through the experiment's schema —
    /// the convenience form for analysis/reporting paths.
    pub fn metric(&self, schema: &MetricSchema, name: &str) -> Option<f64> {
        self.get(schema.lookup(name)?)
    }
}

/// Whether larger or smaller metric values are better.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Smaller metric values are better (loss-like).
    Min,
    /// Larger metric values are better (accuracy-like).
    Max,
}

impl Mode {
    /// Is `a` better than `b` under this mode?
    pub fn better(&self, a: f64, b: f64) -> bool {
        match self {
            Mode::Min => a < b,
            Mode::Max => a > b,
        }
    }
    /// Normalize so that higher is always better.
    pub fn ascending(&self, v: f64) -> f64 {
        match self {
            Mode::Min => -v,
            Mode::Max => v,
        }
    }
    /// The worst possible value under this mode (identity of `better`).
    pub fn worst(&self) -> f64 {
        match self {
            Mode::Min => f64::INFINITY,
            Mode::Max => f64::NEG_INFINITY,
        }
    }
}

/// Lifecycle state of a trial, driven by the runner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialStatus {
    /// Waiting for resources (never started, or descheduled).
    Pending,
    /// Placed on a node with a live trainable, stepping.
    Running,
    /// Checkpointed and descheduled by the scheduler (e.g. HyperBand
    /// rung boundary); resumable via `choose_trial_to_run`.
    Paused,
    /// Finished normally (stopping criterion met).
    Completed,
    /// Stopped early by the scheduler.
    Stopped,
    /// Failed more than `max_failures` times.
    Errored,
}

impl TrialStatus {
    /// Completed, Stopped or Errored: the trial will never run again.
    pub fn is_terminal(&self) -> bool {
        matches!(self, TrialStatus::Completed | TrialStatus::Stopped | TrialStatus::Errored)
    }

    /// Stable label used in snapshots and JSONL logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            TrialStatus::Pending => "Pending",
            TrialStatus::Running => "Running",
            TrialStatus::Paused => "Paused",
            TrialStatus::Completed => "Completed",
            TrialStatus::Stopped => "Stopped",
            TrialStatus::Errored => "Errored",
        }
    }

    /// Parse a label written by [`TrialStatus::as_str`].
    pub fn from_label(s: &str) -> Option<Self> {
        Some(match s {
            "Pending" => TrialStatus::Pending,
            "Running" => TrialStatus::Running,
            "Paused" => TrialStatus::Paused,
            "Completed" => TrialStatus::Completed,
            "Stopped" => TrialStatus::Stopped,
            "Errored" => TrialStatus::Errored,
            _ => return None,
        })
    }
}

/// One training run with a (mutable under PBT) hyperparameter
/// configuration — the coordinator's unit of scheduling.
#[derive(Clone, Debug)]
pub struct Trial {
    /// Unique id within the experiment.
    pub id: TrialId,
    /// Current hyperparameter assignment.
    pub config: Config,
    /// Lifecycle state.
    pub status: TrialStatus,
    /// Resource demand leased while running.
    pub resources: Resources,
    /// Node the trial is (or was last) placed on.
    pub node: Option<NodeId>,
    /// Training iterations completed so far.
    pub iteration: u64,
    /// Training seconds consumed so far (virtual or wall).
    pub time_total_s: f64,
    /// Most recent intermediate result.
    pub last_result: Option<ResultRow>,
    /// Best metric value seen (under the experiment's mode).
    pub best_metric: Option<f64>,
    /// Latest checkpoint of this trial, if any.
    pub checkpoint: Option<crate::checkpoint::CheckpointId>,
    /// Failures so far (compared against `max_failures`).
    pub num_failures: u32,
    /// Seed for the trial's own stochasticity (data order, init).
    pub seed: u64,
    /// Set when the scheduler mutated the config (PBT lineage).
    pub mutations: u32,
}

impl Trial {
    /// A fresh Pending trial.
    pub fn new(id: TrialId, config: Config, resources: Resources, seed: u64) -> Self {
        Trial {
            id,
            config,
            status: TrialStatus::Pending,
            resources,
            node: None,
            iteration: 0,
            time_total_s: 0.0,
            last_result: None,
            best_metric: None,
            checkpoint: None,
            num_failures: 0,
            seed,
            mutations: 0,
        }
    }

    /// The trial's workload class for throughput profiling: the
    /// `"workload"` config parameter when present (categorical grids
    /// plant it), else `"default"` so homogeneous experiments share one
    /// profile per shape.
    pub fn workload_class(&self) -> &str {
        self.config.get("workload").and_then(|v| v.as_str()).unwrap_or("default")
    }

    /// Serialize for the experiment snapshot (see `coordinator::persist`).
    /// Metric ids are resolved back to names through `schema`: snapshots
    /// always store names, so ids stay process-ephemeral and old
    /// snapshots keep restoring.
    pub fn to_json(&self, schema: &MetricSchema) -> crate::util::json::Json {
        use crate::coordinator::persist::{config_to_json, u64_to_json};
        use crate::util::json::Json;
        let row_json = |r: &ResultRow| {
            Json::obj(vec![
                ("iteration", Json::Num(r.iteration as f64)),
                ("time_total_s", Json::Num(r.time_total_s)),
                (
                    "metrics",
                    Json::Obj(
                        r.metrics
                            .iter()
                            .filter_map(|(id, v)| {
                                schema.name(*id).map(|n| (n.to_string(), Json::Num(*v)))
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("config", config_to_json(&self.config)),
            ("status", Json::Str(self.status.as_str().into())),
            ("cpu", Json::Num(self.resources.cpu)),
            ("gpu", Json::Num(self.resources.gpu)),
            (
                "custom",
                Json::Obj(
                    self.resources
                        .custom
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            ("iteration", Json::Num(self.iteration as f64)),
            ("time_total_s", Json::Num(self.time_total_s)),
            ("last_result", self.last_result.as_ref().map(row_json).unwrap_or(Json::Null)),
            ("best_metric", self.best_metric.map(Json::Num).unwrap_or(Json::Null)),
            (
                "checkpoint",
                self.checkpoint.map(|c| Json::Num(c as f64)).unwrap_or(Json::Null),
            ),
            ("num_failures", Json::Num(self.num_failures as f64)),
            ("seed", u64_to_json(self.seed)),
            ("mutations", Json::Num(self.mutations as f64)),
        ])
    }

    /// Rebuild a trial from a snapshot written by [`Trial::to_json`],
    /// re-interning metric names into `schema`.
    pub fn from_json(
        j: &crate::util::json::Json,
        schema: &mut MetricSchema,
    ) -> Option<Trial> {
        use crate::coordinator::persist::{config_from_json, u64_from_json};
        let mut row = |r: &crate::util::json::Json| -> Option<ResultRow> {
            Some(ResultRow {
                iteration: r.get("iteration")?.as_u64()?,
                time_total_s: r.get("time_total_s")?.as_f64()?,
                // Non-numeric entries are skipped, not fatal: JSON has
                // no NaN, so a diverged metric serializes as `null` and
                // must not make the whole snapshot unreadable.
                metrics: r
                    .get("metrics")?
                    .as_obj()?
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|f| (schema.intern(k), f)))
                    .collect(),
            })
        };
        Some(Trial {
            id: j.get("id")?.as_u64()?,
            config: config_from_json(j.get("config")?)?,
            status: TrialStatus::from_label(j.get("status")?.as_str()?)?,
            resources: {
                let mut r =
                    Resources::cpu_gpu(j.get("cpu")?.as_f64()?, j.get("gpu")?.as_f64()?);
                if let Some(custom) = j.get("custom").and_then(|c| c.as_obj()) {
                    for (k, v) in custom {
                        r.custom.insert(k.clone(), v.as_f64()?);
                    }
                }
                r
            },
            node: None, // placement is rebuilt on relaunch
            iteration: j.get("iteration")?.as_u64()?,
            time_total_s: j.get("time_total_s")?.as_f64()?,
            last_result: j.get("last_result").and_then(row),
            best_metric: j.get("best_metric").and_then(|m| m.as_f64()),
            checkpoint: j.get("checkpoint").and_then(|c| c.as_u64()),
            num_failures: j.get("num_failures")?.as_u64()? as u32,
            seed: u64_from_json(j.get("seed")?)?,
            mutations: j.get("mutations")?.as_u64()? as u32,
        })
    }

    /// Record a result row, updating iteration, time and best metric.
    /// `NaN` metric values never become the best: without the guard a
    /// NaN *first* result would stick forever (`mode.better` is false
    /// for every comparison against NaN, in both directions).
    pub fn record(&mut self, row: ResultRow, metric: MetricId, mode: Mode) {
        self.iteration = row.iteration;
        self.time_total_s = row.time_total_s;
        self.update_best(row.get(metric), mode);
        self.last_result = Some(row);
    }

    /// Hot-path variant of [`Trial::record`]: build the row in place
    /// from a trainable's raw `StepOutput` metrics, reusing the previous
    /// `last_result` allocation — zero heap traffic per result once the
    /// row vector has reached its steady-state capacity.
    pub fn record_step(
        &mut self,
        iteration: u64,
        time_total_s: f64,
        metrics: &BTreeMap<String, f64>,
        schema: &mut MetricSchema,
        metric: MetricId,
        mode: Mode,
    ) {
        self.iteration = iteration;
        self.time_total_s = time_total_s;
        let mut row = self.last_result.take().unwrap_or_default();
        row.iteration = iteration;
        row.time_total_s = time_total_s;
        row.metrics.clear();
        for (name, v) in metrics {
            row.metrics.push((schema.intern(name), *v));
        }
        self.update_best(row.get(metric), mode);
        self.last_result = Some(row);
    }

    fn update_best(&mut self, value: Option<f64>, mode: Mode) {
        if let Some(v) = value {
            if !v.is_nan() && self.best_metric.map_or(true, |b| mode.better(v, b)) {
                self.best_metric = Some(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(lr: f64) -> Config {
        let mut c = Config::new();
        c.insert("lr".into(), ParamValue::F64(lr));
        c
    }

    #[test]
    fn mode_comparisons() {
        assert!(Mode::Min.better(1.0, 2.0));
        assert!(Mode::Max.better(2.0, 1.0));
        assert_eq!(Mode::Min.ascending(3.0), -3.0);
        assert!(Mode::Min.worst().is_infinite());
    }

    #[test]
    fn record_tracks_best_under_min() {
        let mut schema = MetricSchema::new();
        let loss = schema.intern("loss");
        let mut t = Trial::new(1, cfg(0.1), Resources::cpu(1.0), 0);
        t.record(ResultRow::new(1, 1.0).with(loss, 2.0), loss, Mode::Min);
        t.record(ResultRow::new(2, 2.0).with(loss, 3.0), loss, Mode::Min);
        assert_eq!(t.best_metric, Some(2.0));
        assert_eq!(t.iteration, 2);
        t.record(ResultRow::new(3, 3.0).with(loss, 1.0), loss, Mode::Min);
        assert_eq!(t.best_metric, Some(1.0));
    }

    #[test]
    fn record_step_reuses_the_row_allocation() {
        let mut schema = MetricSchema::new();
        let loss = schema.intern("loss");
        let mut t = Trial::new(1, cfg(0.1), Resources::cpu(1.0), 0);
        let mut metrics = BTreeMap::new();
        metrics.insert("loss".to_string(), 2.0);
        metrics.insert("accuracy".to_string(), 0.5);
        t.record_step(1, 1.0, &metrics, &mut schema, loss, Mode::Min);
        let cap = t.last_result.as_ref().unwrap().metrics.capacity();
        let ptr = t.last_result.as_ref().unwrap().metrics.as_ptr();
        metrics.insert("loss".to_string(), 1.0);
        t.record_step(2, 2.0, &metrics, &mut schema, loss, Mode::Min);
        let row = t.last_result.as_ref().unwrap();
        assert_eq!(row.metrics.capacity(), cap);
        assert_eq!(row.metrics.as_ptr(), ptr); // same buffer, no realloc
        assert_eq!(row.get(loss), Some(1.0));
        assert_eq!(t.best_metric, Some(1.0));
        assert_eq!(t.iteration, 2);
        // NaN never becomes best; iteration/time still advance.
        metrics.insert("loss".to_string(), f64::NAN);
        t.record_step(3, 3.0, &metrics, &mut schema, loss, Mode::Min);
        assert_eq!(t.best_metric, Some(1.0));
        assert_eq!(t.iteration, 3);
    }

    #[test]
    fn terminal_statuses() {
        assert!(TrialStatus::Completed.is_terminal());
        assert!(TrialStatus::Stopped.is_terminal());
        assert!(TrialStatus::Errored.is_terminal());
        assert!(!TrialStatus::Paused.is_terminal());
        assert!(!TrialStatus::Pending.is_terminal());
    }

    #[test]
    fn snapshot_json_roundtrip_preserves_everything() {
        let mut schema = MetricSchema::new();
        let loss = schema.intern("loss");
        let mut c = cfg(0.015625);
        c.insert("layers".into(), ParamValue::I64(3));
        c.insert("act".into(), ParamValue::Str("gelu".into()));
        let mut t = Trial::new(9, c, Resources::cpu(2.0).with_custom("tpu", 0.5), u64::MAX - 7);
        t.status = TrialStatus::Paused;
        t.record(ResultRow::new(4, 3.25).with(loss, 0.125), loss, Mode::Min);
        t.checkpoint = Some(17);
        t.num_failures = 2;
        t.mutations = 1;
        let text = t.to_json(&schema).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let back = Trial::from_json(&parsed, &mut schema).unwrap();
        assert_eq!(back.id, t.id);
        assert_eq!(back.config, t.config);
        assert_eq!(back.status, t.status);
        assert_eq!(back.resources, t.resources);
        assert_eq!(back.iteration, 4);
        assert_eq!(back.time_total_s, 3.25);
        assert_eq!(back.last_result.as_ref().unwrap().metrics, t.last_result.unwrap().metrics);
        assert_eq!(back.best_metric, Some(0.125));
        assert_eq!(back.checkpoint, Some(17));
        assert_eq!(back.num_failures, 2);
        assert_eq!(back.seed, u64::MAX - 7);
        assert_eq!(back.mutations, 1);
    }

    #[test]
    fn from_json_interns_into_a_fresh_schema() {
        // A resumed process starts with an empty schema: names written
        // by the previous process must re-intern (ids may differ; values
        // are found by name).
        let mut writer = MetricSchema::new();
        let acc = writer.intern("accuracy");
        let mut t = Trial::new(1, cfg(0.1), Resources::cpu(1.0), 3);
        t.record(ResultRow::new(2, 1.5).with(acc, 0.75), acc, Mode::Max);
        let text = t.to_json(&writer).to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut reader = MetricSchema::new();
        reader.intern("loss"); // occupy id 0 so ids genuinely differ
        let back = Trial::from_json(&parsed, &mut reader).unwrap();
        let row = back.last_result.unwrap();
        assert_eq!(row.metric(&reader, "accuracy"), Some(0.75));
    }

    #[test]
    fn config_str_is_canonical() {
        let mut c = cfg(0.5);
        c.insert("act".into(), ParamValue::Str("relu".into()));
        assert_eq!(config_str(&c), "act=relu,lr=0.5");
    }
}
