//! The parameter DSL of §4.3: `grid_search`, `uniform`, `loguniform`,
//! `quniform`, `randint`, `choice`, constants — and the machinery that
//! turns a search space into concrete trial configs (full grid cross
//! product for grid dimensions, seeded sampling for stochastic ones).
//! "Tune's parameter DSL offers features similar to those provided by
//! HyperOpt."

use std::collections::BTreeMap;

use crate::util::rng::Rng;

use super::trial::{Config, ParamValue};

/// One dimension of a search space: how a parameter's values arise.
#[derive(Clone, Debug)]
pub enum ParamDist {
    /// Every value is expanded into the initial trial grid.
    GridSearch(Vec<ParamValue>),
    /// Sampled uniformly from the listed values.
    Choice(Vec<ParamValue>),
    /// Uniform float in `[lo, hi)`.
    Uniform(f64, f64),
    /// Log-uniform float in `[lo, hi)`, `lo > 0`.
    LogUniform(f64, f64),
    /// Uniform quantized to multiples of `q`.
    QUniform(f64, f64, f64),
    /// Uniform integer in `[lo, hi)`.
    RandInt(i64, i64),
    /// A fixed value.
    Const(ParamValue),
}

impl ParamDist {
    /// Draw one value from this distribution.
    pub fn sample(&self, rng: &mut Rng) -> ParamValue {
        match self {
            ParamDist::GridSearch(vs) | ParamDist::Choice(vs) => rng.choose(vs).clone(),
            ParamDist::Uniform(lo, hi) => ParamValue::F64(rng.uniform(*lo, *hi)),
            ParamDist::LogUniform(lo, hi) => ParamValue::F64(rng.log_uniform(*lo, *hi)),
            ParamDist::QUniform(lo, hi, q) => {
                let v = rng.uniform(*lo, *hi);
                ParamValue::F64((v / q).round() * q)
            }
            ParamDist::RandInt(lo, hi) => ParamValue::I64(rng.range(*lo, *hi)),
            ParamDist::Const(v) => v.clone(),
        }
    }

    /// Is the value inside this distribution's support?
    pub fn contains(&self, v: &ParamValue) -> bool {
        match self {
            ParamDist::GridSearch(vs) | ParamDist::Choice(vs) => vs.contains(v),
            ParamDist::Uniform(lo, hi) | ParamDist::LogUniform(lo, hi) => {
                v.as_f64().map_or(false, |x| x >= *lo && x <= *hi)
            }
            ParamDist::QUniform(lo, hi, _) => {
                v.as_f64().map_or(false, |x| x >= *lo - 1e-12 && x <= *hi + 1e-12)
            }
            ParamDist::RandInt(lo, hi) => match v {
                ParamValue::I64(x) => x >= lo && x < hi,
                _ => false,
            },
            ParamDist::Const(c) => v == c,
        }
    }
}

/// An ordered search space: param name -> distribution.
pub type SearchSpace = BTreeMap<String, ParamDist>;

/// Builder-style helpers mirroring the python DSL.
pub struct SpaceBuilder {
    space: SearchSpace,
}

impl SpaceBuilder {
    /// An empty search space.
    pub fn new() -> Self {
        SpaceBuilder { space: SearchSpace::new() }
    }
    /// `grid_search` over float values.
    pub fn grid_f64(mut self, key: &str, values: &[f64]) -> Self {
        self.space.insert(
            key.into(),
            ParamDist::GridSearch(values.iter().map(|v| ParamValue::F64(*v)).collect()),
        );
        self
    }
    /// `grid_search` over string values.
    pub fn grid_str(mut self, key: &str, values: &[&str]) -> Self {
        self.space.insert(
            key.into(),
            ParamDist::GridSearch(values.iter().map(|v| ParamValue::Str(v.to_string())).collect()),
        );
        self
    }
    /// Uniform choice over string values.
    pub fn choice_str(mut self, key: &str, values: &[&str]) -> Self {
        self.space.insert(
            key.into(),
            ParamDist::Choice(values.iter().map(|v| ParamValue::Str(v.to_string())).collect()),
        );
        self
    }
    /// Uniform float in `[lo, hi)`.
    pub fn uniform(mut self, key: &str, lo: f64, hi: f64) -> Self {
        self.space.insert(key.into(), ParamDist::Uniform(lo, hi));
        self
    }
    /// Log-uniform float in `[lo, hi)`, `lo > 0`.
    pub fn loguniform(mut self, key: &str, lo: f64, hi: f64) -> Self {
        self.space.insert(key.into(), ParamDist::LogUniform(lo, hi));
        self
    }
    /// Uniform float quantized to multiples of `q`.
    pub fn quniform(mut self, key: &str, lo: f64, hi: f64, q: f64) -> Self {
        self.space.insert(key.into(), ParamDist::QUniform(lo, hi, q));
        self
    }
    /// Uniform integer in `[lo, hi)`.
    pub fn randint(mut self, key: &str, lo: i64, hi: i64) -> Self {
        self.space.insert(key.into(), ParamDist::RandInt(lo, hi));
        self
    }
    /// A fixed parameter.
    pub fn constant(mut self, key: &str, v: ParamValue) -> Self {
        self.space.insert(key.into(), ParamDist::Const(v));
        self
    }
    /// Finish building.
    pub fn build(self) -> SearchSpace {
        self.space
    }
}

impl Default for SpaceBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Number of configs in the grid cross-product (stochastic dims count 1).
pub fn grid_size(space: &SearchSpace) -> usize {
    space
        .values()
        .map(|d| match d {
            ParamDist::GridSearch(vs) => vs.len().max(1),
            _ => 1,
        })
        .product()
}

/// Expand the full grid over `GridSearch` dimensions; each grid point
/// samples the stochastic dimensions once from `rng`. This is exactly
/// the paper's "initial set of trials input to the scheduler".
pub fn expand_grid(space: &SearchSpace, rng: &mut Rng) -> Vec<Config> {
    let mut configs = vec![Config::new()];
    for (key, dist) in space {
        match dist {
            ParamDist::GridSearch(vs) => {
                let mut next = Vec::with_capacity(configs.len() * vs.len());
                for c in &configs {
                    for v in vs {
                        let mut c2 = c.clone();
                        c2.insert(key.clone(), v.clone());
                        next.push(c2);
                    }
                }
                configs = next;
            }
            _ => {
                for c in &mut configs {
                    c.insert(key.clone(), dist.sample(rng));
                }
            }
        }
    }
    configs
}

/// Sample one full config (all dimensions, grid dims sampled uniformly).
pub fn sample_config(space: &SearchSpace, rng: &mut Rng) -> Config {
    space.iter().map(|(k, d)| (k.clone(), d.sample(rng))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SearchSpace {
        SpaceBuilder::new()
            .grid_f64("lr", &[0.01, 0.001, 0.0001])
            .grid_str("activation", &["relu", "tanh"])
            .uniform("momentum", 0.8, 0.99)
            .build()
    }

    #[test]
    fn grid_size_is_cross_product() {
        assert_eq!(grid_size(&space()), 6);
    }

    #[test]
    fn expand_grid_covers_all_combinations() {
        let mut rng = Rng::new(0);
        let configs = expand_grid(&space(), &mut rng);
        assert_eq!(configs.len(), 6);
        let mut seen = std::collections::BTreeSet::new();
        for c in &configs {
            let lr = c["lr"].as_f64().unwrap();
            let act = c["activation"].as_str().unwrap().to_string();
            seen.insert((format!("{lr}"), act));
            let m = c["momentum"].as_f64().unwrap();
            assert!((0.8..0.99).contains(&m));
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn samples_respect_support() {
        let sp = SpaceBuilder::new()
            .loguniform("lr", 1e-4, 1e-1)
            .quniform("bs", 16.0, 256.0, 16.0)
            .randint("layers", 1, 5)
            .choice_str("opt", &["sgd", "adam"])
            .build();
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let c = sample_config(&sp, &mut rng);
            for (k, d) in &sp {
                assert!(d.contains(&c[k]), "{k}: {:?}", c[k]);
            }
            let bs = c["bs"].as_f64().unwrap();
            assert!((bs / 16.0 - (bs / 16.0).round()).abs() < 1e-9);
        }
    }

    #[test]
    fn const_dim_is_constant() {
        let sp = SpaceBuilder::new().constant("model", ParamValue::Str("tlm".into())).build();
        let mut rng = Rng::new(2);
        assert_eq!(sample_config(&sp, &mut rng)["model"], ParamValue::Str("tlm".into()));
    }

    #[test]
    fn quickstart_grid_matches_paper_example() {
        // §4.3: 3 x 2 grid over lr and activation.
        let sp = SpaceBuilder::new()
            .grid_f64("lr", &[0.01, 0.001, 0.0001])
            .grid_str("activation", &["relu", "tanh"])
            .build();
        assert_eq!(grid_size(&sp), 6);
    }
}
