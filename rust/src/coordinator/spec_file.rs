//! Experiment specification files: the declarative JSON form of §4.3's
//! `run_experiments` call, so whole experiments are launchable from the
//! CLI (`tune run --spec configs/example.json`) and reproducible as
//! checked-in artifacts.
//!
//! ```json
//! {
//!   "name": "asha-tlm", "metric": "loss", "mode": "min",
//!   "num_samples": 16, "max_iterations_per_trial": 60,
//!   "workload": "jax-tlm",
//!   "scheduler": {"type": "asha", "grace_period": 3,
//!                  "reduction_factor": 3, "max_t": 60},
//!   "search": "random",
//!   "space": {
//!     "lr":         {"loguniform": [0.003, 1.0]},
//!     "momentum":   {"uniform": [0.5, 0.99]},
//!     "activation": {"choice": ["gelu", "relu"]},
//!     "layers":     {"randint": [1, 4]},
//!     "batch":      {"grid": [16, 32]}
//!   },
//!   "cluster": {"nodes": 4, "cpus_per_node": 8.0},
//!   "resources_per_trial": {"cpu": 1.0, "gpu": 0.0}
//! }
//! ```
//!
//! Resource-aware forms: `resources_per_trial` accepts fractional
//! `cpu`/`gpu` plus arbitrary custom keys (`{"cpu": 1, "gpu": 0.5,
//! "tpu": 1}`); `cluster.nodes` may be a *list* of per-node shapes for
//! a heterogeneous cluster (`{"nodes": [{"cpus": 8, "gpus": 4},
//! {"cpus": 16}]}`); and an optional `autoscale` block enables elastic
//! scaling (`{"autoscale": {"max_nodes": 8, "node_cpus": 8,
//! "node_gpus": 4, "scale_up_after": 4, "scale_down_after": 200,
//! "scale_down_util": 0.1, "min_nodes": 1}}`).
//!
//! Hardware-aware forms: a per-node `"price_per_hour"` ($/hour billing
//! metadata, never a resource dimension), an autoscale `"templates"`
//! list of priced node shapes the scaler may add, a top-level
//! `"hw_aware": true` flag enabling learned-throughput placement, and
//! `"budget": {"max_cost": 25.0}` as a hard virtual-dollar cap.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use anyhow::{anyhow, bail, Context, Result};

use crate::ray::{AutoscalePolicy, Cluster, NodeTemplate, Resources};
use crate::util::json::{parse, Json};

use super::experiment::{ExperimentSpec, SchedulerKind, SearchKind};
use super::spec::{ParamDist, SearchSpace};
use super::trial::{Mode, ParamValue};

/// Everything a spec file defines.
pub struct SpecFile {
    /// The experiment parameters.
    pub spec: ExperimentSpec,
    /// Parsed search space.
    pub space: SearchSpace,
    /// Scheduler selection.
    pub scheduler: SchedulerKind,
    /// Search-algorithm selection.
    pub search: SearchKind,
    /// Workload name: "curve" | "pbt-sim" | "const" | "jax-mlp" | "jax-tlm".
    pub workload: String,
    /// Cluster shape to run on.
    pub cluster: Cluster,
    /// Elastic autoscaling policy, when the spec has an `autoscale`
    /// block (None = fixed cluster).
    pub autoscale: Option<AutoscalePolicy>,
    /// Fair-share weight when the spec runs under `tune serve` (min 1;
    /// ignored by the single-experiment `tune run`).
    pub weight: u64,
}

fn jf(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

fn param_value(j: &Json) -> Result<ParamValue> {
    Ok(match j {
        Json::Num(n) => ParamValue::F64(*n),
        Json::Str(s) => ParamValue::Str(s.clone()),
        Json::Bool(b) => ParamValue::Bool(*b),
        other => bail!("unsupported param literal {other:?}"),
    })
}

fn parse_dist(j: &Json) -> Result<ParamDist> {
    // Bare literal = constant.
    if !matches!(j, Json::Obj(_)) {
        return Ok(ParamDist::Const(param_value(j)?));
    }
    let obj = j.as_obj().unwrap();
    let (kind, arg) = obj.iter().next().ok_or_else(|| anyhow!("empty dist"))?;
    let pair = || -> Result<(f64, f64)> {
        let a = arg.as_arr().ok_or_else(|| anyhow!("{kind}: expected [lo, hi]"))?;
        anyhow::ensure!(a.len() >= 2, "{kind}: expected [lo, hi]");
        Ok((
            a[0].as_f64().ok_or_else(|| anyhow!("bad lo"))?,
            a[1].as_f64().ok_or_else(|| anyhow!("bad hi"))?,
        ))
    };
    Ok(match kind.as_str() {
        "uniform" => {
            let (lo, hi) = pair()?;
            ParamDist::Uniform(lo, hi)
        }
        "loguniform" => {
            let (lo, hi) = pair()?;
            ParamDist::LogUniform(lo, hi)
        }
        "quniform" => {
            let a = arg.as_arr().ok_or_else(|| anyhow!("quniform: [lo,hi,q]"))?;
            anyhow::ensure!(a.len() == 3, "quniform: [lo, hi, q]");
            ParamDist::QUniform(
                a[0].as_f64().unwrap_or(0.0),
                a[1].as_f64().unwrap_or(0.0),
                a[2].as_f64().unwrap_or(1.0),
            )
        }
        "randint" => {
            let (lo, hi) = pair()?;
            ParamDist::RandInt(lo as i64, hi as i64)
        }
        "choice" => ParamDist::Choice(
            arg.as_arr()
                .ok_or_else(|| anyhow!("choice: expected array"))?
                .iter()
                .map(param_value)
                .collect::<Result<_>>()?,
        ),
        "grid" | "grid_search" => ParamDist::GridSearch(
            arg.as_arr()
                .ok_or_else(|| anyhow!("grid: expected array"))?
                .iter()
                .map(param_value)
                .collect::<Result<_>>()?,
        ),
        "const" => ParamDist::Const(param_value(arg)?),
        other => bail!("unknown distribution {other:?}"),
    })
}

fn parse_scheduler(j: Option<&Json>, max_t: u64, space: &SearchSpace) -> Result<SchedulerKind> {
    let Some(j) = j else { return Ok(SchedulerKind::Fifo) };
    let ty = match j {
        Json::Str(s) => s.clone(),
        _ => j
            .get("type")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("scheduler needs a type"))?
            .to_string(),
    };
    Ok(match ty.as_str() {
        "fifo" => SchedulerKind::Fifo,
        "asha" => SchedulerKind::Asha {
            grace_period: jf(j, "grace_period").unwrap_or(1.0) as u64,
            reduction_factor: jf(j, "reduction_factor").unwrap_or(3.0),
            max_t: jf(j, "max_t").unwrap_or(max_t as f64) as u64,
        },
        "hyperband" => SchedulerKind::HyperBand {
            max_t: jf(j, "max_t").unwrap_or(max_t as f64) as u64,
            eta: jf(j, "eta").unwrap_or(3.0),
        },
        "median" | "median_stopping" => SchedulerKind::MedianStopping {
            grace_period: jf(j, "grace_period").unwrap_or(5.0) as u64,
            min_samples: jf(j, "min_samples").unwrap_or(3.0) as usize,
        },
        "pbt" => SchedulerKind::Pbt {
            perturbation_interval: jf(j, "perturbation_interval").unwrap_or(10.0) as u64,
            space: space.clone(),
        },
        other => bail!("unknown scheduler {other:?}"),
    })
}

impl SpecFile {
    /// Load and parse a spec file from disk.
    pub fn load(path: &std::path::Path) -> Result<SpecFile> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse_str(&text)
    }

    /// Parse a spec from JSON text (defaults applied per field).
    pub fn parse_str(text: &str) -> Result<SpecFile> {
        let j = parse(text).map_err(|e| anyhow!("parsing spec: {e}"))?;

        let mut space = SearchSpace::new();
        if let Some(sp) = j.get("space").and_then(|v| v.as_obj()) {
            for (k, dj) in sp {
                space.insert(
                    k.clone(),
                    parse_dist(dj).with_context(|| format!("space.{k}"))?,
                );
            }
        }

        let mut spec = ExperimentSpec::named(
            j.get("name").and_then(|v| v.as_str()).unwrap_or("experiment"),
        );
        if let Some(m) = j.get("metric").and_then(|v| v.as_str()) {
            spec.metric = m.to_string();
        }
        spec.mode = match j.get("mode").and_then(|v| v.as_str()) {
            Some("max") => Mode::Max,
            Some("min") | None => Mode::Min,
            Some(other) => bail!("mode must be min|max, got {other:?}"),
        };
        if let Some(n) = jf(&j, "num_samples") {
            spec.num_samples = n as usize;
        }
        if let Some(n) = jf(&j, "max_iterations_per_trial") {
            spec.max_iterations_per_trial = n as u64;
        }
        if let Some(n) = jf(&j, "metric_target") {
            spec.metric_target = Some(n);
        }
        if let Some(n) = jf(&j, "max_experiment_time_s") {
            spec.max_experiment_time_s = n;
        }
        if let Some(n) = jf(&j, "max_concurrent") {
            spec.max_concurrent = n as usize;
        }
        if let Some(n) = jf(&j, "max_failures") {
            spec.max_failures = n as u32;
        }
        if let Some(n) = jf(&j, "checkpoint_freq") {
            spec.checkpoint_freq = n as u64;
        }
        if let Some(n) = jf(&j, "seed") {
            spec.seed = n as u64;
        }
        if let Some(r) = j.get("resources_per_trial") {
            spec.resources_per_trial = parse_resources(r)?;
        }
        if let Some(b) = j.get("hw_aware").and_then(|v| v.as_bool()) {
            spec.hw_aware = b;
        }
        if let Some(bj) = j.get("budget") {
            anyhow::ensure!(bj.as_obj().is_some(), "budget: expected an object");
            if let Some(m) = jf(bj, "max_cost") {
                anyhow::ensure!(
                    m.is_finite() && m >= 0.0,
                    "budget.max_cost: must be a finite non-negative dollar amount"
                );
                spec.budget_max_cost = Some(m);
            }
        }

        let scheduler =
            parse_scheduler(j.get("scheduler"), spec.max_iterations_per_trial, &space)?;
        let search = match j.get("search").and_then(|v| v.as_str()).unwrap_or("random") {
            "grid" => SearchKind::Grid,
            "random" => SearchKind::Random,
            "tpe" => SearchKind::Tpe,
            "evolution" => SearchKind::Evolution,
            other => bail!("unknown search {other:?}"),
        };
        let workload = j
            .get("workload")
            .and_then(|v| v.as_str())
            .unwrap_or("curve")
            .to_string();
        let cluster = parse_cluster(j.get("cluster"))?;
        let autoscale = j.get("autoscale").map(parse_autoscale).transpose()?;
        // Clamped: the hub multiplies weights by the live-trial budget,
        // so an absurd value must not be able to overflow the math.
        let weight = (jf(&j, "weight").unwrap_or(1.0) as u64).clamp(1, 1_000_000);

        Ok(SpecFile { spec, space, scheduler, search, workload, cluster, autoscale, weight })
    }
}

/// Parse a resource vector: `cpu`/`gpu` plus arbitrary custom keys,
/// fractional amounts allowed. Rejects NaN/negative quantities up front
/// so a bad demand errors at spec load, not mid-experiment.
fn parse_resources(j: &Json) -> Result<Resources> {
    let obj = j.as_obj().ok_or_else(|| anyhow!("expected a {{name: amount}} object"))?;
    // Default 1 CPU, matching ExperimentSpec::named.
    let mut r = Resources { cpu: 1.0, ..Default::default() };
    for (k, v) in obj {
        let amount = v.as_f64().ok_or_else(|| anyhow!("{k}: expected a number"))?;
        match k.as_str() {
            "cpu" => r.cpu = amount,
            "gpu" => r.gpu = amount,
            _ => {
                r.custom.insert(k.clone(), amount);
            }
        }
    }
    r.validate_demand().map_err(|e| anyhow!("resources_per_trial: {e}"))?;
    Ok(r)
}

/// Parse the cluster shape: uniform (`{"nodes": 4, "cpus_per_node": 8,
/// "gpus_per_node": 4}`) or heterogeneous (`{"nodes": [{"cpus": 8,
/// "gpus": 4}, {"cpus": 16}]}`, custom keys allowed per node). A node's
/// `"price_per_hour"` is billing metadata, not a resource dimension.
fn parse_cluster(j: Option<&Json>) -> Result<Cluster> {
    let Some(c) = j else {
        return Ok(Cluster::uniform(4, Resources::cpu(8.0)));
    };
    if let Some(list) = c.get("nodes").and_then(|n| n.as_arr()) {
        let mut shapes = Vec::with_capacity(list.len());
        for (i, nj) in list.iter().enumerate() {
            let obj = nj
                .as_obj()
                .ok_or_else(|| anyhow!("cluster.nodes[{i}]: expected an object"))?;
            let mut shape = Resources::default();
            let mut price = 0.0;
            for (k, v) in obj {
                let amount =
                    v.as_f64().ok_or_else(|| anyhow!("cluster.nodes[{i}].{k}: bad number"))?;
                match k.as_str() {
                    "cpus" | "cpu" => shape.cpu = amount,
                    "gpus" | "gpu" => shape.gpu = amount,
                    "price_per_hour" => price = amount,
                    _ => {
                        shape.custom.insert(k.clone(), amount);
                    }
                }
            }
            shape
                .validate_demand()
                .map_err(|e| anyhow!("cluster.nodes[{i}]: {e}"))?;
            anyhow::ensure!(
                price.is_finite() && price >= 0.0,
                "cluster.nodes[{i}].price_per_hour: must be finite and >= 0"
            );
            shapes.push((shape, price));
        }
        anyhow::ensure!(!shapes.is_empty(), "cluster.nodes: empty node list");
        return Ok(Cluster::heterogeneous_priced(shapes));
    }
    let nodes = jf(c, "nodes").unwrap_or(4.0) as usize;
    let cpus = jf(c, "cpus_per_node").unwrap_or(8.0);
    let gpus = jf(c, "gpus_per_node").unwrap_or(0.0);
    Ok(Cluster::uniform(nodes.max(1), Resources::cpu_gpu(cpus, gpus)))
}

/// Parse the `autoscale` block into an [`AutoscalePolicy`] (defaults
/// applied per field; the node template defaults to an 8-CPU node). An
/// optional `"templates"` array of priced node objects (`{"cpus": 8,
/// "gpus": 4, "price_per_hour": 6.0}`) gives the scaler a menu of
/// hardware shapes; `"node_price"` prices the legacy single template.
fn parse_autoscale(j: &Json) -> Result<AutoscalePolicy> {
    anyhow::ensure!(j.as_obj().is_some(), "autoscale: expected an object");
    let d = AutoscalePolicy::default();
    let template = Resources::cpu_gpu(
        jf(j, "node_cpus").unwrap_or(d.node_template.cpu),
        jf(j, "node_gpus").unwrap_or(0.0),
    );
    let mut templates = Vec::new();
    if let Some(list) = j.get("templates").and_then(|t| t.as_arr()) {
        for (i, tj) in list.iter().enumerate() {
            let obj = tj
                .as_obj()
                .ok_or_else(|| anyhow!("autoscale.templates[{i}]: expected an object"))?;
            let mut shape = Resources::default();
            let mut price = 0.0;
            for (k, v) in obj {
                let amount = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("autoscale.templates[{i}].{k}: bad number"))?;
                match k.as_str() {
                    "cpus" | "cpu" => shape.cpu = amount,
                    "gpus" | "gpu" => shape.gpu = amount,
                    "price_per_hour" => price = amount,
                    _ => {
                        shape.custom.insert(k.clone(), amount);
                    }
                }
            }
            templates.push(NodeTemplate { shape, price_per_hour: price });
        }
    }
    if templates.is_empty() {
        if let Some(p) = jf(j, "node_price") {
            templates.push(NodeTemplate { shape: template.clone(), price_per_hour: p });
        }
    }
    let policy = AutoscalePolicy {
        node_template: template,
        templates,
        min_nodes: jf(j, "min_nodes").unwrap_or(d.min_nodes as f64) as usize,
        max_nodes: jf(j, "max_nodes").unwrap_or(d.max_nodes as f64) as usize,
        scale_up_after: jf(j, "scale_up_after").unwrap_or(d.scale_up_after as f64) as u64,
        scale_down_after: jf(j, "scale_down_after").unwrap_or(d.scale_down_after as f64) as u64,
        scale_down_util: jf(j, "scale_down_util").unwrap_or(d.scale_down_util),
    };
    policy.validate().map_err(|e| anyhow!("autoscale: {e}"))?;
    Ok(policy)
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"{
        "name": "t", "metric": "loss", "mode": "min",
        "num_samples": 8, "max_iterations_per_trial": 27, "seed": 5,
        "workload": "curve",
        "scheduler": {"type": "asha", "grace_period": 2, "reduction_factor": 3, "max_t": 27},
        "search": "tpe",
        "space": {
            "lr": {"loguniform": [1e-4, 1.0]},
            "momentum": {"uniform": [0.8, 0.99]},
            "activation": {"choice": ["relu", "tanh"]},
            "layers": {"randint": [1, 4]},
            "bs": {"grid": [16, 32]},
            "model": "mlp"
        },
        "cluster": {"nodes": 2, "cpus_per_node": 4},
        "resources_per_trial": {"cpu": 0.5}
    }"#;

    #[test]
    fn parses_full_spec() {
        let f = SpecFile::parse_str(EXAMPLE).unwrap();
        assert_eq!(f.spec.name, "t");
        assert_eq!(f.spec.num_samples, 8);
        assert_eq!(f.spec.mode, Mode::Min);
        assert_eq!(f.spec.seed, 5);
        assert_eq!(f.spec.resources_per_trial.cpu, 0.5);
        assert_eq!(f.space.len(), 6);
        assert!(matches!(f.space["lr"], ParamDist::LogUniform(..)));
        assert!(matches!(f.space["bs"], ParamDist::GridSearch(..)));
        assert!(matches!(f.space["model"], ParamDist::Const(..)));
        assert!(matches!(f.scheduler, SchedulerKind::Asha { grace_period: 2, .. }));
        assert_eq!(f.cluster.nodes.len(), 2);
        assert_eq!(f.workload, "curve");
    }

    #[test]
    fn defaults_apply() {
        let f = SpecFile::parse_str(r#"{"space": {"x": {"uniform": [0, 1]}}}"#).unwrap();
        assert!(matches!(f.scheduler, SchedulerKind::Fifo));
        assert_eq!(f.spec.metric, "loss");
        assert_eq!(f.workload, "curve");
    }

    #[test]
    fn pbt_scheduler_captures_space() {
        let f = SpecFile::parse_str(
            r#"{"space": {"lr": {"loguniform": [1e-4, 1.0]}},
                "scheduler": {"type": "pbt", "perturbation_interval": 5}}"#,
        )
        .unwrap();
        match f.scheduler {
            SchedulerKind::Pbt { perturbation_interval, space } => {
                assert_eq!(perturbation_interval, 5);
                assert!(space.contains_key("lr"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_specs_error() {
        assert!(SpecFile::parse_str("{").is_err());
        assert!(SpecFile::parse_str(r#"{"mode": "sideways"}"#).is_err());
        assert!(SpecFile::parse_str(r#"{"scheduler": "warp"}"#).is_err());
        assert!(SpecFile::parse_str(r#"{"space": {"x": {"zipf": [1]}}}"#).is_err());
    }

    #[test]
    fn resources_accept_fractional_gpu_and_custom_keys() {
        let f = SpecFile::parse_str(
            r#"{"resources_per_trial": {"cpu": 0.5, "gpu": 0.25, "tpu": 1}}"#,
        )
        .unwrap();
        let r = &f.spec.resources_per_trial;
        assert_eq!(r.cpu, 0.5);
        assert_eq!(r.gpu, 0.25);
        assert_eq!(r.custom.get("tpu"), Some(&1.0));
        // cpu omitted: defaults to 1, matching ExperimentSpec::named.
        let f = SpecFile::parse_str(r#"{"resources_per_trial": {"gpu": 2}}"#).unwrap();
        assert_eq!(f.spec.resources_per_trial.cpu, 1.0);
        assert_eq!(f.spec.resources_per_trial.gpu, 2.0);
    }

    #[test]
    fn negative_or_non_numeric_resources_error() {
        assert!(SpecFile::parse_str(r#"{"resources_per_trial": {"gpu": -1}}"#).is_err());
        assert!(SpecFile::parse_str(r#"{"resources_per_trial": {"cpu": "lots"}}"#).is_err());
        assert!(SpecFile::parse_str(r#"{"resources_per_trial": 4}"#).is_err());
    }

    #[test]
    fn heterogeneous_cluster_node_list() {
        let f = SpecFile::parse_str(
            r#"{"cluster": {"nodes": [
                {"cpus": 8, "gpus": 4},
                {"cpus": 8, "gpus": 4},
                {"cpus": 16},
                {"cpus": 4, "tpu": 2}
            ]}}"#,
        )
        .unwrap();
        assert_eq!(f.cluster.nodes.len(), 4);
        assert_eq!(f.cluster.node(0).total, Resources::cpu_gpu(8.0, 4.0));
        assert_eq!(f.cluster.node(2).total, Resources::cpu(16.0));
        assert_eq!(f.cluster.node(3).total.custom.get("tpu"), Some(&2.0));
        assert!(SpecFile::parse_str(r#"{"cluster": {"nodes": []}}"#).is_err());
        assert!(SpecFile::parse_str(r#"{"cluster": {"nodes": [{"cpus": -8}]}}"#).is_err());
    }

    #[test]
    fn autoscale_block_parses_into_policy() {
        let f = SpecFile::parse_str(
            r#"{"autoscale": {"max_nodes": 6, "min_nodes": 2, "node_cpus": 8,
                "node_gpus": 4, "scale_up_after": 3, "scale_down_after": 40,
                "scale_down_util": 0.2}}"#,
        )
        .unwrap();
        let p = f.autoscale.expect("autoscale parsed");
        assert_eq!(p.max_nodes, 6);
        assert_eq!(p.min_nodes, 2);
        assert_eq!(p.node_template, Resources::cpu_gpu(8.0, 4.0));
        assert_eq!(p.scale_up_after, 3);
        assert_eq!(p.scale_down_after, 40);
        assert_eq!(p.scale_down_util, 0.2);
        // Absent block: no autoscaler.
        assert!(SpecFile::parse_str("{}").unwrap().autoscale.is_none());
        // Bad knobs error.
        assert!(SpecFile::parse_str(r#"{"autoscale": {"scale_down_util": 2}}"#).is_err());
        assert!(SpecFile::parse_str(r#"{"autoscale": {"scale_up_after": 0}}"#).is_err());
    }

    #[test]
    fn hw_aware_budget_and_priced_nodes_parse() {
        let f = SpecFile::parse_str(
            r#"{"hw_aware": true,
                "budget": {"max_cost": 12.5},
                "cluster": {"nodes": [
                    {"cpus": 8, "gpus": 4, "price_per_hour": 6.0},
                    {"cpus": 8}
                ]},
                "autoscale": {"max_nodes": 4, "templates": [
                    {"cpus": 8, "gpus": 4, "price_per_hour": 6.0},
                    {"cpus": 8, "price_per_hour": 1.5}
                ]}}"#,
        )
        .unwrap();
        assert!(f.spec.hw_aware);
        assert_eq!(f.spec.budget_max_cost, Some(12.5));
        // price_per_hour is billing metadata, never a resource dimension.
        assert_eq!(f.cluster.node(0).total, Resources::cpu_gpu(8.0, 4.0));
        assert!(f.cluster.node(0).total.custom.is_empty());
        assert_eq!(f.cluster.node(0).price_per_hour, 6.0);
        assert_eq!(f.cluster.node(1).price_per_hour, 0.0);
        let p = f.autoscale.expect("autoscale parsed");
        assert_eq!(p.templates.len(), 2);
        assert_eq!(p.templates[0].shape, Resources::cpu_gpu(8.0, 4.0));
        assert_eq!(p.templates[0].price_per_hour, 6.0);
        assert_eq!(p.templates[1].price_per_hour, 1.5);
        // node_price prices the legacy single-template form.
        let f = SpecFile::parse_str(
            r#"{"autoscale": {"node_cpus": 16, "node_price": 2.0}}"#,
        )
        .unwrap();
        let p = f.autoscale.expect("autoscale parsed");
        assert_eq!(p.templates.len(), 1);
        assert_eq!(p.templates[0].shape, Resources::cpu(16.0));
        assert_eq!(p.templates[0].price_per_hour, 2.0);
        // Defaults: flag off, no budget, no templates.
        let f = SpecFile::parse_str("{}").unwrap();
        assert!(!f.spec.hw_aware);
        assert_eq!(f.spec.budget_max_cost, None);
        // Bad money errors.
        assert!(SpecFile::parse_str(r#"{"budget": {"max_cost": -1}}"#).is_err());
        assert!(SpecFile::parse_str(
            r#"{"cluster": {"nodes": [{"cpus": 8, "price_per_hour": -1}]}}"#
        )
        .is_err());
        assert!(SpecFile::parse_str(
            r#"{"autoscale": {"templates": [{"cpus": 8, "price_per_hour": -1}]}}"#
        )
        .is_err());
    }

    #[test]
    fn sampled_configs_respect_parsed_space() {
        let f = SpecFile::parse_str(EXAMPLE).unwrap();
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..100 {
            let c = crate::coordinator::spec::sample_config(&f.space, &mut rng);
            for (k, d) in &f.space {
                assert!(d.contains(&c[k]), "{k}");
            }
        }
    }
}
