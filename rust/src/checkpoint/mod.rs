//! Checkpoint management. Per the paper (§4.2), Tune keeps trial
//! metadata in memory and relies on checkpoints for fault tolerance;
//! schedulers "save and clone promising parameters (via checkpoint and
//! restore)". Checkpoints are opaque byte blobs produced by
//! `Trainable::save`; the store keeps them in memory and can optionally
//! spill every write to disk for post-mortem restore.

use std::collections::BTreeMap;
use std::path::PathBuf;

/// Handle to one stored checkpoint.
pub type CheckpointId = u64;

/// Bookkeeping for one checkpoint.
#[derive(Clone, Debug)]
pub struct CheckpointMeta {
    /// The checkpoint's id.
    pub id: CheckpointId,
    /// Trial that produced it.
    pub trial: u64,
    /// Training iteration at snapshot time.
    pub iteration: u64,
    /// Blob size in bytes.
    pub bytes: usize,
}

/// In-memory checkpoint store with per-trial GC and optional disk spill.
#[derive(Debug, Default)]
pub struct CheckpointStore {
    next_id: CheckpointId,
    data: BTreeMap<CheckpointId, Vec<u8>>,
    meta: BTreeMap<CheckpointId, CheckpointMeta>,
    /// Latest checkpoint per trial (what PBT exploit clones).
    latest: BTreeMap<u64, CheckpointId>,
    disk_dir: Option<PathBuf>,
    /// Keep at most this many checkpoints per trial (0 = unbounded).
    pub keep_per_trial: usize,
    /// Checkpoints written so far.
    pub saved: u64,
    /// Successful reads so far.
    pub restored: u64,
}

impl CheckpointStore {
    /// A store keeping the 2 newest checkpoints per trial.
    pub fn new() -> Self {
        CheckpointStore { next_id: 1, keep_per_trial: 2, ..Default::default() }
    }

    /// Also persist every checkpoint under `dir` (for `analyze`/restart).
    pub fn with_disk(mut self, dir: PathBuf) -> Self {
        std::fs::create_dir_all(&dir).ok();
        self.disk_dir = Some(dir);
        self
    }

    /// Store a blob for `trial` at `iteration`; returns its id.
    pub fn save(&mut self, trial: u64, iteration: u64, blob: Vec<u8>) -> CheckpointId {
        let id = self.next_id;
        self.next_id += 1;
        if let Some(dir) = &self.disk_dir {
            let path = dir.join(format!("trial{trial}_iter{iteration}_ckpt{id}.bin"));
            std::fs::write(path, &blob).ok();
        }
        self.meta.insert(id, CheckpointMeta { id, trial, iteration, bytes: blob.len() });
        self.data.insert(id, blob);
        self.latest.insert(trial, id);
        self.saved += 1;
        self.gc(trial);
        id
    }

    /// Read a checkpoint blob back (counts as a restore).
    pub fn get(&mut self, id: CheckpointId) -> Option<&[u8]> {
        let found = self.data.get(&id).map(|v| v.as_slice());
        if found.is_some() {
            self.restored += 1;
        }
        found
    }

    /// Metadata of a stored checkpoint.
    pub fn meta(&self, id: CheckpointId) -> Option<&CheckpointMeta> {
        self.meta.get(&id)
    }

    /// Newest checkpoint id for a trial, if any.
    pub fn latest_for(&self, trial: u64) -> Option<CheckpointId> {
        self.latest.get(&trial).copied()
    }

    /// Drop all but the newest `keep_per_trial` checkpoints of `trial`.
    fn gc(&mut self, trial: u64) {
        if self.keep_per_trial == 0 {
            return;
        }
        let mut ids: Vec<CheckpointId> = self
            .meta
            .values()
            .filter(|m| m.trial == trial)
            .map(|m| m.id)
            .collect();
        ids.sort();
        while ids.len() > self.keep_per_trial {
            let old = ids.remove(0);
            self.data.remove(&old);
            self.meta.remove(&old);
        }
    }

    /// Number of checkpoints currently stored.
    pub fn len(&self) -> usize {
        self.data.len()
    }
    /// True when no checkpoints are stored.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    /// Total stored bytes across checkpoints.
    pub fn total_bytes(&self) -> usize {
        self.data.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_get_roundtrip() {
        let mut s = CheckpointStore::new();
        let id = s.save(7, 10, vec![1, 2, 3]);
        assert_eq!(s.get(id).unwrap(), &[1, 2, 3]);
        assert_eq!(s.latest_for(7), Some(id));
        assert_eq!(s.meta(id).unwrap().iteration, 10);
        assert_eq!((s.saved, s.restored), (1, 1));
    }

    #[test]
    fn gc_keeps_newest() {
        let mut s = CheckpointStore::new(); // keep_per_trial = 2
        let a = s.save(1, 1, vec![1]);
        let b = s.save(1, 2, vec![2]);
        let c = s.save(1, 3, vec![3]);
        assert!(s.get(a).is_none());
        assert!(s.get(b).is_some());
        assert_eq!(s.latest_for(1), Some(c));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn gc_is_per_trial() {
        let mut s = CheckpointStore::new();
        for t in 0..4 {
            s.save(t, 1, vec![t as u8]);
        }
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn disk_spill_writes_files() {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_test_{}", std::process::id()));
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        s.save(1, 5, vec![9; 16]);
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(n, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
