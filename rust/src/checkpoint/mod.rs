//! Checkpoint management. Per the paper (§4.2), Tune keeps trial
//! metadata in memory and relies on checkpoints for fault tolerance;
//! schedulers "save and clone promising parameters (via checkpoint and
//! restore)". Checkpoints are opaque byte blobs produced by
//! `Trainable::save`.
//!
//! The store is **content-addressed** (see [`chunk`]): every blob is
//! identified by a 128-bit whole-blob hash and split into
//! content-defined chunks held in a refcounted [`chunk::ChunkTable`].
//! Two consequences drive the design:
//!
//! * **PBT exploit clones are refcount bumps.** Saving bytes the store
//!   already holds — the exploit path hands the donor's `Arc<[u8]>`
//!   straight back in — matches on the blob key and stores nothing.
//! * **Lineage checkpoints dedup.** Consecutive checkpoints of one
//!   trial share all chunks outside the mutated regions, so keeping a
//!   deep history costs the *delta*, not the full state, per step.
//!
//! Per-trial GC decrements refcounts and only physically frees a chunk
//! (memory and its spill file) at refcount zero. With a disk directory
//! attached, chunks stream to `checkpoints/chunks/` with the atomic
//! write + fsync discipline of `persist.rs`, and an optional memory
//! budget evicts cold payloads to that tier; `get` faults them back in
//! with length + rehash verification, degrading a torn file to "blob
//! unavailable" (the runner restarts that trial from scratch) instead
//! of serving corrupt bytes. Snapshots persist chunk *manifests*;
//! refcounts and indices are rebuilt on restore, and legacy whole-blob
//! snapshots (pre-chunk format) remain restorable.
//!
//! # Example
//!
//! ```
//! use tune::checkpoint::CheckpointStore;
//!
//! let mut store = CheckpointStore::new(); // keeps the 2 newest per trial
//! let id = store.save(7, 10, vec![1, 2, 3]);
//! assert_eq!(store.get(id).as_deref(), Some(&[1u8, 2, 3][..]));
//! assert_eq!(store.latest_for(7), Some(id));
//! assert_eq!(store.meta(id).unwrap().iteration, 10);
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::util::json::Json;

pub mod chunk;

pub use chunk::{ChunkParams, ChunkTable, ChunkTableStats, ContentHash, SharedChunkTable};

use chunk::{blob_key, intern_manifest};

/// Handle to one stored checkpoint.
pub type CheckpointId = u64;

/// Bookkeeping for one checkpoint.
#[derive(Clone, Debug)]
pub struct CheckpointMeta {
    /// The checkpoint's id.
    pub id: CheckpointId,
    /// Trial that produced it.
    pub trial: u64,
    /// Training iteration at snapshot time.
    pub iteration: u64,
    /// Training seconds the trial had consumed at snapshot time (0.0
    /// when saved via [`CheckpointStore::save`]; the runner uses
    /// [`CheckpointStore::save_timed`] so crash-resume rollback restores
    /// time accounting exactly, not just the iteration count).
    pub time_total_s: f64,
    /// Blob size in bytes (logical — the deduped physical footprint is
    /// tracked by the chunk table).
    pub bytes: usize,
}

/// One distinct blob: its chunk manifest plus how many checkpoint ids
/// currently map to it.
#[derive(Debug)]
struct BlobEntry {
    /// Checkpoint ids referencing this blob.
    refs: u64,
    /// Logical length in bytes.
    len: usize,
    /// Ordered `(chunk key, chunk length)` — concatenation rebuilds the
    /// blob.
    manifest: Vec<(ContentHash, u32)>,
    /// Cached fully-assembled blob (what `get` hands out); dropped
    /// first under memory pressure, rebuilt from chunks on demand.
    assembled: Option<Arc<[u8]>>,
    /// LRU clock for assembled-cache eviction.
    last_use: u64,
}

/// Copyable store counters, surfaced in `ExperimentResult` and benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct CkptStoreStats {
    /// Checkpoints written over the store's lifetime.
    pub saved: u64,
    /// Successful blob reads over the store's lifetime.
    pub restored: u64,
    /// Checkpoints currently live.
    pub checkpoints: u64,
    /// Distinct blobs currently live.
    pub unique_blobs: u64,
    /// Distinct chunks currently live in the chunk table.
    pub unique_chunks: u64,
    /// Sum of live checkpoints' blob sizes (pre-dedup).
    pub logical_bytes: u64,
    /// Deduped bytes in the chunk table. With a chunk table shared
    /// across stores this includes the other owners' chunks.
    pub physical_bytes: u64,
    /// Memory-resident bytes: chunk payloads + assembled-blob caches.
    pub resident_bytes: u64,
    /// Saves that matched a live blob byte-for-byte (PBT exploit
    /// clones and no-progress re-saves).
    pub blob_dedup_hits: u64,
    /// Chunk interns that matched an existing chunk.
    pub chunk_dedup_hits: u64,
    /// Chunks spilled to the disk tier.
    pub spilled_chunks: u64,
    /// Evicted chunks faulted back in from disk.
    pub chunk_disk_loads: u64,
}

impl CkptStoreStats {
    /// Logical bytes ÷ physical bytes — how much the content addressing
    /// saved. 1.0 means no duplication existed; an exploit-heavy PBT
    /// run is expected well above 5.
    pub fn dedup_ratio(&self) -> f64 {
        if self.physical_bytes == 0 {
            if self.logical_bytes == 0 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            self.logical_bytes as f64 / self.physical_bytes as f64
        }
    }
}

/// Content-addressed checkpoint store with per-trial GC, blob- and
/// chunk-level dedup, and an optional memory-budgeted disk tier.
#[derive(Debug, Default)]
pub struct CheckpointStore {
    next_id: CheckpointId,
    meta: BTreeMap<CheckpointId, CheckpointMeta>,
    /// Checkpoint id -> whole-blob content key.
    blob_of: BTreeMap<CheckpointId, ContentHash>,
    /// Distinct live blobs by content key.
    blobs: BTreeMap<ContentHash, BlobEntry>,
    /// The refcounted chunk tier (shareable with the object store).
    table: SharedChunkTable,
    /// Latest checkpoint per trial (what PBT exploit clones).
    latest: BTreeMap<u64, CheckpointId>,
    /// Live checkpoint ids per trial, ascending — O(1) GC eviction
    /// instead of a full meta scan per save.
    per_trial: BTreeMap<u64, Vec<CheckpointId>>,
    /// Ids restored from a legacy whole-blob snapshot -> their
    /// `trialN_iterM_ckptK.bin` file, deleted when that id is GCed.
    legacy_files: BTreeMap<CheckpointId, String>,
    disk_dir: Option<PathBuf>,
    /// Cap on memory-resident bytes (assembled caches + chunk
    /// payloads); `None` = unbounded. Eviction needs the disk tier.
    mem_budget: Option<usize>,
    /// Bytes currently held in assembled-blob caches.
    assembled_bytes: usize,
    /// Sum of live checkpoints' logical sizes.
    logical_bytes: u64,
    /// LRU clock shared by save/get touches.
    tick: u64,
    /// Saves deduped at the whole-blob level.
    blob_dedup_hits: u64,
    /// Keep at most this many checkpoints per trial (0 = unbounded).
    pub keep_per_trial: usize,
    /// Checkpoints written so far.
    pub saved: u64,
    /// Successful reads so far.
    pub restored: u64,
    /// Ids saved since the delta cursor was last reset (still live —
    /// a same-window GC eviction removes the id from here instead of
    /// recording a remove).
    delta_added: Vec<CheckpointId>,
    /// Ids GC-evicted since the delta cursor was last reset.
    delta_removed: Vec<CheckpointId>,
}

impl CheckpointStore {
    /// A store keeping the 2 newest checkpoints per trial.
    pub fn new() -> Self {
        CheckpointStore { next_id: 1, keep_per_trial: 2, ..Default::default() }
    }

    /// Also persist every checkpoint under `dir` (for `analyze`/
    /// restart): chunks stream to `dir/chunks/` as they are interned.
    /// Chunks saved before the tier was attached are spilled eagerly.
    pub fn with_disk(mut self, dir: PathBuf) -> Self {
        std::fs::create_dir_all(&dir).ok();
        self.table.lock().expect("chunk table lock").set_disk_dir(dir.join("chunks"));
        self.disk_dir = Some(dir);
        self
    }

    /// Use a caller-provided chunk table (shared with the plasma object
    /// store, so cross-layer duplicates are stored once). Must be
    /// called before any save.
    pub fn with_chunk_table(mut self, table: SharedChunkTable) -> Self {
        debug_assert!(self.blobs.is_empty(), "attach the shared table before saving");
        self.table = table;
        self
    }

    /// Handle to the underlying chunk table.
    pub fn chunk_table(&self) -> SharedChunkTable {
        Arc::clone(&self.table)
    }

    /// Cap memory-resident bytes (assembled caches + chunk payloads),
    /// evicting immediately if over. Chunk eviction requires the disk
    /// tier; without it only assembled caches are droppable (chunks are
    /// the sole copy of the bytes).
    pub fn set_mem_budget(&mut self, budget: Option<usize>) {
        self.mem_budget = budget;
        self.enforce_budget();
    }

    /// Store a blob for `trial` at `iteration`; returns its id.
    pub fn save(&mut self, trial: u64, iteration: u64, blob: impl Into<Arc<[u8]>>) -> CheckpointId {
        self.save_timed(trial, iteration, 0.0, blob)
    }

    /// [`CheckpointStore::save`] plus the trial's accumulated training
    /// seconds, so a crash-resume rollback can restore time accounting
    /// exactly alongside the iteration count. Accepts a `Vec<u8>`
    /// (fresh `Trainable::save` output) or an already-shared
    /// `Arc<[u8]>` (PBT exploit clones) — identical bytes dedup to a
    /// refcount bump on the existing blob entry; near-identical bytes
    /// share all unchanged chunks.
    pub fn save_timed(
        &mut self,
        trial: u64,
        iteration: u64,
        time_total_s: f64,
        blob: impl Into<Arc<[u8]>>,
    ) -> CheckpointId {
        let blob: Arc<[u8]> = blob.into();
        let key = blob_key(&blob);
        let id = self.next_id;
        self.next_id += 1;
        let meta = CheckpointMeta { id, trial, iteration, time_total_s, bytes: blob.len() };
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.blobs.get_mut(&key) {
            debug_assert_eq!(e.len, blob.len(), "blob key collision");
            e.refs += 1;
            e.last_use = tick;
            if e.assembled.is_none() {
                self.assembled_bytes += e.len;
                e.assembled = Some(Arc::clone(&blob));
            }
            self.blob_dedup_hits += 1;
        } else {
            let manifest = {
                let mut table = self.table.lock().expect("chunk table lock");
                intern_manifest(&mut table, &blob)
            };
            self.assembled_bytes += blob.len();
            self.blobs.insert(
                key,
                BlobEntry {
                    refs: 1,
                    len: blob.len(),
                    manifest,
                    assembled: Some(blob),
                    last_use: tick,
                },
            );
        }
        self.logical_bytes += meta.bytes as u64;
        self.blob_of.insert(id, key);
        self.meta.insert(id, meta);
        self.latest.insert(trial, id);
        self.per_trial.entry(trial).or_default().push(id);
        self.saved += 1;
        self.delta_added.push(id);
        self.gc(trial);
        self.enforce_budget();
        id
    }

    /// Shared handle to a checkpoint blob (counts as a restore). A
    /// cached assembled blob is a refcount bump, not a byte copy;
    /// otherwise the blob is reassembled from its chunks, faulting
    /// evicted ones in from disk. Returns `None` for unknown ids *and*
    /// for blobs whose chunks can no longer be read back verifiably
    /// (torn spill file) — callers degrade that trial to
    /// replay-from-scratch rather than poisoning the store.
    pub fn get(&mut self, id: CheckpointId) -> Option<Arc<[u8]>> {
        let key = *self.blob_of.get(&id)?;
        self.tick += 1;
        let tick = self.tick;
        {
            let e = self.blobs.get_mut(&key)?;
            e.last_use = tick;
            if let Some(b) = &e.assembled {
                self.restored += 1;
                return Some(Arc::clone(b));
            }
        }
        // Slow path: reassemble from (possibly spilled) chunks.
        let (len, manifest) = {
            let e = &self.blobs[&key];
            (e.len, e.manifest.clone())
        };
        let mut buf = Vec::with_capacity(len);
        {
            let mut table = self.table.lock().expect("chunk table lock");
            for (k, l) in &manifest {
                let piece = table.get(*k)?;
                if piece.len() != *l as usize {
                    return None;
                }
                buf.extend_from_slice(&piece);
            }
        }
        if buf.len() != len {
            return None;
        }
        let arc: Arc<[u8]> = buf.into();
        let e = self.blobs.get_mut(&key).expect("blob entry seen above");
        e.assembled = Some(Arc::clone(&arc));
        self.assembled_bytes += len;
        self.restored += 1;
        self.enforce_budget();
        Some(arc)
    }

    /// Metadata of a stored checkpoint.
    pub fn meta(&self, id: CheckpointId) -> Option<&CheckpointMeta> {
        self.meta.get(&id)
    }

    /// Newest checkpoint id for a trial, if any.
    pub fn latest_for(&self, trial: u64) -> Option<CheckpointId> {
        self.latest.get(&trial).copied()
    }

    /// All live checkpoint ids, ascending.
    pub fn ids(&self) -> impl Iterator<Item = CheckpointId> + '_ {
        self.meta.keys().copied()
    }

    /// Drop all but the newest `keep_per_trial` checkpoints of `trial`.
    /// Eviction decrements the blob's refcount; the blob's chunks are
    /// only physically freed (memory and spill files) when no live blob
    /// references them. (Snapshots only ever reference still-live
    /// metadata, so freeing evicted chunks never breaks resume.)
    fn gc(&mut self, trial: u64) {
        if self.keep_per_trial == 0 {
            return;
        }
        loop {
            let Some(ids) = self.per_trial.get(&trial) else { return };
            if ids.len() <= self.keep_per_trial {
                return;
            }
            let old = ids[0];
            // Delta bookkeeping: an id born and evicted inside the same
            // delta window never reaches disk state — drop it from the
            // add list instead of journaling a remove.
            if let Some(pos) = self.delta_added.iter().position(|a| *a == old) {
                self.delta_added.swap_remove(pos);
            } else {
                self.delta_removed.push(old);
            }
            self.drop_checkpoint(old);
        }
    }

    /// Remove one checkpoint from every index, releasing its blob (and
    /// transitively its chunks at refcount zero). No delta journaling —
    /// callers that evict live state journal at their own site.
    fn drop_checkpoint(&mut self, id: CheckpointId) {
        let Some(meta) = self.meta.remove(&id) else { return };
        self.logical_bytes -= meta.bytes as u64;
        if let Some(ids) = self.per_trial.get_mut(&meta.trial) {
            if let Some(pos) = ids.iter().position(|x| *x == id) {
                ids.remove(pos);
            }
            match ids.last() {
                Some(l) => {
                    self.latest.insert(meta.trial, *l);
                }
                None => {
                    self.per_trial.remove(&meta.trial);
                    self.latest.remove(&meta.trial);
                }
            }
        }
        if let Some(key) = self.blob_of.remove(&id) {
            let free = {
                let e = self.blobs.get_mut(&key).expect("blob entry for live checkpoint");
                e.refs -= 1;
                e.refs == 0
            };
            if free {
                let e = self.blobs.remove(&key).expect("entry just seen");
                if e.assembled.is_some() {
                    self.assembled_bytes -= e.len;
                }
                let mut table = self.table.lock().expect("chunk table lock");
                for (k, _) in &e.manifest {
                    table.release(*k);
                }
            }
        }
        if let Some(name) = self.legacy_files.remove(&id) {
            if let Some(dir) = &self.disk_dir {
                std::fs::remove_file(dir.join(name)).ok();
            }
        }
    }

    /// Enforce the memory budget: drop assembled-blob caches coldest
    /// first (they are rebuildable from chunks), then evict chunk
    /// payloads to the disk tier.
    fn enforce_budget(&mut self) {
        let Some(budget) = self.mem_budget else { return };
        let chunk_resident =
            self.table.lock().expect("chunk table lock").resident_bytes() as usize;
        let mut total = self.assembled_bytes + chunk_resident;
        if total <= budget {
            return;
        }
        let mut victims: Vec<(u64, ContentHash, usize)> = self
            .blobs
            .iter()
            .filter(|(_, e)| e.assembled.is_some())
            .map(|(k, e)| (e.last_use, *k, e.len))
            .collect();
        victims.sort_unstable();
        for (_, key, len) in victims {
            if total <= budget {
                break;
            }
            let e = self.blobs.get_mut(&key).expect("entry just listed");
            e.assembled = None;
            self.assembled_bytes -= len;
            total -= len;
        }
        if total > budget {
            let chunk_budget = budget.saturating_sub(self.assembled_bytes) as u64;
            self.table.lock().expect("chunk table lock").evict_to(chunk_budget);
        }
    }

    /// File name a legacy whole-blob checkpoint spilled to (the
    /// pre-chunk on-disk format, still read on restore).
    fn spill_name(meta: &CheckpointMeta) -> String {
        format!("trial{}_iter{}_ckpt{}.bin", meta.trial, meta.iteration, meta.id)
    }

    fn meta_json(&self, m: &CheckpointMeta) -> Json {
        let key = self.blob_of.get(&m.id).expect("live meta has a blob key");
        Json::obj(vec![
            ("id", Json::Num(m.id as f64)),
            ("trial", Json::Num(m.trial as f64)),
            ("iteration", Json::Num(m.iteration as f64)),
            ("time", Json::Num(m.time_total_s)),
            ("bytes", Json::Num(m.bytes as f64)),
            ("blob", Json::Str(key.to_hex())),
        ])
    }

    fn manifest_json(manifest: &[(ContentHash, u32)]) -> Json {
        Json::Arr(
            manifest
                .iter()
                .map(|(k, l)| Json::Arr(vec![Json::Str(k.to_hex()), Json::Num(*l as f64)]))
                .collect(),
        )
    }

    fn parse_manifest(v: &Json) -> Option<Vec<(ContentHash, u32)>> {
        let arr = v.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for pair in arr {
            let pair = pair.as_arr()?;
            if pair.len() != 2 {
                return None;
            }
            let key = ContentHash::from_hex(pair[0].as_str()?)?;
            let len = pair[1].as_u64()?;
            out.push((key, len as u32));
        }
        Some(out)
    }

    /// Serialize the store's metadata for the experiment snapshot:
    /// checkpoint metas (with their blob keys) plus each distinct
    /// blob's chunk manifest. Chunk *bytes* are not embedded — they
    /// live in the `chunks/` spill tier.
    pub fn snapshot(&self) -> Json {
        let metas = self.meta.values().map(|m| self.meta_json(m)).collect();
        let blobs = self
            .blobs
            .iter()
            .map(|(key, e)| {
                Json::obj(vec![
                    ("key", Json::Str(key.to_hex())),
                    ("len", Json::Num(e.len as f64)),
                    ("chunks", Self::manifest_json(&e.manifest)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("next_id", Json::Num(self.next_id as f64)),
            ("saved", Json::Num(self.saved as f64)),
            ("restored", Json::Num(self.restored as f64)),
            ("metas", Json::Arr(metas)),
            ("blobs", Json::Arr(blobs)),
        ])
    }

    fn parse_meta(m: &Json) -> Result<CheckpointMeta, String> {
        let (Some(id), Some(trial), Some(iteration), Some(bytes)) = (
            m.get("id").and_then(|v| v.as_u64()),
            m.get("trial").and_then(|v| v.as_u64()),
            m.get("iteration").and_then(|v| v.as_u64()),
            m.get("bytes").and_then(|v| v.as_u64()),
        ) else {
            return Err("checkpoint snapshot: malformed meta entry".into());
        };
        let time_total_s = m.get("time").and_then(|v| v.as_f64()).unwrap_or(0.0);
        Ok(CheckpointMeta { id, trial, iteration, time_total_s, bytes: bytes as usize })
    }

    /// Register a restored meta under `key` in every index (no delta
    /// journaling — restored state is the baseline the next journal
    /// diffs against).
    fn register_restored(&mut self, meta: CheckpointMeta, key: ContentHash) {
        let e = self.blobs.get_mut(&key).expect("blob entry materialized by caller");
        e.refs += 1;
        self.logical_bytes += meta.bytes as u64;
        self.blob_of.insert(meta.id, key);
        self.latest.insert(meta.trial, meta.id);
        self.per_trial.entry(meta.trial).or_default().push(meta.id);
        self.meta.insert(meta.id, meta);
    }

    /// Materialize (or validate against) the blob entry for `key`,
    /// two-phase: every chunk of the manifest must be resident or
    /// loadable+verifiable from disk before any refcount commits, so a
    /// half-valid manifest leaves no trace. Returns false to drop the
    /// checkpoint (degradation, not an error).
    fn adopt_blob(
        &mut self,
        key: ContentHash,
        len: usize,
        manifest: &[(ContentHash, u32)],
    ) -> bool {
        if let Some(e) = self.blobs.get(&key) {
            return e.len == len;
        }
        if manifest.iter().map(|(_, l)| *l as usize).sum::<usize>() != len {
            return false;
        }
        {
            let mut table = self.table.lock().expect("chunk table lock");
            if !manifest.iter().all(|(k, l)| table.ensure_loadable(*k, *l as usize)) {
                return false;
            }
            for (k, _) in manifest {
                table.commit_ref(*k);
            }
        }
        self.blobs.insert(
            key,
            BlobEntry { refs: 0, len, manifest: manifest.to_vec(), assembled: None, last_use: 0 },
        );
        true
    }

    /// Ingest a whole blob read from a legacy spill file: chunk it into
    /// the table exactly like a fresh save (so a mixed legacy/new
    /// population still dedups), remembering the legacy file for
    /// deletion when this id is GCed. The legacy file itself is NOT
    /// deleted here — until the next full snapshot lands, a crash would
    /// re-restore from the *old* snapshot, which still needs it.
    fn ingest_legacy(&mut self, meta: CheckpointMeta, bytes: Vec<u8>, file: String) {
        let arc: Arc<[u8]> = bytes.into();
        let key = blob_key(&arc);
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.blobs.get_mut(&key) {
            e.last_use = tick;
        } else {
            let manifest = {
                let mut table = self.table.lock().expect("chunk table lock");
                intern_manifest(&mut table, &arc)
            };
            self.assembled_bytes += arc.len();
            self.blobs.insert(
                key,
                BlobEntry {
                    refs: 0,
                    len: arc.len(),
                    manifest,
                    assembled: Some(arc),
                    last_use: tick,
                },
            );
        }
        self.legacy_files.insert(meta.id, file);
        self.register_restored(meta, key);
    }

    /// Rebuild a store from a [`CheckpointStore::snapshot`] manifest.
    /// Chunked entries revalidate every chunk (resident or readable
    /// from `dir/chunks/` with matching length and content hash);
    /// legacy entries (no `blob` key) read their whole-blob spill file
    /// from `dir`. Entries that fail either way are dropped — callers
    /// fall back to restart-from-scratch for those trials. Refcounts
    /// and indices are recomputed here, never trusted from disk. The
    /// rebuilt store keeps spilling to `dir`.
    ///
    /// After folding any delta journals on top, call
    /// [`CheckpointStore::sweep_orphan_chunks`] — not earlier: a chunk
    /// file unreferenced by the base snapshot may belong to a blob only
    /// a later delta adds.
    pub fn restore_from(snap: &Json, dir: &Path) -> Result<Self, String> {
        let mut store = CheckpointStore::new().with_disk(dir.to_path_buf());
        store.next_id = snap
            .get("next_id")
            .and_then(|v| v.as_u64())
            .ok_or("checkpoint snapshot: missing next_id")?;
        store.saved = snap.get("saved").and_then(|v| v.as_u64()).unwrap_or(0);
        store.restored = snap.get("restored").and_then(|v| v.as_u64()).unwrap_or(0);
        let mut defs: BTreeMap<ContentHash, (usize, Vec<(ContentHash, u32)>)> = BTreeMap::new();
        if let Some(blobs) = snap.get("blobs").and_then(|b| b.as_arr()) {
            for b in blobs {
                let (Some(key), Some(len), Some(manifest)) = (
                    b.get("key").and_then(|v| v.as_str()).and_then(ContentHash::from_hex),
                    b.get("len").and_then(|v| v.as_u64()),
                    b.get("chunks").and_then(Self::parse_manifest),
                ) else {
                    return Err("checkpoint snapshot: malformed blob entry".into());
                };
                defs.insert(key, (len as usize, manifest));
            }
        }
        let metas = snap
            .get("metas")
            .and_then(|m| m.as_arr())
            .ok_or("checkpoint snapshot: missing metas")?;
        for m in metas {
            let meta = Self::parse_meta(m)?;
            match m.get("blob").and_then(|v| v.as_str()).and_then(ContentHash::from_hex) {
                Some(key) => {
                    let Some((len, manifest)) = defs.get(&key) else { continue };
                    if *len != meta.bytes {
                        continue;
                    }
                    // Clone keeps `defs` borrowed immutably only here.
                    let manifest = manifest.clone();
                    if !store.adopt_blob(key, *len, &manifest) {
                        continue;
                    }
                    store.register_restored(meta, key);
                }
                None => {
                    // Legacy whole-blob format.
                    let name = Self::spill_name(&meta);
                    let Ok(blob) = std::fs::read(dir.join(&name)) else {
                        continue; // spill file lost: drop the entry
                    };
                    if blob.len() != meta.bytes {
                        continue; // truncated write: drop the entry
                    }
                    store.ingest_legacy(meta, blob, name);
                }
            }
        }
        Ok(store)
    }

    /// Incremental snapshot: metadata added/removed since the last
    /// [`CheckpointStore::snapshot`]/delta, for the runner's delta
    /// records. Added entries carry their blob key *and* chunk manifest
    /// inline, so folding needs no base-snapshot lookup; chunk bytes
    /// are never embedded — the fold revalidates them from the spill
    /// tier, exactly like a full restore.
    pub fn snapshot_delta(&mut self) -> Json {
        let added = self
            .delta_added
            .iter()
            .filter_map(|id| self.meta.get(id))
            .map(|m| {
                let key = self.blob_of.get(&m.id).expect("live meta has a blob key");
                let e = &self.blobs[key];
                Json::obj(vec![
                    ("id", Json::Num(m.id as f64)),
                    ("trial", Json::Num(m.trial as f64)),
                    ("iteration", Json::Num(m.iteration as f64)),
                    ("time", Json::Num(m.time_total_s)),
                    ("bytes", Json::Num(m.bytes as f64)),
                    ("blob", Json::Str(key.to_hex())),
                    ("chunks", Self::manifest_json(&e.manifest)),
                ])
            })
            .collect();
        let removed = self.delta_removed.iter().map(|id| Json::Num(*id as f64)).collect();
        self.delta_added.clear();
        self.delta_removed.clear();
        Json::obj(vec![
            ("next_id", Json::Num(self.next_id as f64)),
            ("saved", Json::Num(self.saved as f64)),
            ("restored", Json::Num(self.restored as f64)),
            ("added", Json::Arr(added)),
            ("removed", Json::Arr(removed)),
        ])
    }

    /// Fold a [`CheckpointStore::snapshot_delta`] record into this
    /// store. Additions revalidate their chunks from the spill tier
    /// (legacy whole-blob entries read their spill file); entries that
    /// fail are dropped, the same degradation contract as
    /// [`CheckpointStore::restore_from`]. Folding never journals.
    pub fn apply_delta(&mut self, delta: &Json, dir: &Path) -> Result<(), String> {
        if let Some(n) = delta.get("next_id").and_then(|v| v.as_u64()) {
            self.next_id = n;
        }
        if let Some(n) = delta.get("saved").and_then(|v| v.as_u64()) {
            self.saved = n;
        }
        if let Some(n) = delta.get("restored").and_then(|v| v.as_u64()) {
            self.restored = n;
        }
        for m in delta
            .get("added")
            .and_then(|a| a.as_arr())
            .ok_or("checkpoint delta: missing added")?
        {
            let meta = Self::parse_meta(m).map_err(|_| "checkpoint delta: malformed added entry")?;
            match m.get("blob").and_then(|v| v.as_str()).and_then(ContentHash::from_hex) {
                Some(key) => {
                    let Some(manifest) = m.get("chunks").and_then(Self::parse_manifest) else {
                        return Err("checkpoint delta: malformed added entry".into());
                    };
                    if !self.adopt_blob(key, meta.bytes, &manifest) {
                        continue;
                    }
                    self.register_restored(meta, key);
                }
                None => {
                    let name = Self::spill_name(&meta);
                    let Ok(blob) = std::fs::read(dir.join(&name)) else {
                        continue; // spill file lost: drop the entry
                    };
                    if blob.len() != meta.bytes {
                        continue; // truncated write: drop the entry
                    }
                    self.ingest_legacy(meta, blob, name);
                }
            }
        }
        for id in delta
            .get("removed")
            .and_then(|r| r.as_arr())
            .ok_or("checkpoint delta: missing removed")?
        {
            let id = id.as_u64().ok_or("checkpoint delta: bad removed id")?;
            self.drop_checkpoint(id);
        }
        Ok(())
    }

    /// Drop refcount-0 chunk placeholders left by degraded manifests
    /// and delete chunk files no live chunk claims. Call once per
    /// restore, **after** all delta journals have folded. Returns the
    /// number of files removed.
    pub fn sweep_orphan_chunks(&mut self) -> usize {
        let mut table = self.table.lock().expect("chunk table lock");
        table.drop_unreferenced();
        table.sweep_orphans()
    }

    /// A full snapshot was just persisted; forget the journals.
    pub fn reset_delta_cursor(&mut self) {
        self.delta_added.clear();
        self.delta_removed.clear();
    }

    /// Number of checkpoints currently stored.
    pub fn len(&self) -> usize {
        self.meta.len()
    }
    /// True when no checkpoints are stored.
    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }
    /// Total *logical* bytes across live checkpoints (pre-dedup; the
    /// physical footprint is `stats().physical_bytes`).
    pub fn total_bytes(&self) -> usize {
        self.logical_bytes as usize
    }

    /// Current counters, cheap to copy into results and benches.
    pub fn stats(&self) -> CkptStoreStats {
        let t = self.table.lock().expect("chunk table lock").stats();
        CkptStoreStats {
            saved: self.saved,
            restored: self.restored,
            checkpoints: self.meta.len() as u64,
            unique_blobs: self.blobs.len() as u64,
            unique_chunks: t.unique_chunks,
            logical_bytes: self.logical_bytes,
            physical_bytes: t.physical_bytes,
            resident_bytes: t.resident_bytes + self.assembled_bytes as u64,
            blob_dedup_hits: self.blob_dedup_hits,
            chunk_dedup_hits: t.dedup_hits,
            spilled_chunks: t.spilled,
            chunk_disk_loads: t.disk_loads,
        }
    }

    /// Full-scan verification that every incrementally-maintained index
    /// and counter matches a recomputation from the ground-truth meta
    /// table — the store-level mirror of the runner's
    /// `debug_check_indices`. Covers: meta/blob_of/blobs alignment,
    /// per-trial index and `latest`, logical/assembled byte counters,
    /// blob refcounts vs live ids, chunk refcounts vs manifest
    /// occurrences, and the chunk tier's files (length-checked, no
    /// orphans). Panics on any violation. Test-only diagnostics.
    #[doc(hidden)]
    pub fn debug_check_store(&self) {
        assert_eq!(self.meta.len(), self.blob_of.len(), "meta/blob_of key drift");
        let mut logical = 0u64;
        let mut per: BTreeMap<u64, Vec<CheckpointId>> = BTreeMap::new();
        for (id, m) in &self.meta {
            assert_eq!(m.id, *id, "meta id key drift");
            assert!(self.blob_of.contains_key(id), "meta {id} missing blob key");
            logical += m.bytes as u64;
            per.entry(m.trial).or_default().push(*id);
        }
        assert_eq!(logical, self.logical_bytes, "logical byte counter drifted");
        assert_eq!(per, self.per_trial, "per-trial index drifted");
        assert_eq!(per.len(), self.latest.len(), "latest index size drift");
        for (trial, ids) in &per {
            assert_eq!(
                self.latest.get(trial),
                ids.last(),
                "latest[{trial}] != newest live id"
            );
        }
        let mut blob_refs: BTreeMap<ContentHash, u64> = BTreeMap::new();
        for key in self.blob_of.values() {
            *blob_refs.entry(*key).or_default() += 1;
        }
        assert_eq!(
            blob_refs.len(),
            self.blobs.len(),
            "blob entries out of sync with referenced keys"
        );
        let mut assembled = 0usize;
        let mut chunk_refs: BTreeMap<ContentHash, u64> = BTreeMap::new();
        for (key, e) in &self.blobs {
            assert_eq!(
                Some(&e.refs),
                blob_refs.get(key),
                "blob {key} refcount != live ids mapping to it"
            );
            let sum: usize = e.manifest.iter().map(|(_, l)| *l as usize).sum();
            assert_eq!(sum, e.len, "blob {key} manifest lengths != blob length");
            if let Some(a) = &e.assembled {
                assert_eq!(a.len(), e.len, "blob {key} assembled cache length mismatch");
                assembled += e.len;
            }
            for (k, _) in &e.manifest {
                *chunk_refs.entry(*k).or_default() += 1;
            }
        }
        assert_eq!(assembled, self.assembled_bytes, "assembled byte counter drifted");
        let table = self.table.lock().expect("chunk table lock");
        // A table shared with another store legitimately holds chunks
        // (and refs) this store doesn't know about.
        let strict = Arc::strong_count(&self.table) == 1;
        table.debug_check(&chunk_refs, strict, !strict);
        if let Some(budget) = self.mem_budget {
            if table.has_disk() {
                assert!(
                    self.assembled_bytes as u64 + table.resident_bytes() <= budget as u64,
                    "resident {} + {} over budget {budget}",
                    self.assembled_bytes,
                    table.resident_bytes()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn chunk_files(dir: &Path) -> usize {
        std::fs::read_dir(dir.join("chunks")).map(|d| d.count()).unwrap_or(0)
    }

    #[test]
    fn save_get_roundtrip() {
        let mut s = CheckpointStore::new();
        let id = s.save(7, 10, vec![1, 2, 3]);
        assert_eq!(&s.get(id).unwrap()[..], &[1, 2, 3]);
        assert_eq!(s.latest_for(7), Some(id));
        assert_eq!(s.meta(id).unwrap().iteration, 10);
        assert_eq!((s.saved, s.restored), (1, 1));
        s.debug_check_store();
    }

    #[test]
    fn gc_keeps_newest() {
        let mut s = CheckpointStore::new(); // keep_per_trial = 2
        let a = s.save(1, 1, vec![1]);
        let b = s.save(1, 2, vec![2]);
        let c = s.save(1, 3, vec![3]);
        assert!(s.get(a).is_none());
        assert!(s.get(b).is_some());
        assert_eq!(s.latest_for(1), Some(c));
        assert_eq!(s.len(), 2);
        s.debug_check_store();
    }

    #[test]
    fn gc_is_per_trial() {
        let mut s = CheckpointStore::new();
        for t in 0..4 {
            s.save(t, 1, vec![t as u8]);
        }
        assert_eq!(s.len(), 4);
        s.debug_check_store();
    }

    #[test]
    fn exploit_clone_is_a_refcount_bump() {
        let mut s = CheckpointStore::new();
        let blob: Arc<[u8]> = vec![7u8; 50_000].into();
        let a = s.save_timed(1, 10, 1.0, Arc::clone(&blob));
        // The PBT exploit path: hand the donor's handle straight back.
        let donor = s.get(a).unwrap();
        let b = s.save_timed(2, 10, 1.0, donor);
        let st = s.stats();
        assert_eq!(st.blob_dedup_hits, 1);
        assert_eq!(st.logical_bytes, 100_000);
        assert_eq!(st.physical_bytes, 50_000, "clone stored zero new bytes");
        assert!((st.dedup_ratio() - 2.0).abs() < 1e-9);
        // Both ids hand out the same allocation.
        assert!(Arc::ptr_eq(&s.get(a).unwrap(), &s.get(b).unwrap()));
        s.debug_check_store();
        // Dropping one clone keeps the blob; dropping both frees it.
        s.keep_per_trial = 0; // disable GC; drop via delta-removed path
        let d = Json::obj(vec![
            ("added", Json::Arr(vec![])),
            ("removed", Json::Arr(vec![Json::Num(a as f64)])),
        ]);
        s.apply_delta(&d, Path::new("/nonexistent")).unwrap();
        assert_eq!(s.stats().physical_bytes, 50_000);
        assert!(s.get(b).is_some());
        s.debug_check_store();
    }

    #[test]
    fn lineage_checkpoints_share_chunks() {
        // A 100 KiB state with a 1 KiB mutation: the second checkpoint
        // must cost ~the delta, not another 100 KiB.
        let mut s = CheckpointStore::new();
        let mut state = vec![0u8; 100_000];
        for (i, b) in state.iter_mut().enumerate() {
            *b = (i * 31 % 251) as u8;
        }
        s.save(1, 1, state.clone());
        for b in state[40_000..41_000].iter_mut() {
            *b ^= 0xAA;
        }
        s.save(1, 2, state.clone());
        let st = s.stats();
        assert_eq!(st.logical_bytes, 200_000);
        assert!(
            st.physical_bytes < 130_000,
            "near-identical checkpoints stored {} physical bytes",
            st.physical_bytes
        );
        assert!(st.chunk_dedup_hits > 0);
        s.debug_check_store();
    }

    #[test]
    fn budget_evicts_and_faults_back_in() {
        let dir = tmpdir("budget");
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        let blob: Vec<u8> = (0..60_000u32).map(|i| (i % 241) as u8).collect();
        let id = s.save(1, 1, blob.clone());
        s.set_mem_budget(Some(1024));
        assert!(s.stats().resident_bytes <= 1024);
        s.debug_check_store();
        // Reassembly faults chunks back in from the spill tier...
        assert_eq!(&s.get(id).unwrap()[..], &blob[..]);
        assert!(s.stats().chunk_disk_loads > 0);
        // ...and the budget re-applies after the fetch.
        assert!(s.stats().resident_bytes <= 1024);
        s.debug_check_store();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_restore_roundtrip_through_disk() {
        let dir = tmpdir("resume");
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        let a = s.save(1, 5, vec![1, 1]);
        let b = s.save(1, 10, vec![2, 2]);
        let c = s.save(3, 2, vec![3]);
        let snap = s.snapshot();
        let text = snap.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut r = CheckpointStore::restore_from(&parsed, &dir).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(&r.get(a).unwrap()[..], &[1, 1]);
        assert_eq!(&r.get(b).unwrap()[..], &[2, 2]);
        assert_eq!(r.latest_for(1), Some(b));
        assert_eq!(r.latest_for(3), Some(c));
        assert_eq!(r.meta(b).unwrap().iteration, 10);
        // Dedup state survives the roundtrip bit-for-bit.
        assert_eq!(r.stats().physical_bytes, s.stats().physical_bytes);
        r.sweep_orphan_chunks();
        r.debug_check_store();
        // New saves continue the id sequence without collisions.
        let d = r.save(1, 15, vec![4]);
        assert!(d > c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_drops_blobs_with_torn_chunks() {
        let dir = tmpdir("torn");
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        let blob_a: Vec<u8> = vec![9; 8];
        let blob_b: Vec<u8> = vec![8; 8];
        let a = s.save(1, 1, blob_a.clone());
        let b = s.save(2, 1, blob_b.clone());
        let snap = s.snapshot();
        // Truncate a's chunk file, delete b's entirely. The restoring
        // store has nothing resident, so both must fail validation.
        let file_a = dir.join("chunks").join(format!("c{}.bin", chunk::chunk_key(&blob_a)));
        let file_b = dir.join("chunks").join(format!("c{}.bin", chunk::chunk_key(&blob_b)));
        std::fs::write(&file_a, [9; 3]).unwrap();
        std::fs::remove_file(&file_b).unwrap();
        let mut r = CheckpointStore::restore_from(&snap, &dir).unwrap();
        assert!(r.get(a).is_none());
        assert!(r.get(b).is_none());
        assert_eq!(r.latest_for(1), None);
        assert!(r.is_empty(), "both entries degraded");
        // The degraded store is not poisoned: sweeping and saving work.
        r.sweep_orphan_chunks();
        r.debug_check_store();
        let c = r.save(1, 2, vec![5; 8]);
        assert_eq!(&r.get(c).unwrap()[..], &[5; 8]);
        r.debug_check_store();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_whole_blob_snapshot_restores() {
        let dir = tmpdir("legacy");
        // A pre-chunk snapshot: metas without blob keys, whole-blob
        // spill files on disk.
        std::fs::write(dir.join("trial1_iter5_ckpt1.bin"), [1u8, 1]).unwrap();
        std::fs::write(dir.join("trial1_iter9_ckpt2.bin"), [2u8, 2, 2]).unwrap();
        std::fs::write(dir.join("trial2_iter3_ckpt3.bin"), [3u8]).unwrap();
        let text = r#"{"next_id":4,"saved":3,"restored":0,"metas":[
            {"id":1,"trial":1,"iteration":5,"time":5.0,"bytes":2},
            {"id":2,"trial":1,"iteration":9,"time":9.0,"bytes":3},
            {"id":3,"trial":2,"iteration":3,"time":3.0,"bytes":1}]}"#;
        let snap = crate::util::json::parse(text).unwrap();
        let mut r = CheckpointStore::restore_from(&snap, &dir).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(&r.get(1).unwrap()[..], &[1, 1]);
        assert_eq!(&r.get(2).unwrap()[..], &[2, 2, 2]);
        assert_eq!(r.latest_for(1), Some(2));
        assert_eq!(r.meta(2).unwrap().time_total_s, 9.0);
        r.debug_check_store();
        // Legacy files stay on disk after ingest (the old snapshot must
        // remain restorable until a new-format snapshot lands) ...
        assert!(dir.join("trial1_iter5_ckpt1.bin").exists());
        // ... a new snapshot is chunked ...
        let snap2 = r.snapshot();
        let r2 = CheckpointStore::restore_from(&snap2, &dir).unwrap();
        assert_eq!(r2.len(), 3);
        // ... and GC of a legacy id finally deletes its file.
        let _ = r.save(1, 12, vec![7; 2]); // keep=2: evicts legacy id 1
        assert!(!dir.join("trial1_iter5_ckpt1.bin").exists());
        assert!(dir.join("trial1_iter9_ckpt2.bin").exists());
        r.debug_check_store();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_missing_and_truncated_blobs_are_dropped() {
        let dir = tmpdir("legacy_trunc");
        std::fs::write(dir.join("trial1_iter1_ckpt1.bin"), [9u8; 3]).unwrap(); // truncated
        let text = r#"{"next_id":3,"saved":2,"restored":0,"metas":[
            {"id":1,"trial":1,"iteration":1,"time":0.0,"bytes":8},
            {"id":2,"trial":2,"iteration":1,"time":0.0,"bytes":8}]}"#;
        let snap = crate::util::json::parse(text).unwrap();
        let mut r = CheckpointStore::restore_from(&snap, &dir).unwrap();
        assert!(r.get(1).is_none());
        assert!(r.get(2).is_none());
        assert_eq!(r.latest_for(1), None);
        r.debug_check_store();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_frees_chunk_files_only_at_refcount_zero() {
        let dir = tmpdir("gc");
        let mut s = CheckpointStore::new().with_disk(dir.clone()); // keep 2
        for i in 1..=5u64 {
            s.save_timed(1, i, i as f64, vec![i as u8; 8]);
        }
        // Only the 2 newest survive, in memory AND in the chunk tier
        // (each tiny blob is exactly one chunk, all distinct).
        assert_eq!(s.len(), 2);
        assert_eq!(chunk_files(&dir), 2);
        s.debug_check_store();
        // A shared blob's chunk survives until BOTH referents die.
        let shared = vec![42u8; 8];
        s.save(2, 1, shared.clone());
        s.save(3, 1, shared.clone());
        assert_eq!(chunk_files(&dir), 3);
        s.save(2, 2, vec![43u8; 8]);
        s.save(2, 3, vec![44u8; 8]); // evicts trial 2's shared-blob ref
        assert_eq!(chunk_files(&dir), 5, "chunk still pinned by trial 3");
        s.save(3, 2, vec![45u8; 8]);
        s.save(3, 3, vec![46u8; 8]); // evicts the last shared-blob ref
        assert_eq!(chunk_files(&dir), 6, "shared chunk freed at refcount 0");
        s.debug_check_store();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_fold_matches_live_store() {
        let dir = tmpdir("delta");
        let mut live = CheckpointStore::new().with_disk(dir.clone());
        let a = live.save_timed(1, 1, 1.0, vec![1; 4]);
        let base = live.snapshot();
        live.reset_delta_cursor();
        // Window: two more saves for trial 1 -> GC evicts `a` (keep 2),
        // plus one save for trial 2.
        let b = live.save_timed(1, 2, 2.0, vec![2; 4]);
        let c = live.save_timed(1, 3, 3.0, vec![3; 4]);
        let d = live.save_timed(2, 1, 1.0, vec![4; 4]);
        let delta = live.snapshot_delta();
        let mut folded = CheckpointStore::restore_from(&base, &dir).unwrap();
        folded
            .apply_delta(&crate::util::json::parse(&delta.to_string()).unwrap(), &dir)
            .unwrap();
        assert!(folded.get(a).is_none(), "evicted id survived the fold");
        assert_eq!(&folded.get(b).unwrap()[..], &[2; 4]);
        assert_eq!(&folded.get(c).unwrap()[..], &[3; 4]);
        assert_eq!(&folded.get(d).unwrap()[..], &[4; 4]);
        assert_eq!(folded.latest_for(1), Some(c));
        assert_eq!(folded.latest_for(2), Some(d));
        assert_eq!(folded.len(), live.len());
        assert_eq!(folded.stats().physical_bytes, live.stats().physical_bytes);
        folded.sweep_orphan_chunks();
        folded.debug_check_store();
        // New saves continue the id sequence without collisions.
        assert!(folded.save(3, 1, vec![9]) > d);
        // An id born AND evicted inside one window never appears.
        let dir2 = tmpdir("delta_w");
        let mut w = CheckpointStore::new().with_disk(dir2.clone());
        w.keep_per_trial = 1;
        w.reset_delta_cursor();
        let x = w.save(7, 1, vec![1]);
        let _y = w.save(7, 2, vec![2]); // evicts x within the window
        let dj = w.snapshot_delta();
        let added = dj.get("added").unwrap().as_arr().unwrap();
        assert_eq!(added.len(), 1);
        assert_ne!(added[0].get("id").unwrap().as_u64(), Some(x));
        assert_eq!(dj.get("removed").unwrap().as_arr().unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn disk_spill_writes_chunk_files() {
        let dir = tmpdir("spillfiles");
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        s.save(1, 5, vec![9; 16]);
        assert_eq!(chunk_files(&dir), 1);
        // No whole-blob files in the new format — only the chunk tier.
        let top: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(top, vec!["chunks".to_string()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_blob_roundtrips() {
        let dir = tmpdir("empty");
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        let id = s.save(1, 1, Vec::new());
        assert_eq!(s.get(id).unwrap().len(), 0);
        let snap = s.snapshot();
        let mut r = CheckpointStore::restore_from(&snap, &dir).unwrap();
        assert_eq!(r.get(id).unwrap().len(), 0);
        r.debug_check_store();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_chunk_table_dedups_across_stores() {
        let table = chunk::new_shared_table();
        let mut a = CheckpointStore::new().with_chunk_table(Arc::clone(&table));
        let mut b = CheckpointStore::new().with_chunk_table(Arc::clone(&table));
        let blob = vec![5u8; 30_000];
        a.save(1, 1, blob.clone());
        let before = table.lock().unwrap().physical_bytes();
        b.save(1, 1, blob);
        let after = table.lock().unwrap().physical_bytes();
        assert_eq!(before, after, "second store stored zero new chunk bytes");
        a.debug_check_store();
        b.debug_check_store();
    }
}
