//! Checkpoint management. Per the paper (§4.2), Tune keeps trial
//! metadata in memory and relies on checkpoints for fault tolerance;
//! schedulers "save and clone promising parameters (via checkpoint and
//! restore)". Checkpoints are opaque byte blobs produced by
//! `Trainable::save`; the store keeps them in memory (as shared
//! `Arc<[u8]>` handles, so relaunches and PBT exploits clone a
//! refcount, never the bytes) and can optionally spill every write to
//! disk for post-mortem restore — and, since the durability work, for
//! crash-safe experiment resume: the store's metadata is serialized
//! into the experiment snapshot and the blobs are re-read from the
//! spill directory on restart.
//!
//! # Example
//!
//! ```
//! use tune::checkpoint::CheckpointStore;
//!
//! let mut store = CheckpointStore::new(); // keeps the 2 newest per trial
//! let id = store.save(7, 10, vec![1, 2, 3]);
//! assert_eq!(store.get(id).as_deref(), Some(&[1u8, 2, 3][..]));
//! assert_eq!(store.latest_for(7), Some(id));
//! assert_eq!(store.meta(id).unwrap().iteration, 10);
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::util::json::Json;

/// Handle to one stored checkpoint.
pub type CheckpointId = u64;

/// Bookkeeping for one checkpoint.
#[derive(Clone, Debug)]
pub struct CheckpointMeta {
    /// The checkpoint's id.
    pub id: CheckpointId,
    /// Trial that produced it.
    pub trial: u64,
    /// Training iteration at snapshot time.
    pub iteration: u64,
    /// Training seconds the trial had consumed at snapshot time (0.0
    /// when saved via [`CheckpointStore::save`]; the runner uses
    /// [`CheckpointStore::save_timed`] so crash-resume rollback restores
    /// time accounting exactly, not just the iteration count).
    pub time_total_s: f64,
    /// Blob size in bytes.
    pub bytes: usize,
}

/// In-memory checkpoint store with per-trial GC and optional disk spill.
#[derive(Debug, Default)]
pub struct CheckpointStore {
    next_id: CheckpointId,
    data: BTreeMap<CheckpointId, Arc<[u8]>>,
    meta: BTreeMap<CheckpointId, CheckpointMeta>,
    /// Latest checkpoint per trial (what PBT exploit clones).
    latest: BTreeMap<u64, CheckpointId>,
    disk_dir: Option<PathBuf>,
    /// Keep at most this many checkpoints per trial (0 = unbounded).
    pub keep_per_trial: usize,
    /// Checkpoints written so far.
    pub saved: u64,
    /// Successful reads so far.
    pub restored: u64,
    /// Ids saved since the delta cursor was last reset (still live —
    /// a same-window GC eviction removes the id from here instead of
    /// recording a remove).
    delta_added: Vec<CheckpointId>,
    /// Ids GC-evicted since the delta cursor was last reset.
    delta_removed: Vec<CheckpointId>,
}

impl CheckpointStore {
    /// A store keeping the 2 newest checkpoints per trial.
    pub fn new() -> Self {
        CheckpointStore { next_id: 1, keep_per_trial: 2, ..Default::default() }
    }

    /// Also persist every checkpoint under `dir` (for `analyze`/restart).
    pub fn with_disk(mut self, dir: PathBuf) -> Self {
        std::fs::create_dir_all(&dir).ok();
        self.disk_dir = Some(dir);
        self
    }

    /// Store a blob for `trial` at `iteration`; returns its id.
    pub fn save(&mut self, trial: u64, iteration: u64, blob: impl Into<Arc<[u8]>>) -> CheckpointId {
        self.save_timed(trial, iteration, 0.0, blob)
    }

    /// [`CheckpointStore::save`] plus the trial's accumulated training
    /// seconds, so a crash-resume rollback can restore time accounting
    /// exactly alongside the iteration count. Accepts a `Vec<u8>`
    /// (fresh `Trainable::save` output) or an already-shared
    /// `Arc<[u8]>` (PBT exploit clones) — the latter stores without
    /// copying the bytes.
    pub fn save_timed(
        &mut self,
        trial: u64,
        iteration: u64,
        time_total_s: f64,
        blob: impl Into<Arc<[u8]>>,
    ) -> CheckpointId {
        let blob: Arc<[u8]> = blob.into();
        let id = self.next_id;
        self.next_id += 1;
        let meta = CheckpointMeta { id, trial, iteration, time_total_s, bytes: blob.len() };
        if let Some(dir) = &self.disk_dir {
            std::fs::write(dir.join(Self::spill_name(&meta)), &blob[..]).ok();
        }
        self.meta.insert(id, meta);
        self.data.insert(id, blob);
        self.latest.insert(trial, id);
        self.saved += 1;
        self.delta_added.push(id);
        self.gc(trial);
        id
    }

    /// Shared handle to a checkpoint blob (counts as a restore). The
    /// clone is a refcount bump, not a byte copy — launches and PBT
    /// exploits hand the same allocation around.
    pub fn get(&mut self, id: CheckpointId) -> Option<Arc<[u8]>> {
        let found = self.data.get(&id).map(Arc::clone);
        if found.is_some() {
            self.restored += 1;
        }
        found
    }

    /// Metadata of a stored checkpoint.
    pub fn meta(&self, id: CheckpointId) -> Option<&CheckpointMeta> {
        self.meta.get(&id)
    }

    /// Newest checkpoint id for a trial, if any.
    pub fn latest_for(&self, trial: u64) -> Option<CheckpointId> {
        self.latest.get(&trial).copied()
    }

    /// Drop all but the newest `keep_per_trial` checkpoints of `trial`,
    /// including their spill files — otherwise a long durable run grows
    /// `checkpoints/` without bound. (Snapshots only ever reference
    /// still-live metadata, so deleting evicted files never breaks
    /// resume.)
    fn gc(&mut self, trial: u64) {
        if self.keep_per_trial == 0 {
            return;
        }
        let mut ids: Vec<CheckpointId> = self
            .meta
            .values()
            .filter(|m| m.trial == trial)
            .map(|m| m.id)
            .collect();
        ids.sort();
        while ids.len() > self.keep_per_trial {
            let old = ids.remove(0);
            self.data.remove(&old);
            if let Some(meta) = self.meta.remove(&old) {
                if let Some(dir) = &self.disk_dir {
                    std::fs::remove_file(dir.join(Self::spill_name(&meta))).ok();
                }
            }
            // Delta bookkeeping: an id born and evicted inside the same
            // delta window never reaches disk state — drop it from the
            // add list instead of journaling a remove.
            if let Some(pos) = self.delta_added.iter().position(|a| *a == old) {
                self.delta_added.swap_remove(pos);
            } else {
                self.delta_removed.push(old);
            }
        }
    }

    /// File name a checkpoint spills to (stable across restarts).
    fn spill_name(meta: &CheckpointMeta) -> String {
        format!("trial{}_iter{}_ckpt{}.bin", meta.trial, meta.iteration, meta.id)
    }

    /// Serialize the store's metadata for the experiment snapshot. Blobs
    /// are not embedded — they already live in the spill directory.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("next_id", Json::Num(self.next_id as f64)),
            ("saved", Json::Num(self.saved as f64)),
            ("restored", Json::Num(self.restored as f64)),
            (
                "metas",
                Json::Arr(
                    self.meta
                        .values()
                        .map(|m| {
                            Json::obj(vec![
                                ("id", Json::Num(m.id as f64)),
                                ("trial", Json::Num(m.trial as f64)),
                                ("iteration", Json::Num(m.iteration as f64)),
                                ("time", Json::Num(m.time_total_s)),
                                ("bytes", Json::Num(m.bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild a store from a [`CheckpointStore::snapshot`] manifest,
    /// reading the blobs back from the spill directory `dir`. Metadata
    /// entries whose blob file is missing or truncated are dropped
    /// (callers fall back to restart-from-scratch for those trials).
    /// The rebuilt store keeps spilling to `dir`.
    pub fn restore_from(snap: &Json, dir: &Path) -> Result<Self, String> {
        let mut store = CheckpointStore::new().with_disk(dir.to_path_buf());
        store.next_id = snap
            .get("next_id")
            .and_then(|v| v.as_u64())
            .ok_or("checkpoint snapshot: missing next_id")?;
        store.saved = snap.get("saved").and_then(|v| v.as_u64()).unwrap_or(0);
        store.restored = snap.get("restored").and_then(|v| v.as_u64()).unwrap_or(0);
        let metas = snap
            .get("metas")
            .and_then(|m| m.as_arr())
            .ok_or("checkpoint snapshot: missing metas")?;
        for m in metas {
            let (Some(id), Some(trial), Some(iteration), Some(bytes)) = (
                m.get("id").and_then(|v| v.as_u64()),
                m.get("trial").and_then(|v| v.as_u64()),
                m.get("iteration").and_then(|v| v.as_u64()),
                m.get("bytes").and_then(|v| v.as_u64()),
            ) else {
                return Err("checkpoint snapshot: malformed meta entry".into());
            };
            let time_total_s = m.get("time").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let meta =
                CheckpointMeta { id, trial, iteration, time_total_s, bytes: bytes as usize };
            let Ok(blob) = std::fs::read(dir.join(Self::spill_name(&meta))) else {
                continue; // spill file lost: drop the entry
            };
            if blob.len() != meta.bytes {
                continue; // truncated write: drop the entry
            }
            // `latest` is the max id per trial by construction (ids are
            // monotone), so it rebuilds incrementally here.
            if store.latest.get(&trial).map_or(true, |l| *l < id) {
                store.latest.insert(trial, id);
            }
            store.data.insert(id, blob.into());
            store.meta.insert(id, meta);
        }
        Ok(store)
    }

    /// Incremental snapshot: metadata added/removed since the last
    /// [`CheckpointStore::snapshot`]/delta, for the runner's delta
    /// records. Blobs are never embedded — additions re-read from the
    /// spill directory on fold, exactly like a full restore.
    pub fn snapshot_delta(&mut self) -> Json {
        let added = self
            .delta_added
            .iter()
            .filter_map(|id| self.meta.get(id))
            .map(|m| {
                Json::obj(vec![
                    ("id", Json::Num(m.id as f64)),
                    ("trial", Json::Num(m.trial as f64)),
                    ("iteration", Json::Num(m.iteration as f64)),
                    ("time", Json::Num(m.time_total_s)),
                    ("bytes", Json::Num(m.bytes as f64)),
                ])
            })
            .collect();
        let removed = self.delta_removed.iter().map(|id| Json::Num(*id as f64)).collect();
        self.delta_added.clear();
        self.delta_removed.clear();
        Json::obj(vec![
            ("next_id", Json::Num(self.next_id as f64)),
            ("saved", Json::Num(self.saved as f64)),
            ("restored", Json::Num(self.restored as f64)),
            ("added", Json::Arr(added)),
            ("removed", Json::Arr(removed)),
        ])
    }

    /// Fold a [`CheckpointStore::snapshot_delta`] record into this
    /// store, reading added blobs back from the spill directory `dir`.
    /// Additions whose spill file is missing/truncated are dropped, the
    /// same degradation contract as [`CheckpointStore::restore_from`].
    pub fn apply_delta(&mut self, delta: &Json, dir: &Path) -> Result<(), String> {
        if let Some(n) = delta.get("next_id").and_then(|v| v.as_u64()) {
            self.next_id = n;
        }
        if let Some(n) = delta.get("saved").and_then(|v| v.as_u64()) {
            self.saved = n;
        }
        if let Some(n) = delta.get("restored").and_then(|v| v.as_u64()) {
            self.restored = n;
        }
        for m in delta
            .get("added")
            .and_then(|a| a.as_arr())
            .ok_or("checkpoint delta: missing added")?
        {
            let (Some(id), Some(trial), Some(iteration), Some(bytes)) = (
                m.get("id").and_then(|v| v.as_u64()),
                m.get("trial").and_then(|v| v.as_u64()),
                m.get("iteration").and_then(|v| v.as_u64()),
                m.get("bytes").and_then(|v| v.as_u64()),
            ) else {
                return Err("checkpoint delta: malformed added entry".into());
            };
            let time_total_s = m.get("time").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let meta =
                CheckpointMeta { id, trial, iteration, time_total_s, bytes: bytes as usize };
            let Ok(blob) = std::fs::read(dir.join(Self::spill_name(&meta))) else {
                continue; // spill file lost: drop the entry
            };
            if blob.len() != meta.bytes {
                continue; // truncated write: drop the entry
            }
            if self.latest.get(&trial).map_or(true, |l| *l < id) {
                self.latest.insert(trial, id);
            }
            self.data.insert(id, blob.into());
            self.meta.insert(id, meta);
        }
        for id in delta
            .get("removed")
            .and_then(|r| r.as_arr())
            .ok_or("checkpoint delta: missing removed")?
        {
            let id = id.as_u64().ok_or("checkpoint delta: bad removed id")?;
            self.data.remove(&id);
            if let Some(meta) = self.meta.remove(&id) {
                // GC only ever evicts non-latest ids, but stay robust:
                // recompute this trial's latest if it was removed.
                if self.latest.get(&meta.trial) == Some(&id) {
                    let new_latest = self
                        .meta
                        .values()
                        .filter(|m| m.trial == meta.trial)
                        .map(|m| m.id)
                        .max();
                    match new_latest {
                        Some(l) => self.latest.insert(meta.trial, l),
                        None => self.latest.remove(&meta.trial),
                    };
                }
            }
        }
        Ok(())
    }

    /// A full snapshot was just persisted; forget the journals.
    pub fn reset_delta_cursor(&mut self) {
        self.delta_added.clear();
        self.delta_removed.clear();
    }

    /// Number of checkpoints currently stored.
    pub fn len(&self) -> usize {
        self.data.len()
    }
    /// True when no checkpoints are stored.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    /// Total stored bytes across checkpoints.
    pub fn total_bytes(&self) -> usize {
        self.data.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_get_roundtrip() {
        let mut s = CheckpointStore::new();
        let id = s.save(7, 10, vec![1, 2, 3]);
        assert_eq!(&s.get(id).unwrap()[..], &[1, 2, 3]);
        assert_eq!(s.latest_for(7), Some(id));
        assert_eq!(s.meta(id).unwrap().iteration, 10);
        assert_eq!((s.saved, s.restored), (1, 1));
    }

    #[test]
    fn gc_keeps_newest() {
        let mut s = CheckpointStore::new(); // keep_per_trial = 2
        let a = s.save(1, 1, vec![1]);
        let b = s.save(1, 2, vec![2]);
        let c = s.save(1, 3, vec![3]);
        assert!(s.get(a).is_none());
        assert!(s.get(b).is_some());
        assert_eq!(s.latest_for(1), Some(c));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn gc_is_per_trial() {
        let mut s = CheckpointStore::new();
        for t in 0..4 {
            s.save(t, 1, vec![t as u8]);
        }
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn snapshot_restore_roundtrip_through_disk() {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_resume_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        let a = s.save(1, 5, vec![1, 1]);
        let b = s.save(1, 10, vec![2, 2]);
        let c = s.save(3, 2, vec![3]);
        let snap = s.snapshot();
        let text = snap.to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let mut r = CheckpointStore::restore_from(&parsed, &dir).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(&r.get(a).unwrap()[..], &[1, 1]);
        assert_eq!(&r.get(b).unwrap()[..], &[2, 2]);
        assert_eq!(r.latest_for(1), Some(b));
        assert_eq!(r.latest_for(3), Some(c));
        assert_eq!(r.meta(b).unwrap().iteration, 10);
        // New saves continue the id sequence without collisions.
        let d = r.save(1, 15, vec![4]);
        assert!(d > c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_drops_missing_and_truncated_blobs() {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_trunc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        let a = s.save(1, 1, vec![9; 8]);
        let b = s.save(2, 1, vec![8; 8]);
        let snap = s.snapshot();
        // Corrupt trial 1's file, delete trial 2's entirely.
        std::fs::write(dir.join("trial1_iter1_ckpt1.bin"), [9; 3]).unwrap();
        std::fs::remove_file(dir.join("trial2_iter1_ckpt2.bin")).unwrap();
        let mut r = CheckpointStore::restore_from(&snap, &dir).unwrap();
        assert!(r.get(a).is_none());
        assert!(r.get(b).is_none());
        assert_eq!(r.latest_for(1), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_also_deletes_spill_files() {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_gc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut s = CheckpointStore::new().with_disk(dir.clone()); // keep 2
        for i in 1..=5u64 {
            s.save_timed(1, i, i as f64, vec![i as u8]);
        }
        // Only the 2 newest survive, in memory AND on disk.
        assert_eq!(s.len(), 2);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_fold_matches_live_store() {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_delta_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut live = CheckpointStore::new().with_disk(dir.clone());
        let a = live.save_timed(1, 1, 1.0, vec![1; 4]);
        let base = live.snapshot();
        live.reset_delta_cursor();
        // Window: two more saves for trial 1 -> GC evicts `a` (keep 2),
        // plus one save for trial 2.
        let b = live.save_timed(1, 2, 2.0, vec![2; 4]);
        let c = live.save_timed(1, 3, 3.0, vec![3; 4]);
        let d = live.save_timed(2, 1, 1.0, vec![4; 4]);
        let delta = live.snapshot_delta();
        let mut folded = CheckpointStore::restore_from(&base, &dir).unwrap();
        folded
            .apply_delta(&crate::util::json::parse(&delta.to_string()).unwrap(), &dir)
            .unwrap();
        assert!(folded.get(a).is_none(), "evicted id survived the fold");
        assert_eq!(&folded.get(b).unwrap()[..], &[2; 4]);
        assert_eq!(&folded.get(c).unwrap()[..], &[3; 4]);
        assert_eq!(&folded.get(d).unwrap()[..], &[4; 4]);
        assert_eq!(folded.latest_for(1), Some(c));
        assert_eq!(folded.latest_for(2), Some(d));
        assert_eq!(folded.len(), live.len());
        // New saves continue the id sequence without collisions.
        assert!(folded.save(3, 1, vec![9]) > d);
        // An id born AND evicted inside one window never appears.
        let mut w = CheckpointStore::new().with_disk(dir.clone());
        w.keep_per_trial = 1;
        w.reset_delta_cursor();
        let x = w.save(7, 1, vec![1]);
        let _y = w.save(7, 2, vec![2]); // evicts x within the window
        let dj = w.snapshot_delta();
        let added = dj.get("added").unwrap().as_arr().unwrap();
        assert_eq!(added.len(), 1);
        assert_ne!(added[0].get("id").unwrap().as_u64(), Some(x));
        assert_eq!(dj.get("removed").unwrap().as_arr().unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disk_spill_writes_files() {
        let dir = std::env::temp_dir().join(format!("tune_ckpt_test_{}", std::process::id()));
        let mut s = CheckpointStore::new().with_disk(dir.clone());
        s.save(1, 5, vec![9; 16]);
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(n, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
