//! Content-addressed chunk layer beneath [`CheckpointStore`].
//!
//! Checkpoint blobs are split into **content-defined chunks** with a
//! gear-hash rolling boundary (cut points follow the *content*, so an
//! insertion near the front of a blob shifts at most the chunks it
//! touches — consecutive lineage checkpoints and PBT exploit clones
//! share almost all their chunks). Each chunk is keyed by a 128-bit
//! content hash and refcounted: storing the same bytes twice bumps a
//! counter instead of copying, and per-trial GC only physically frees a
//! chunk when its refcount reaches zero.
//!
//! The table is **tiered**: with a disk directory attached, every chunk
//! is eagerly spilled to `chunks/c<32-hex>.bin` with the same atomic
//! write + fsync discipline `persist.rs` uses (so a crash never leaves a
//! torn chunk behind a completed save), and under a memory budget the
//! in-memory payloads of cold chunks are dropped — `get` faults them
//! back in from disk, verifying length *and* content hash so a torn or
//! truncated file degrades to "chunk missing" instead of serving
//! corrupt bytes.
//!
//! Indices and refcounts are never persisted; restore recomputes them
//! from the blob manifests in the snapshot (the same rebuild-don't-trust
//! discipline as the runner's `rebuild_indexes`).
//!
//! [`CheckpointStore`]: super::CheckpointStore

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::coordinator::persist::write_atomic_bytes;

/// A 128-bit content hash — wide enough that random collisions are out
/// of reach for any realistic checkpoint population (2^64 chunks for a
/// birthday collision).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ContentHash {
    /// High 64 bits.
    pub hi: u64,
    /// Low 64 bits.
    pub lo: u64,
}

impl ContentHash {
    /// Render as 32 lowercase hex digits (the on-disk chunk file stem
    /// and the snapshot wire format).
    pub fn to_hex(self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }

    /// Parse the 32-hex-digit form; `None` on any malformed input.
    pub fn from_hex(s: &str) -> Option<ContentHash> {
        if s.len() != 32 || !s.is_ascii() {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(ContentHash { hi, lo })
    }
}

impl fmt::Display for ContentHash {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

/// Domain-separation seed for whole-blob keys.
pub const BLOB_SEED: u64 = 0xB10B;
/// Domain-separation seed for chunk keys.
pub const CHUNK_SEED: u64 = 0xC4A2;

const C1: u64 = 0x87C3_7B91_1142_53D5;
const C2: u64 = 0x4CF5_AD43_2745_937F;

#[inline]
fn fmix64(mut k: u64) -> u64 {
    k ^= k >> 33;
    k = k.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    k ^= k >> 33;
    k = k.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    k ^= k >> 33;
    k
}

/// 128-bit content hash (MurmurHash3-x64-128 style mixing) of `data`
/// under a domain-separation `seed`. Not cryptographic — the threat
/// model is accidental collision, not an adversary forging checkpoints.
pub fn content_hash(data: &[u8], seed: u64) -> ContentHash {
    let mut h1 = seed;
    let mut h2 = seed;
    let mut chunks = data.chunks_exact(16);
    for block in &mut chunks {
        let mut k1 = u64::from_le_bytes(block[..8].try_into().expect("8-byte block"));
        let mut k2 = u64::from_le_bytes(block[8..].try_into().expect("8-byte block"));
        k1 = k1.wrapping_mul(C1).rotate_left(31).wrapping_mul(C2);
        h1 = (h1 ^ k1).rotate_left(27).wrapping_add(h2).wrapping_mul(5).wrapping_add(0x52DC_E729);
        k2 = k2.wrapping_mul(C2).rotate_left(33).wrapping_mul(C1);
        h2 = (h2 ^ k2).rotate_left(31).wrapping_add(h1).wrapping_mul(5).wrapping_add(0x3849_5AB5);
    }
    let tail = chunks.remainder();
    if !tail.is_empty() {
        let mut buf = [0u8; 16];
        buf[..tail.len()].copy_from_slice(tail);
        let mut k1 = u64::from_le_bytes(buf[..8].try_into().expect("8-byte block"));
        let mut k2 = u64::from_le_bytes(buf[8..].try_into().expect("8-byte block"));
        k1 = k1.wrapping_mul(C1).rotate_left(31).wrapping_mul(C2);
        h1 ^= k1;
        k2 = k2.wrapping_mul(C2).rotate_left(33).wrapping_mul(C1);
        h2 ^= k2;
    }
    h1 ^= data.len() as u64;
    h2 ^= data.len() as u64;
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    h1 = fmix64(h1);
    h2 = fmix64(h2);
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    ContentHash { hi: h1, lo: h2 }
}

/// Whole-blob identity key — the fast path: two saves of identical
/// bytes (a PBT exploit clone) collapse to a refcount bump with no
/// chunking work at all.
pub fn blob_key(data: &[u8]) -> ContentHash {
    content_hash(data, BLOB_SEED)
}

/// Per-chunk content key.
pub fn chunk_key(data: &[u8]) -> ContentHash {
    content_hash(data, CHUNK_SEED)
}

const fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Gear table for the rolling boundary hash: one random-looking 64-bit
/// word per byte value, generated deterministically at compile time.
const GEAR: [u64; 256] = {
    let mut t = [0u64; 256];
    let mut i = 0;
    while i < 256 {
        t[i] = splitmix64(i as u64 ^ 0x6EA2_D15C_31FB_770Cu64);
        i += 1;
    }
    t
};

/// Content-defined chunking parameters. The gear hash `h = (h << 1) +
/// GEAR[byte]` carries an intrinsic 64-byte window (older bytes shift
/// out the top); a boundary is declared when the low `mask` bits are
/// zero, giving an expected chunk size of `mask + 1` bytes past `min`.
#[derive(Clone, Copy, Debug)]
pub struct ChunkParams {
    /// No boundary before this many bytes (also caps tiny-chunk
    /// metadata overhead).
    pub min: usize,
    /// Boundary condition `h & mask == 0`; expected spacing `mask + 1`.
    pub mask: u64,
    /// Forced boundary at this size regardless of content.
    pub max: usize,
}

impl Default for ChunkParams {
    fn default() -> Self {
        // avg ~8 KiB chunks: small enough that a few-KiB mutation in a
        // large checkpoint dirties ~1-2 chunks, big enough that manifest
        // overhead stays ~0.4% of blob size.
        ChunkParams { min: 2048, mask: 0x1FFF, max: 65536 }
    }
}

/// Split `data` into content-defined spans under `params`. The spans
/// concatenate back to exactly `data`; every span except possibly the
/// last is in `[min, max]`.
pub fn chunk_spans(data: &[u8], params: ChunkParams) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut start = 0;
    while start < data.len() {
        let remain = data.len() - start;
        if remain <= params.min {
            spans.push((start, data.len()));
            break;
        }
        let limit = remain.min(params.max);
        let mut h: u64 = 0;
        let mut cut = limit;
        // The first `min` bytes still feed the rolling hash so the
        // boundary decision at `min` has full window context.
        for (i, &b) in data[start..start + limit].iter().enumerate() {
            h = (h << 1).wrapping_add(GEAR[b as usize]);
            if i + 1 >= params.min && h & params.mask == 0 {
                cut = i + 1;
                break;
            }
        }
        spans.push((start, start + cut));
        start += cut;
    }
    spans
}

/// One refcounted chunk.
#[derive(Debug)]
struct ChunkEntry {
    /// Live references: one per occurrence in a live blob manifest.
    refs: u64,
    /// Payload length in bytes.
    len: u32,
    /// Resident payload; `None` when evicted to the disk tier.
    data: Option<Arc<[u8]>>,
    /// Whether `chunks/c<hex>.bin` holds a durable copy.
    on_disk: bool,
    /// LRU clock for eviction ordering.
    last_use: u64,
}

/// Counters the store surfaces in results and benches. Copy-cheap.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChunkTableStats {
    /// Distinct chunks currently live.
    pub unique_chunks: u64,
    /// Sum of live chunk payload lengths (deduped physical bytes).
    pub physical_bytes: u64,
    /// Bytes of chunk payloads currently resident in memory.
    pub resident_bytes: u64,
    /// `intern` calls that hit an existing chunk (deduped).
    pub dedup_hits: u64,
    /// Chunks spilled to the disk tier over the table's lifetime.
    pub spilled: u64,
    /// Evicted chunks faulted back in from disk.
    pub disk_loads: u64,
}

/// The refcounted, tiered chunk table. Shared (behind
/// [`SharedChunkTable`]) between the checkpoint store and the plasma
/// object store so cross-layer duplicates are stored once.
#[derive(Debug, Default)]
pub struct ChunkTable {
    chunks: BTreeMap<ContentHash, ChunkEntry>,
    disk_dir: Option<PathBuf>,
    params: ChunkParams,
    tick: u64,
    resident_bytes: u64,
    physical_bytes: u64,
    dedup_hits: u64,
    spilled: u64,
    disk_loads: u64,
}

/// Shared handle: the coordinator is single-threaded, the mutex exists
/// only so the handle is `Send + Sync` across executor boundaries.
pub type SharedChunkTable = Arc<Mutex<ChunkTable>>;

/// A fresh, unshared table handle.
pub fn new_shared_table() -> SharedChunkTable {
    Arc::new(Mutex::new(ChunkTable::default()))
}

impl ChunkTable {
    /// Chunking parameters (stable across save/restore so restored
    /// blobs re-chunk identically).
    pub fn params(&self) -> ChunkParams {
        self.params
    }

    fn file_for(&self, key: ContentHash) -> Option<PathBuf> {
        self.disk_dir.as_ref().map(|d| d.join(format!("c{key}.bin")))
    }

    /// Attach the disk tier. Creates the directory and eagerly spills
    /// every chunk that predates it, so durability is uniform from here
    /// on.
    pub fn set_disk_dir(&mut self, dir: PathBuf) {
        std::fs::create_dir_all(&dir).ok();
        self.disk_dir = Some(dir);
        let keys: Vec<ContentHash> = self
            .chunks
            .iter()
            .filter(|(_, e)| !e.on_disk)
            .map(|(k, _)| *k)
            .collect();
        for key in keys {
            self.spill(key);
        }
    }

    /// Whether a disk tier is attached.
    pub fn has_disk(&self) -> bool {
        self.disk_dir.is_some()
    }

    fn spill(&mut self, key: ContentHash) {
        let Some(path) = self.file_for(key) else { return };
        let Some(e) = self.chunks.get_mut(&key) else { return };
        if e.on_disk {
            return;
        }
        let Some(data) = &e.data else { return };
        if write_atomic_bytes(&path, data).is_ok() {
            e.on_disk = true;
            self.spilled += 1;
        }
    }

    /// Intern one chunk's bytes: bump the refcount if the content is
    /// already stored, otherwise insert (and spill if a disk tier is
    /// attached). Returns the chunk's content key.
    pub fn intern(&mut self, data: &[u8]) -> ContentHash {
        let key = chunk_key(data);
        self.tick += 1;
        if let Some(e) = self.chunks.get_mut(&key) {
            debug_assert_eq!(e.len as usize, data.len(), "content hash collision");
            e.refs += 1;
            e.last_use = self.tick;
            self.dedup_hits += 1;
            return key;
        }
        let entry = ChunkEntry {
            refs: 1,
            len: data.len() as u32,
            data: Some(Arc::from(data)),
            on_disk: false,
            last_use: self.tick,
        };
        self.resident_bytes += data.len() as u64;
        self.physical_bytes += data.len() as u64;
        self.chunks.insert(key, entry);
        self.spill(key);
        key
    }

    /// Drop one reference; at zero the chunk is physically freed —
    /// memory and chunk file both.
    pub fn release(&mut self, key: ContentHash) {
        let Some(e) = self.chunks.get_mut(&key) else { return };
        e.refs = e.refs.saturating_sub(1);
        if e.refs > 0 {
            return;
        }
        let e = self.chunks.remove(&key).expect("entry just seen");
        if e.data.is_some() {
            self.resident_bytes -= u64::from(e.len);
        }
        self.physical_bytes -= u64::from(e.len);
        if e.on_disk {
            if let Some(path) = self.file_for(key) {
                std::fs::remove_file(path).ok();
            }
        }
    }

    /// Fetch a chunk's bytes, faulting in from the disk tier if it was
    /// evicted. A torn/truncated/corrupt chunk file fails the length or
    /// rehash check and yields `None` — the caller degrades that one
    /// blob instead of serving bad bytes.
    pub fn get(&mut self, key: ContentHash) -> Option<Arc<[u8]>> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.chunks.get_mut(&key)?;
        e.last_use = tick;
        if let Some(d) = &e.data {
            return Some(Arc::clone(d));
        }
        let len = e.len;
        let path = self.file_for(key)?;
        let bytes = std::fs::read(path).ok()?;
        if bytes.len() != len as usize || chunk_key(&bytes) != key {
            return None;
        }
        let arc: Arc<[u8]> = bytes.into();
        let e = self.chunks.get_mut(&key).expect("entry just seen");
        e.data = Some(Arc::clone(&arc));
        self.resident_bytes += u64::from(len);
        self.disk_loads += 1;
        Some(arc)
    }

    /// Make sure `key` is servable for a manifest being restored:
    /// either resident with the right length, or loadable+verifiable
    /// from disk. Inserts a refcount-0 placeholder for disk chunks —
    /// the caller commits references with [`Self::commit_ref`] only
    /// once the *whole* manifest validates, and sweeps refcount-0
    /// leftovers with [`Self::drop_unreferenced`] afterwards.
    pub fn ensure_loadable(&mut self, key: ContentHash, len: usize) -> bool {
        if let Some(e) = self.chunks.get(&key) {
            return e.len as usize == len;
        }
        let Some(path) = self.file_for(key) else { return false };
        let Ok(bytes) = std::fs::read(path) else { return false };
        if bytes.len() != len || chunk_key(&bytes) != key {
            return false;
        }
        self.tick += 1;
        let entry = ChunkEntry {
            refs: 0,
            len: len as u32,
            data: Some(bytes.into()),
            on_disk: true,
            last_use: self.tick,
        };
        self.resident_bytes += len as u64;
        self.physical_bytes += len as u64;
        self.disk_loads += 1;
        self.chunks.insert(key, entry);
        true
    }

    /// Add one reference to an already-materialized chunk (restore's
    /// commit phase).
    pub fn commit_ref(&mut self, key: ContentHash) {
        let e = self.chunks.get_mut(&key).expect("commit_ref on validated chunk");
        e.refs += 1;
    }

    /// Drop refcount-0 placeholders left by failed manifest validation
    /// — from memory only; their files stay for [`Self::sweep_orphans`]
    /// to judge after all deltas have folded.
    pub fn drop_unreferenced(&mut self) {
        let dead: Vec<ContentHash> =
            self.chunks.iter().filter(|(_, e)| e.refs == 0).map(|(k, _)| *k).collect();
        for key in dead {
            let e = self.chunks.remove(&key).expect("entry just seen");
            if e.data.is_some() {
                self.resident_bytes -= u64::from(e.len);
            }
            self.physical_bytes -= u64::from(e.len);
        }
    }

    /// Delete chunk files on disk that no live chunk entry claims.
    /// Must run only *after* every delta has folded into a restore —
    /// earlier, a file may belong to a chunk only a later delta
    /// references. Returns the number of files removed.
    pub fn sweep_orphans(&mut self) -> usize {
        let Some(dir) = self.disk_dir.clone() else { return 0 };
        let Ok(entries) = std::fs::read_dir(&dir) else { return 0 };
        let mut removed = 0;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(hex) = name.strip_prefix('c').and_then(|n| n.strip_suffix(".bin")) else {
                continue;
            };
            let Some(key) = ContentHash::from_hex(hex) else { continue };
            if !self.chunks.contains_key(&key) && std::fs::remove_file(entry.path()).is_ok() {
                removed += 1;
            }
        }
        removed
    }

    /// Evict resident payloads (coldest first) until resident bytes fit
    /// `budget`. Only chunks with a durable disk copy are evictable;
    /// without a disk tier this is a no-op for safety.
    pub fn evict_to(&mut self, budget: u64) {
        if self.resident_bytes <= budget {
            return;
        }
        let mut victims: Vec<(u64, ContentHash, u32)> = self
            .chunks
            .iter()
            .filter(|(_, e)| e.data.is_some() && e.on_disk)
            .map(|(k, e)| (e.last_use, *k, e.len))
            .collect();
        victims.sort_unstable();
        for (_, key, len) in victims {
            if self.resident_bytes <= budget {
                break;
            }
            let e = self.chunks.get_mut(&key).expect("entry just seen");
            e.data = None;
            self.resident_bytes -= u64::from(len);
        }
    }

    /// Current counters.
    pub fn stats(&self) -> ChunkTableStats {
        ChunkTableStats {
            unique_chunks: self.chunks.len() as u64,
            physical_bytes: self.physical_bytes,
            resident_bytes: self.resident_bytes,
            dedup_hits: self.dedup_hits,
            spilled: self.spilled,
            disk_loads: self.disk_loads,
        }
    }

    /// Resident payload bytes (the part a memory budget constrains).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Deduped physical bytes across all live chunks.
    pub fn physical_bytes(&self) -> u64 {
        self.physical_bytes
    }

    /// Number of distinct live chunks.
    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    /// True when no chunks are live.
    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    /// Full-scan verification of the incremental state (the PR 6
    /// `debug_check_indices` discipline at the chunk layer):
    /// per-entry sanity, recomputed byte counters, refcounts against
    /// `expected` occurrence counts, and the disk tier (every `on_disk`
    /// entry's file exists with the right length; no orphan chunk files
    /// unless `allow_orphans`). With `strict`, refcounts must *equal*
    /// the expected counts (sole-owner table); a table shared across
    /// stores only checks `>=`.
    ///
    /// Panics (via `assert`) on any violation.
    #[doc(hidden)]
    pub fn debug_check(
        &self,
        expected: &BTreeMap<ContentHash, u64>,
        strict: bool,
        allow_orphans: bool,
    ) {
        let mut resident = 0u64;
        let mut physical = 0u64;
        for (key, e) in &self.chunks {
            assert!(e.refs > 0, "chunk {key} live with refcount 0");
            if let Some(d) = &e.data {
                assert_eq!(d.len(), e.len as usize, "chunk {key} resident length mismatch");
                resident += u64::from(e.len);
            } else {
                assert!(e.on_disk, "chunk {key} neither resident nor on disk");
            }
            physical += u64::from(e.len);
            if e.on_disk {
                let path = self.file_for(*key).expect("on_disk implies disk_dir");
                let meta = std::fs::metadata(&path)
                    .unwrap_or_else(|_| panic!("chunk file missing for on-disk chunk {key}"));
                assert_eq!(meta.len(), u64::from(e.len), "chunk file length mismatch for {key}");
            }
            let want = expected.get(key).copied().unwrap_or(0);
            if strict {
                assert_eq!(e.refs, want, "chunk {key} refcount {} != expected {want}", e.refs);
            } else {
                assert!(e.refs >= want, "chunk {key} refcount {} < expected {want}", e.refs);
            }
        }
        assert_eq!(resident, self.resident_bytes, "resident byte counter drifted");
        assert_eq!(physical, self.physical_bytes, "physical byte counter drifted");
        for (key, want) in expected {
            if *want > 0 {
                assert!(self.chunks.contains_key(key), "expected chunk {key} not in table");
            }
        }
        if let (Some(dir), false) = (&self.disk_dir, allow_orphans) {
            if let Ok(entries) = std::fs::read_dir(dir) {
                for entry in entries.flatten() {
                    let name = entry.file_name();
                    let name = name.to_string_lossy();
                    let Some(hex) = name.strip_prefix('c').and_then(|n| n.strip_suffix(".bin"))
                    else {
                        continue;
                    };
                    if let Some(key) = ContentHash::from_hex(hex) {
                        assert!(
                            self.chunks.contains_key(&key),
                            "orphan chunk file on disk: {name}"
                        );
                    }
                }
            }
        }
    }
}

/// Chunk `data` and intern every span, returning the blob's manifest:
/// `(chunk key, span length)` in order. Concatenating the chunks in
/// manifest order reproduces `data` exactly.
pub fn intern_manifest(table: &mut ChunkTable, data: &[u8]) -> Vec<(ContentHash, u32)> {
    let spans = chunk_spans(data, table.params());
    let mut manifest = Vec::with_capacity(spans.len());
    for (a, b) in spans {
        let key = table.intern(&data[a..b]);
        manifest.push((key, (b - a) as u32));
    }
    manifest
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tune_chunk_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Deterministic pseudo-random bytes without pulling in the util rng.
    fn noise(len: usize, seed: u64) -> Vec<u8> {
        let mut x = seed;
        (0..len)
            .map(|_| {
                x = splitmix64(x);
                (x & 0xFF) as u8
            })
            .collect()
    }

    #[test]
    fn content_hash_is_stable_and_seed_separated() {
        let a = content_hash(b"hello world", 1);
        assert_eq!(a, content_hash(b"hello world", 1));
        assert_ne!(a, content_hash(b"hello world", 2));
        assert_ne!(a, content_hash(b"hello worle", 1));
        assert_ne!(blob_key(b"x"), chunk_key(b"x"));
        // Length is mixed in: a zero-padded prefix is not the same hash.
        assert_ne!(content_hash(&[0u8; 8], 1), content_hash(&[0u8; 16], 1));
    }

    #[test]
    fn hex_roundtrip() {
        let k = content_hash(b"roundtrip", 7);
        assert_eq!(ContentHash::from_hex(&k.to_hex()), Some(k));
        assert_eq!(ContentHash::from_hex("nope"), None);
        assert_eq!(ContentHash::from_hex(&"g".repeat(32)), None);
    }

    #[test]
    fn spans_concatenate_to_input_and_respect_bounds() {
        let params = ChunkParams::default();
        for (len, seed) in [(0usize, 1u64), (1, 2), (2047, 3), (2048, 4), (100_000, 5), (300_000, 6)]
        {
            let data = noise(len, seed);
            let spans = chunk_spans(&data, params);
            let mut rebuilt = Vec::new();
            for (i, &(a, b)) in spans.iter().enumerate() {
                rebuilt.extend_from_slice(&data[a..b]);
                let n = b - a;
                assert!(n <= params.max, "span {n} over max");
                if i + 1 < spans.len() {
                    assert!(n >= params.min, "non-final span {n} under min");
                }
            }
            assert_eq!(rebuilt, data, "len {len}");
            if len == 0 {
                assert!(spans.is_empty());
            }
        }
    }

    #[test]
    fn chunking_is_shift_resistant() {
        // Insert 100 bytes near the front of a 200 KiB blob: most chunk
        // keys must survive (a fixed-stride chunker would lose ~all).
        let base = noise(200_000, 42);
        let mut shifted = base.clone();
        for (i, b) in noise(100, 43).into_iter().enumerate() {
            shifted.insert(5000 + i, b);
        }
        let params = ChunkParams::default();
        let keys = |d: &[u8]| -> std::collections::BTreeSet<ContentHash> {
            chunk_spans(d, params).into_iter().map(|(a, b)| chunk_key(&d[a..b])).collect()
        };
        let a = keys(&base);
        let b = keys(&shifted);
        let shared = a.intersection(&b).count();
        assert!(
            shared * 10 >= a.len() * 7,
            "only {shared}/{} chunks survived an insertion",
            a.len()
        );
    }

    #[test]
    fn intern_release_refcounts_and_frees_at_zero() {
        let mut t = ChunkTable::default();
        let data = noise(10_000, 9);
        let k = t.intern(&data);
        let k2 = t.intern(&data);
        assert_eq!(k, k2);
        assert_eq!(t.stats().dedup_hits, 1);
        assert_eq!(t.physical_bytes(), 10_000);
        t.release(k);
        assert_eq!(t.len(), 1, "still one live ref");
        t.release(k);
        assert!(t.is_empty());
        assert_eq!(t.physical_bytes(), 0);
        assert_eq!(t.resident_bytes(), 0);
    }

    #[test]
    fn spill_evict_fault_in_roundtrip() {
        let dir = tmpdir("spill");
        let mut t = ChunkTable::default();
        let data = noise(30_000, 11);
        let k = t.intern(&data);
        // Attaching the tier late spills the pre-existing chunk.
        t.set_disk_dir(dir.clone());
        assert!(t.stats().spilled >= 1);
        t.evict_to(0);
        assert_eq!(t.resident_bytes(), 0);
        let got = t.get(k).expect("fault-in from disk");
        assert_eq!(&got[..], &data[..]);
        assert_eq!(t.stats().disk_loads, 1);
        assert_eq!(t.resident_bytes(), 30_000);
        // Release at zero deletes the chunk file too.
        t.release(k);
        assert_eq!(std::fs::read_dir(dir.clone()).unwrap().count(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_chunk_file_fails_verification() {
        let dir = tmpdir("torn");
        let mut t = ChunkTable::default();
        t.set_disk_dir(dir.clone());
        let data = noise(20_000, 13);
        let k = t.intern(&data);
        t.evict_to(0);
        // Truncate the spilled file: length check trips.
        let path = dir.join(format!("c{k}.bin"));
        std::fs::write(&path, &data[..100]).unwrap();
        assert!(t.get(k).is_none());
        // Right length, wrong bytes: rehash trips.
        let mut bad = data.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        assert!(t.get(k).is_none());
        // Restore the real bytes: readable again (no poisoning).
        std::fs::write(&path, &data).unwrap();
        assert_eq!(&t.get(k).expect("healed")[..], &data[..]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_orphans_removes_only_unclaimed_files() {
        let dir = tmpdir("sweep");
        let mut t = ChunkTable::default();
        t.set_disk_dir(dir.clone());
        let data = noise(5_000, 17);
        let _k = t.intern(&data);
        let orphan = dir.join(format!("c{}.bin", content_hash(b"ghost", CHUNK_SEED)));
        std::fs::write(&orphan, b"ghost").unwrap();
        std::fs::write(dir.join("README.txt"), b"not a chunk").unwrap();
        assert_eq!(t.sweep_orphans(), 1);
        assert!(!orphan.exists());
        assert!(dir.join("README.txt").exists(), "non-chunk files are left alone");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ensure_loadable_validates_then_commit_refs() {
        let dir = tmpdir("ensure");
        let mut t = ChunkTable::default();
        t.set_disk_dir(dir.clone());
        let data = noise(8_000, 19);
        let k = t.intern(&data);
        // A second table over the same directory (the restore path).
        let mut r = ChunkTable::default();
        r.set_disk_dir(dir.clone());
        assert!(r.ensure_loadable(k, data.len()));
        assert!(!r.ensure_loadable(k, data.len() + 1), "length mismatch rejected");
        assert!(!r.ensure_loadable(chunk_key(b"missing"), 7));
        r.commit_ref(k);
        let mut expected = BTreeMap::new();
        expected.insert(k, 1u64);
        r.debug_check(&expected, true, false);
        r.drop_unreferenced();
        assert_eq!(r.len(), 1, "committed chunk survives the placeholder sweep");
        std::fs::remove_dir_all(&dir).ok();
    }
}
