//! Synthetic training data generated on the rust side (the paper's
//! workloads use standard datasets; DESIGN.md documents the
//! substitution). Deterministic per trial seed.
//!
//! * MLP: gaussian inputs labeled by a fixed random linear teacher —
//!   learnable to high accuracy by the shipped MLP.
//! * LM: a noisy affine token chain, next = (5*cur + u) mod V with
//!   u ~ U{0..3}: entropy ln(4) ≈ 1.386 nats, so a converging
//!   transformer shows loss ~ 4.85 -> ~1.4 over a few hundred steps.

use crate::util::rng::Rng;

/// Classification batches for the MLP variants.
pub struct MlpBatchGen {
    rng: Rng,
    teacher: Vec<f32>, // in_dim x classes, fixed across all trials
    /// Input feature dimension.
    pub in_dim: usize,
    /// Number of label classes.
    pub classes: usize,
    /// Rows per batch.
    pub batch: usize,
}

impl MlpBatchGen {
    /// New generator; `seed` controls the data stream, not the teacher.
    pub fn new(batch: usize, in_dim: usize, classes: usize, seed: u64) -> Self {
        // Teacher is shared (seeded independently of the trial) so every
        // trial optimizes the same task.
        let mut trng = Rng::new(0x7EAC4E6);
        let teacher = (0..in_dim * classes).map(|_| trng.normal() as f32).collect();
        MlpBatchGen { rng: Rng::new(seed), teacher, in_dim, classes, batch }
    }

    /// Returns (x: batch*in_dim f32, y: batch i32).
    pub fn next(&mut self) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(self.batch * self.in_dim);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let xi: Vec<f32> = (0..self.in_dim).map(|_| self.rng.normal() as f32).collect();
            let mut best = (0usize, f32::NEG_INFINITY);
            for c in 0..self.classes {
                let mut dot = 0f32;
                for d in 0..self.in_dim {
                    dot += xi[d] * self.teacher[d * self.classes + c];
                }
                if dot > best.1 {
                    best = (c, dot);
                }
            }
            x.extend_from_slice(&xi);
            y.push(best.0 as i32);
        }
        (x, y)
    }

    /// RNG state for checkpointing (data order resumes deterministically).
    pub fn save_seed(&self) -> u64 {
        self.rng.clone().next_u64()
    }
}

/// Token-sequence batches for the transformer-LM variants.
pub struct LmBatchGen {
    rng: Rng,
    /// Rows per batch.
    pub batch: usize,
    /// Tokens per row = seq + 1 (input + shifted target).
    pub row_len: usize,
    /// Vocabulary size.
    pub vocab: i32,
}

impl LmBatchGen {
    /// New generator over a `vocab`-token affine chain.
    pub fn new(batch: usize, row_len: usize, vocab: i32, seed: u64) -> Self {
        LmBatchGen { rng: Rng::new(seed), batch, row_len, vocab }
    }

    /// Returns batch*row_len i32 tokens.
    pub fn next(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.row_len);
        for _ in 0..self.batch {
            let mut cur = (self.rng.next_u64() % self.vocab as u64) as i32;
            out.push(cur);
            for _ in 1..self.row_len {
                let noise = (self.rng.next_u64() % 4) as i32;
                cur = (5 * cur + noise).rem_euclid(self.vocab);
                out.push(cur);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_batches_are_deterministic_per_seed() {
        let mut a = MlpBatchGen::new(8, 4, 3, 42);
        let mut b = MlpBatchGen::new(8, 4, 3, 42);
        assert_eq!(a.next(), b.next());
        let mut c = MlpBatchGen::new(8, 4, 3, 43);
        assert_ne!(a.next().0, c.next().0);
    }

    #[test]
    fn mlp_labels_in_range_and_nontrivial() {
        let mut g = MlpBatchGen::new(256, 32, 10, 1);
        let (_, y) = g.next();
        assert!(y.iter().all(|&l| (0..10).contains(&l)));
        let distinct: std::collections::BTreeSet<i32> = y.iter().copied().collect();
        assert!(distinct.len() >= 5, "labels collapsed: {distinct:?}");
    }

    #[test]
    fn teacher_is_shared_across_trials() {
        let a = MlpBatchGen::new(1, 4, 3, 1).teacher;
        let b = MlpBatchGen::new(1, 4, 3, 999).teacher;
        assert_eq!(a, b);
    }

    #[test]
    fn lm_chain_is_learnable_markov() {
        let mut g = LmBatchGen::new(4, 65, 128, 7);
        let toks = g.next();
        assert_eq!(toks.len(), 4 * 65);
        assert!(toks.iter().all(|&t| (0..128).contains(&t)));
        // Verify the chain property on each row.
        for row in toks.chunks(65) {
            for w in row.windows(2) {
                let diff = (w[1] - 5 * w[0]).rem_euclid(128);
                assert!(diff < 4, "not a chain: {} -> {}", w[0], w[1]);
            }
        }
    }
}
