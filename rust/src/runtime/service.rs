//! PJRT service thread: the xla crate's client is Rc-based (not Send),
//! so one dedicated thread owns the runtime and all live model states —
//! the in-process analogue of Ray's "actor owning the accelerator".
//! Trial trainables talk to it through a cloneable, Send channel handle.
//!
//! Data generation also lives here (per-session, seeded), so a trial's
//! entire compute path — batch synthesis, train step, state
//! serialization — happens device-side, and the trainable only moves
//! metrics and (on checkpoint) opaque state blobs.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender};

use anyhow::{anyhow, Result};

use super::client::PjrtRuntime;
use super::data::{LmBatchGen, MlpBatchGen};

/// Identifier of one training session inside the service.
pub type SessionId = u64;

enum Request {
    /// Create a training session for (model variant, seed).
    Open { session: SessionId, model: String, seed: u64, reply: Sender<Result<()>> },
    /// Run `n` fused train steps; returns (mean loss, mean extra metrics).
    Step {
        session: SessionId,
        n: u32,
        lr: f32,
        momentum: f32,
        reply: Sender<Result<(f64, Vec<f64>)>>,
    },
    /// Serialize session state (+ data-stream position).
    Save { session: SessionId, reply: Sender<Result<Vec<u8>>> },
    /// Restore session state from a Save blob.
    Restore { session: SessionId, blob: Vec<u8>, reply: Sender<Result<()>> },
    Close { session: SessionId },
    Shutdown,
}

enum DataGen {
    Mlp(MlpBatchGen),
    Lm(LmBatchGen),
}

struct Session {
    model: String,
    state: Vec<xla::Literal>,
    gen: DataGen,
    steps: u64,
    seed: u64,
}

/// Send + Clone handle to the service thread.
#[derive(Clone)]
pub struct PjrtService {
    tx: Sender<Request>,
}

impl PjrtService {
    /// Spawn the service over an artifacts directory.
    pub fn spawn(dir: PathBuf) -> Result<PjrtService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || match PjrtRuntime::load(&dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    serve(rt, rx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })?;
        ready_rx.recv().map_err(|e| anyhow!("service died: {e}"))??;
        Ok(PjrtService { tx })
    }

    /// Create a training session for a model variant.
    pub fn open(&self, session: SessionId, model: &str, seed: u64) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Open { session, model: model.into(), seed, reply })
            .map_err(|_| anyhow!("service gone"))?;
        rx.recv().map_err(|_| anyhow!("service gone"))?
    }

    /// Run `n` fused train steps; returns (mean loss, mean extra metrics).
    pub fn step(&self, session: SessionId, n: u32, lr: f32, momentum: f32) -> Result<(f64, Vec<f64>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Step { session, n, lr, momentum, reply })
            .map_err(|_| anyhow!("service gone"))?;
        rx.recv().map_err(|_| anyhow!("service gone"))?
    }

    /// Serialize the session's full training state to a blob.
    pub fn save(&self, session: SessionId) -> Result<Vec<u8>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Save { session, reply })
            .map_err(|_| anyhow!("service gone"))?;
        rx.recv().map_err(|_| anyhow!("service gone"))?
    }

    /// Restore a session from a `save` blob (possibly another trial's).
    pub fn restore(&self, session: SessionId, blob: Vec<u8>) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Restore { session, blob, reply })
            .map_err(|_| anyhow!("service gone"))?;
        rx.recv().map_err(|_| anyhow!("service gone"))?
    }

    /// Drop a session's state.
    pub fn close(&self, session: SessionId) {
        let _ = self.tx.send(Request::Close { session });
    }

    /// Stop the service thread (idempotent; in-flight requests drain).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

fn make_gen(rt: &mut PjrtRuntime, model: &str, seed: u64) -> Result<DataGen> {
    let mm = rt.manifest.model(model)?;
    Ok(match mm.kind.as_str() {
        "mlp" => {
            let in_dim = mm.batch_inputs[0].shape[1];
            DataGen::Mlp(MlpBatchGen::new(mm.batch, in_dim, 10, seed))
        }
        "transformer_lm" => {
            let row_len = mm.batch_inputs[0].shape[1];
            let vocab = rt
                .manifest
                .model(model)?
                .meta
                .get("vocab")
                .and_then(|v| v.as_u64())
                .unwrap_or(128) as i32;
            DataGen::Lm(LmBatchGen::new(mm.batch, row_len, vocab, seed))
        }
        other => return Err(anyhow!("unknown model kind {other}")),
    })
}

fn serve(mut rt: PjrtRuntime, rx: Receiver<Request>) {
    let mut sessions: BTreeMap<SessionId, Session> = BTreeMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Open { session, model, seed, reply } => {
                let r = (|| -> Result<()> {
                    let gen = make_gen(&mut rt, &model, seed)?;
                    let m = rt.model(&model)?;
                    let state = m.init_state((seed & 0x7FFF_FFFF) as i32)?;
                    sessions.insert(session, Session { model, state, gen, steps: 0, seed });
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Request::Step { session, n, lr, momentum, reply } => {
                let r = (|| -> Result<(f64, Vec<f64>)> {
                    let s = sessions.get_mut(&session).ok_or_else(|| anyhow!("no session"))?;
                    let model = rt.model(&s.model)?;
                    let mut loss_acc = 0.0;
                    let mut metric_acc: Vec<f64> = Vec::new();
                    for _ in 0..n.max(1) {
                        let batch = match &mut s.gen {
                            DataGen::Mlp(g) => {
                                let (x, y) = g.next();
                                model.batch_literals(&[x], &[y])?
                            }
                            DataGen::Lm(g) => {
                                let toks = g.next();
                                model.batch_literals(&[], &[toks])?
                            }
                        };
                        let state = std::mem::take(&mut s.state);
                        let out = model.train_step(state, &batch, lr, momentum)?;
                        s.state = out.state;
                        s.steps += 1;
                        loss_acc += out.loss;
                        if metric_acc.is_empty() {
                            metric_acc = vec![0.0; out.metrics.len()];
                        }
                        for (a, m) in metric_acc.iter_mut().zip(&out.metrics) {
                            *a += m;
                        }
                    }
                    let inv = 1.0 / n.max(1) as f64;
                    Ok((loss_acc * inv, metric_acc.into_iter().map(|m| m * inv).collect()))
                })();
                let _ = reply.send(r);
            }
            Request::Save { session, reply } => {
                let r = (|| -> Result<Vec<u8>> {
                    let s = sessions.get(&session).ok_or_else(|| anyhow!("no session"))?;
                    let model = rt.model(&s.model)?;
                    let mut blob = Vec::new();
                    blob.extend_from_slice(&s.steps.to_le_bytes());
                    blob.extend_from_slice(&s.seed.to_le_bytes());
                    blob.extend(model.serialize_state(&s.state)?);
                    Ok(blob)
                })();
                let _ = reply.send(r);
            }
            Request::Restore { session, blob, reply } => {
                let r = (|| -> Result<()> {
                    anyhow::ensure!(blob.len() > 16, "short state blob");
                    let steps = u64::from_le_bytes(blob[..8].try_into().unwrap());
                    let seed = u64::from_le_bytes(blob[8..16].try_into().unwrap());
                    let model_name = sessions
                        .get(&session)
                        .ok_or_else(|| anyhow!("no session"))?
                        .model
                        .clone();
                    let state = rt.model(&model_name)?.deserialize_state(&blob[16..])?;
                    // Re-seed the data stream past the checkpoint, so
                    // restored trials see fresh (but deterministic) data.
                    let gen = make_gen(&mut rt, &model_name, seed ^ steps)?;
                    let s = sessions.get_mut(&session).unwrap();
                    s.state = state;
                    s.steps = steps;
                    s.gen = gen;
                    Ok(())
                })();
                let _ = reply.send(r);
            }
            Request::Close { session } => {
                sessions.remove(&session);
            }
            Request::Shutdown => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn service() -> Option<PjrtService> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(PjrtService::spawn(dir).unwrap())
    }

    #[test]
    fn sessions_are_independent_and_learn() {
        let Some(svc) = service() else { return };
        svc.open(1, "mlp_relu", 11).unwrap();
        svc.open(2, "mlp_relu", 22).unwrap();
        let (l1a, _) = svc.step(1, 5, 0.1, 0.9).unwrap();
        let (l2a, _) = svc.step(2, 5, 0.1, 0.9).unwrap();
        let (l1b, m1) = svc.step(1, 20, 0.1, 0.9).unwrap();
        assert!(l1b < l1a, "{l1a} -> {l1b}");
        assert!(l2a > 0.0);
        assert!(!m1.is_empty()); // accuracy
        svc.close(1);
        svc.close(2);
        svc.shutdown();
    }

    #[test]
    fn save_restore_resumes_loss_level() {
        let Some(svc) = service() else { return };
        svc.open(1, "mlp_tanh", 5).unwrap();
        svc.step(1, 25, 0.1, 0.9).unwrap();
        let blob = svc.save(1).unwrap();
        let (trained_loss, _) = svc.step(1, 1, 0.0, 0.0).unwrap();

        svc.open(2, "mlp_tanh", 99).unwrap();
        let (fresh_loss, _) = svc.step(2, 1, 0.0, 0.0).unwrap();
        svc.restore(2, blob).unwrap();
        let (restored_loss, _) = svc.step(2, 1, 0.0, 0.0).unwrap();
        assert!(restored_loss < fresh_loss, "{restored_loss} vs fresh {fresh_loss}");
        assert!((restored_loss - trained_loss).abs() < 0.5);
        svc.shutdown();
    }

    #[test]
    fn open_unknown_model_errors() {
        let Some(svc) = service() else { return };
        assert!(svc.open(1, "nope", 0).is_err());
        svc.shutdown();
    }
}
