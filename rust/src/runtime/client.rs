//! PJRT runtime: load AOT-compiled HLO-text artifacts and drive them.
//!
//! This is the request-path compute engine: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` once per model variant →
//! `execute` per training step. Python is never involved (it ran once at
//! `make artifacts`).
//!
//! NOT Send (the xla crate's client is Rc-based): the owning thread is
//! the "device". [`super::service::PjrtService`] wraps this in a
//! dedicated thread with a channel API for the multi-threaded executor.

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, ModelManifest};

/// One compiled model variant: train + init executables.
pub struct LoadedModel {
    /// The variant's manifest entry (shapes, metric names, metadata).
    pub manifest: ModelManifest,
    train: xla::PjRtLoadedExecutable,
    init: xla::PjRtLoadedExecutable,
}

/// Output of one fused train step.
pub struct StepResult {
    /// Updated training state (params + velocities).
    pub state: Vec<xla::Literal>,
    /// Scalar training loss of the step.
    pub loss: f64,
    /// Extra metrics in manifest order (after "loss").
    pub metrics: Vec<f64>,
}

impl LoadedModel {
    /// Run the init executable: seed -> fresh state (params + zero
    /// velocities).
    pub fn init_state(&self, seed: i32) -> Result<Vec<xla::Literal>> {
        let seed_lit = xla::Literal::scalar(seed);
        let result = self.init.execute::<xla::Literal>(&[seed_lit])?[0][0].to_literal_sync()?;
        let state = result.to_tuple()?;
        if state.len() != self.manifest.num_state_arrays() {
            return Err(anyhow!(
                "init returned {} arrays, manifest says {}",
                state.len(),
                self.manifest.num_state_arrays()
            ));
        }
        Ok(state)
    }

    /// Run one fused fwd+bwd+update step.
    ///
    /// `state` is consumed and replaced (the executable is functional;
    /// feeding outputs back as inputs is the rust-side analogue of
    /// donated buffers).
    pub fn train_step(
        &self,
        state: Vec<xla::Literal>,
        batch: &[xla::Literal],
        lr: f32,
        momentum: f32,
    ) -> Result<StepResult> {
        let n = self.manifest.num_state_arrays();
        if state.len() != n {
            return Err(anyhow!("state has {} arrays, expected {n}", state.len()));
        }
        if batch.len() != self.manifest.batch_inputs.len() {
            return Err(anyhow!("batch has {} inputs", batch.len()));
        }
        let mut args: Vec<xla::Literal> = state;
        args.extend(batch.iter().map(clone_literal));
        args.push(xla::Literal::scalar(lr));
        args.push(xla::Literal::scalar(momentum));

        let result = self.train.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        if outs.len() != self.manifest.num_outputs() {
            return Err(anyhow!(
                "train returned {} outputs, manifest says {}",
                outs.len(),
                self.manifest.num_outputs()
            ));
        }
        let metrics_lits: Vec<xla::Literal> = outs.split_off(n);
        let loss = metrics_lits[0].get_first_element::<f32>()? as f64;
        let metrics = metrics_lits[1..]
            .iter()
            .map(|l| l.get_first_element::<f32>().map(|v| v as f64))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        Ok(StepResult { state: outs, loss, metrics })
    }

    /// Build batch literals from host vectors according to the manifest.
    pub fn batch_literals(&self, f32_data: &[Vec<f32>], i32_data: &[Vec<i32>]) -> Result<Vec<xla::Literal>> {
        let mut fi = 0;
        let mut ii = 0;
        let mut out = Vec::new();
        for spec in &self.manifest.batch_inputs {
            let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
            let lit = match spec.dtype.as_str() {
                "f32" => {
                    let v = f32_data.get(fi).ok_or_else(|| anyhow!("missing f32 input"))?;
                    fi += 1;
                    anyhow::ensure!(v.len() == spec.elements(), "bad f32 input size");
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                "i32" => {
                    let v = i32_data.get(ii).ok_or_else(|| anyhow!("missing i32 input"))?;
                    ii += 1;
                    anyhow::ensure!(v.len() == spec.elements(), "bad i32 input size");
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                other => return Err(anyhow!("unsupported dtype {other}")),
            };
            out.push(lit);
        }
        Ok(out)
    }

    /// Serialize state to bytes (checkpoint payload): f32 LE, arrays in
    /// manifest order (params then velocities).
    pub fn serialize_state(&self, state: &[xla::Literal]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.manifest.state_elements() * 4);
        for lit in state {
            let v: Vec<f32> = lit.to_vec()?;
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Ok(out)
    }

    /// Inverse of [`serialize_state`].
    pub fn deserialize_state(&self, bytes: &[u8]) -> Result<Vec<xla::Literal>> {
        let want = self.manifest.state_elements() * 4;
        anyhow::ensure!(bytes.len() == want, "state blob {} bytes, want {want}", bytes.len());
        let mut out = Vec::with_capacity(self.manifest.num_state_arrays());
        let mut off = 0;
        // params then velocities: same shapes twice.
        for pass in 0..2 {
            let _ = pass;
            for spec in &self.manifest.state {
                let n = spec.elements();
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    v.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
                    off += 4;
                }
                let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
                out.push(xla::Literal::vec1(&v).reshape(&dims)?);
            }
        }
        Ok(out)
    }
}

/// Literal lacks Clone in the crate; round-trip through bytes.
fn clone_literal(l: &xla::Literal) -> xla::Literal {
    // Literals we clone are small batch inputs; shape-preserving copy.
    let shape = l.array_shape().expect("array literal");
    let dims: Vec<i64> = shape.dims().to_vec();
    match l.ty().expect("element type") {
        xla::ElementType::F32 => {
            let v: Vec<f32> = l.to_vec().expect("f32 vec");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = l.to_vec().expect("i32 vec");
            xla::Literal::vec1(&v).reshape(&dims).expect("reshape")
        }
        other => panic!("unsupported literal type {other:?}"),
    }
}

/// The single-threaded PJRT runtime (not `Send`; see the service).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    /// The artifact manifest this runtime serves models from.
    pub manifest: Manifest,
    models: BTreeMap<String, LoadedModel>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client over an artifacts directory. Models are
    /// compiled lazily on first use (compilation is seconds per
    /// variant).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, manifest, models: BTreeMap::new() })
    }

    /// Name of the backing PJRT platform ("cpu", or "stub" offline).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return a model variant.
    pub fn model(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.models.contains_key(name) {
            let mm = self.manifest.model(name)?.clone();
            let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = self.manifest.dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("loading {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Ok(self.client.compile(&comp)?)
            };
            let train = compile(&mm.train_hlo)?;
            let init = compile(&mm.init_hlo)?;
            self.models.insert(name.to_string(), LoadedModel { manifest: mm, train, init });
        }
        Ok(&self.models[name])
    }

    /// Names of the variants compiled so far.
    pub fn compiled_variants(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::data::MlpBatchGen;

    fn runtime() -> Option<PjrtRuntime> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(PjrtRuntime::load(&dir).unwrap())
    }

    #[test]
    fn mlp_loss_decreases_over_steps() {
        let Some(mut rt) = runtime() else { return };
        let model = rt.model("mlp_relu").unwrap();
        let mut state = model.init_state(0).unwrap();
        let mut gen = MlpBatchGen::new(model.manifest.batch, 32, 10, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let (x, y) = gen.next();
            let batch = model.batch_literals(&[x], &[y]).unwrap();
            let out = model.train_step(state, &batch, 0.1, 0.9).unwrap();
            state = out.state;
            first.get_or_insert(out.loss);
            last = out.loss;
        }
        let first = first.unwrap();
        assert!(last < first * 0.7, "loss {first} -> {last}");
    }

    #[test]
    fn init_is_seed_dependent() {
        let Some(mut rt) = runtime() else { return };
        let model = rt.model("mlp_relu").unwrap();
        let a = model.init_state(0).unwrap();
        let b = model.init_state(1).unwrap();
        let av: Vec<f32> = a[0].to_vec().unwrap();
        let bv: Vec<f32> = b[0].to_vec().unwrap();
        assert_ne!(av, bv);
    }

    #[test]
    fn state_serialization_roundtrip_is_exact() {
        let Some(mut rt) = runtime() else { return };
        let model = rt.model("mlp_tanh").unwrap();
        let state = model.init_state(7).unwrap();
        let blob = model.serialize_state(&state).unwrap();
        let state2 = model.deserialize_state(&blob).unwrap();
        for (a, b) in state.iter().zip(&state2) {
            let av: Vec<f32> = a.to_vec().unwrap();
            let bv: Vec<f32> = b.to_vec().unwrap();
            assert_eq!(av, bv);
        }
    }

    #[test]
    fn lr_zero_is_identity_update() {
        let Some(mut rt) = runtime() else { return };
        let model = rt.model("mlp_relu").unwrap();
        let state = model.init_state(3).unwrap();
        let before = model.serialize_state(&state).unwrap();
        let mut gen = MlpBatchGen::new(model.manifest.batch, 32, 10, 1);
        let (x, y) = gen.next();
        let batch = model.batch_literals(&[x], &[y]).unwrap();
        let out = model.train_step(state, &batch, 0.0, 0.0).unwrap();
        let after = model.serialize_state(&out.state).unwrap();
        // Params unchanged (first half); velocities become grads.
        assert_eq!(before[..before.len() / 2], after[..after.len() / 2]);
    }
}
