//! Runtime layer: AOT-compiled JAX/Pallas workloads behind PJRT.
//!
//! * [`manifest`] — the python↔rust artifact contract
//! * [`client`] — PJRT client, compiled executables, state ser/de
//! * [`service`] — device-owning thread + Send channel handle
//! * [`data`] — deterministic synthetic batch generators

pub mod client;
pub mod data;
pub mod manifest;
pub mod service;

pub use client::{LoadedModel, PjrtRuntime, StepResult};
pub use manifest::{Manifest, ModelManifest};
pub use service::{PjrtService, SessionId};
